"""CI bench-regression gate.

Runs the benchmark harness in smoke mode (``benchmarks/run.py --smoke``),
writes the gated metrics to ``BENCH_ci.json`` (uploaded as a CI
artifact, so the repo finally records a perf trajectory), and compares
them against the committed ``benchmarks/baseline.json``:

* ``tokens_per_step`` — hybrid-schedule decode throughput in engine
  steps (deterministic step accounting, machine-independent);
* ``mean_ttft_steps`` — hybrid mean submit->first-token latency in
  engine steps (deterministic);
* ``async_speedup`` — async/sync wall-clock decode ratio (a *ratio* of
  two runs on the same machine, so it transfers across CI runners where
  absolute tokens/s would not);
* ``paged_batch_gain`` — paged vs dense effective decode batch under the
  same HBM budget (pure ``eval_shape`` arithmetic, deterministic);
* ``fp8_batch_gain`` — fp8-quantized vs bf16 paged effective batch under
  the same device KV byte budget (eval_shape arithmetic, deterministic;
  the KV-tiering capacity claim);
* ``cluster_speedup_2r`` / ``affinity_hit_rate`` — cluster tokens/round
  scaling at 2 replicas over 1, and the prefix-affinity router's
  resident-prefix hit-rate (both counted in deterministic rounds/tokens);
* ``disagg_ttft_gain`` — mixed over prefill/decode-disaggregated mean
  end-to-end TTFT in cluster rounds at equal capacity (deterministic
  round counting; must stay >= 1, i.e. disaggregation never hurts);
* ``spec_decode_gain`` — depth-2 speculative vs non-speculative decode
  tokens per engine step under the target-as-draft acceptance ceiling
  (deterministic step counting; the bench itself asserts the 1.2x
  floor, the gate catches regressions from the committed baseline);
* ``kernel_decode_err`` — the decode-attention kernel smoke row's max
  abs err vs the jnp oracle, with an 8x band: only a genuine numeric
  divergence (a real kernel bug is many orders of magnitude) trips it.
  The row's kernel/oracle wall-clock ratio
  (``kernel_decode_vs_oracle``) is recorded alongside for the perf
  trajectory but not gated — smoke-window interpret-mode timings swing
  severalfold run to run.

``ttft_p99_steps`` / ``per_token_p99_steps`` (exact percentiles over
per-request samples, via the telemetry metrics registry) ride along in
``BENCH_ci.json`` un-gated for now, and every run also appends a
``BENCH_<n>.json`` trajectory snapshot at the repo root
(``benchmarks.run.write_trajectory``).

A metric regressing past its band — or any sub-bench raising — fails the
job.  ``--update`` rewrites the baseline from the current run instead of
gating (commit the result).

  PYTHONPATH=src python -m benchmarks.ci_gate [--update] [--tolerance 0.25]
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from benchmarks import run as bench_run

# benches whose returned metrics dicts are merged (flat, keys disjoint)
# into the gated set; everything else still runs for its own asserts
GATED_BENCHES = ("scheduler_bench", "paged_bench", "kernel_bench",
                 "cluster_bench", "spec_bench")

# metric -> (direction that counts as an improvement, tolerance multiplier).
# Deterministic counts (engine steps, rounds, eval_shape arithmetic) get
# the plain tolerance; async_speedup is a wall-clock ratio of two runs
# on the same machine (it transfers across runners) from a short smoke
# window, so it gets double the slack; kernel_decode_err is an absolute
# float error that can shift with CPU ISA/vectorization, so its 8x band
# only trips on a genuine numeric divergence (a real kernel bug is many
# orders of magnitude).  kernel_decode_vs_oracle is recorded in
# BENCH_ci.json/baseline.json for the trajectory but NOT gated: the
# smoke window's interpret-mode timings swing severalfold run to run,
# so any band tight enough to mean something would flake CI.
GATED = {
    "tokens_per_step": ("higher", 1.0),
    "mean_ttft_steps": ("lower", 1.0),
    "async_speedup": ("higher", 2.0),
    "paged_batch_gain": ("higher", 1.0),
    "fp8_batch_gain": ("higher", 1.0),
    "cluster_speedup_2r": ("higher", 1.0),
    "affinity_hit_rate": ("higher", 1.0),
    "disagg_ttft_gain": ("higher", 1.0),
    "spec_decode_gain": ("higher", 1.0),
    "kernel_decode_err": ("lower", 8.0),
}


def gate(metrics: dict, baseline: dict, tolerance: float) -> list[str]:
    """Return human-readable failure lines for regressed metrics."""
    problems = []
    for key, (direction, slack) in GATED.items():
        base, cur = baseline.get(key), metrics.get(key)
        tol = tolerance * slack
        if base is None or cur is None:
            problems.append(f"{key}: missing (baseline={base}, current={cur})")
            continue
        if direction == "higher":
            floor = base * (1 - tol)
            if cur < floor:
                problems.append(
                    f"{key}: {cur:.3f} regressed below {floor:.3f} "
                    f"(baseline {base:.3f} - {tol:.0%})"
                )
        else:
            ceil = base * (1 + tol)
            if cur > ceil:
                problems.append(
                    f"{key}: {cur:.3f} regressed above {ceil:.3f} "
                    f"(baseline {base:.3f} + {tol:.0%})"
                )
    return problems


def main(argv: list[str] | None = None) -> int:
    here = Path(__file__).resolve().parent
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=str(here / "baseline.json"))
    ap.add_argument("--out", default="BENCH_ci.json")
    ap.add_argument("--tolerance", type=float, default=0.25)
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from this run instead of gating")
    args = ap.parse_args(argv)

    all_metrics, failures = bench_run.run_benches(list(bench_run.ALL), smoke=True)
    metrics: dict = {}
    for bench in GATED_BENCHES:
        metrics.update(all_metrics.get(bench, {}))

    report = {"metrics": metrics, "bench_failures": failures}
    Path(args.out).write_text(json.dumps(report, indent=2) + "\n")
    print(f"\nwrote {args.out}: {json.dumps(metrics)}")
    if all_metrics:
        print(f"trajectory snapshot: {bench_run.write_trajectory(all_metrics)}")

    if args.update:
        Path(args.baseline).write_text(json.dumps(metrics, indent=2) + "\n")
        print(f"baseline updated: {args.baseline}")
        return 1 if failures else 0

    if failures:
        print(f"bench failures: {', '.join(failures)}", file=sys.stderr)
        return 1
    try:
        baseline = json.loads(Path(args.baseline).read_text())
    except FileNotFoundError:
        print(f"no baseline at {args.baseline}; run with --update first",
              file=sys.stderr)
        return 1
    problems = gate(metrics, baseline, args.tolerance)
    if problems:
        print("BENCH REGRESSION:", file=sys.stderr)
        for p in problems:
            print(f"  {p}", file=sys.stderr)
        return 1
    print(f"bench gate OK (tolerance ±{args.tolerance:.0%} vs {args.baseline})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
