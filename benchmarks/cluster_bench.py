"""Cluster scale-out: aggregate throughput vs replica count, and
prefix-affinity routing vs round-robin on a shared-prefix workload.

The paper's §VI scaling argument — add HPU cards, serve more resident
KV, decode more tokens per unit time — maps to engine replicas behind
one router.  Two sections:

* **scaling sweep** — the same mixed-length workload served by 1, 2 (and
  4 in the full run) replicas; reports generated tokens per *cluster
  round* (one round steps every replica once — the deterministic,
  machine-independent scaling metric) plus wall tokens/s, and asserts
  tokens/round strictly increases with replica count.
* **prefix affinity** — G prompt groups sharing long prefixes, paged
  cache, hybrid schedule, interleaved arrivals.  ``round_robin`` shreds
  each group across replicas so their shared blocks never co-reside;
  ``prefix_affinity`` routes members to the replica already holding the
  prefix (via the side-effect-free block-hash probe).  Reports and
  asserts a strictly higher resident-prefix hit-rate, and compares mean
  TTFT in engine steps (prefix-hit chunks are skipped by the chunked
  prefill, so affinity cuts prefill work, not just allocator churn).

* **disaggregated compare** — the same paced arrival stream served by an
  all-``mixed`` cluster and by a prefill/decode split (equal total slot
  count and equal per-replica token budget, so capacity is identical and
  only the *layout* differs).  Under a tight token budget a mixed
  replica's resident decodes shrink its prefill chunks (prefill/decode
  interference), while a prefill-role replica — whose sequences migrate
  to a decode replica the round their last chunk completes — prefills at
  the full budget every round.  Reports and gates ``disagg_ttft_gain`` —
  mixed over disaggregated mean *end-to-end* TTFT in cluster rounds
  (submit round to first-token round, which includes the global queue
  wait) — and asserts the disaggregated layout is no slower.

``main`` returns a metrics dict consumed by ``benchmarks/ci_gate.py``:
``cluster_speedup_2r`` (tokens/round at 2 replicas over 1), the two
hit-rates, and ``disagg_ttft_gain``.  ``--smoke`` runs the down-sized
CI workload (1P+1D vs 2 mixed; the full run compares 2P+2D vs 4 mixed).
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from repro.configs.reduced import reduce_config
from repro.core.placement import Env
from repro.models.registry import build_model
from repro.serving.cluster import Cluster
from repro.serving.engine import Request

MAX_SEQ = 64
MAX_NEW = 8
CHUNK = 16
BLOCK = 8


def _mixed_workload(n_requests, vocab):
    rng = np.random.default_rng(0)
    return [rng.integers(1, vocab, size=int(rng.integers(4, 28))).astype(np.int32)
            for _ in range(n_requests)]


def _shared_prefix_workload(vocab, n_groups, per_group, prefix_len, suffix_len):
    """Interleaved group members: A1 B1 C1 A2 B2 C2 ... — the arrival
    order that scatters groups under round-robin placement."""
    rng = np.random.default_rng(1)
    prefixes = [rng.integers(1, vocab, size=prefix_len).astype(np.int32)
                for _ in range(n_groups)]
    prompts = []
    for j in range(per_group):
        for g in range(n_groups):
            suffix = rng.integers(1, vocab, size=suffix_len).astype(np.int32)
            prompts.append(np.concatenate([prefixes[g], suffix]))
    return prompts


def _serve_cluster(model, params, prompts, n_replicas, route, max_new=MAX_NEW,
                   **engine_kw):
    cl = Cluster(model, params, n_replicas, route=route, **engine_kw)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        cl.submit(r)
    t0 = time.perf_counter()
    stats = cl.run()
    wall = time.perf_counter() - t0
    return reqs, stats, cl, wall


def scaling_sweep(model, params, print_fn=print, smoke: bool = False) -> dict:
    counts = (1, 2) if smoke else (1, 2, 4)
    n_requests = 12 if smoke else 24
    prompts = _mixed_workload(n_requests, model.cfg.vocab)
    print_fn(f"# scaling sweep: {n_requests} mixed-length requests, "
             f"2 slots/replica, route=round_robin")
    print_fn("replicas,rounds,generated,tokens_per_round,imbalance,wall_s,tok_per_s")
    tpr = {}
    for n in counts:
        reqs, stats, _, wall = _serve_cluster(
            model, params, prompts, n, "round_robin",
            n_slots=2, max_seq=MAX_SEQ, schedule="hybrid", prefill_chunk=CHUNK,
        )
        assert all(r.done for r in reqs)
        tpr[n] = stats.tokens_per_round
        print_fn(f"{n},{stats.rounds},{stats.generated},"
                 f"{stats.tokens_per_round:.3f},{stats.load_imbalance:.2f},"
                 f"{wall:.2f},{stats.generated / wall:.1f}")
    for lo, hi in zip(counts, counts[1:]):
        assert tpr[hi] > tpr[lo], (
            f"tokens/round did not scale: {tpr[lo]:.3f} @ {lo} replicas vs "
            f"{tpr[hi]:.3f} @ {hi}"
        )
    speedup = tpr[2] / tpr[1]
    print_fn(f"# cluster 2-replica tokens/round speedup: {speedup:.2f}x")
    return {"cluster_speedup_2r": speedup}


def affinity_compare(model, params, print_fn=print, smoke: bool = False) -> dict:
    per_group = 3 if smoke else 5
    prompts = _shared_prefix_workload(
        model.cfg.vocab, n_groups=3, per_group=per_group,
        prefix_len=2 * BLOCK, suffix_len=3,
    )
    # 4 slots/replica + max_new=12: group members overlap in residence, so
    # the placement policy (not capacity pressure) decides whether a
    # member lands where its prefix blocks live
    kw = dict(n_slots=4, max_seq=MAX_SEQ, cache_kind="paged", block_size=BLOCK,
              schedule="hybrid", prefill_chunk=CHUNK)
    print_fn(f"\n# prefix affinity: 3 groups x {per_group} requests, shared "
             f"{2 * BLOCK}-token prefixes, 2 replicas x 4 slots, paged/hybrid")
    print_fn("route,prefix_hit_rate,mean_ttft_steps,spills,imbalance")
    results = {}
    for route in ("round_robin", "prefix_affinity"):
        reqs, stats, _, _ = _serve_cluster(model, params, prompts, 2, route,
                                           max_new=12, **kw)
        assert all(r.done for r in reqs)
        results[route] = stats
        print_fn(f"{route},{stats.prefix_hit_rate:.3f},"
                 f"{stats.mean_ttft_steps:.2f},{stats.spills},"
                 f"{stats.load_imbalance:.2f}")
    rr, aff = results["round_robin"], results["prefix_affinity"]
    assert aff.prefix_hit_rate > rr.prefix_hit_rate, (
        f"prefix_affinity hit-rate {aff.prefix_hit_rate:.3f} not above "
        f"round_robin {rr.prefix_hit_rate:.3f}"
    )
    print_fn(f"# affinity hit-rate {aff.prefix_hit_rate:.2f} vs round-robin "
             f"{rr.prefix_hit_rate:.2f}; TTFT {aff.mean_ttft_steps:.1f} vs "
             f"{rr.mean_ttft_steps:.1f} engine steps")
    return {
        "affinity_hit_rate": aff.prefix_hit_rate,
        "round_robin_hit_rate": rr.prefix_hit_rate,
        "affinity_ttft_steps": aff.mean_ttft_steps,
        "round_robin_ttft_steps": rr.mean_ttft_steps,
    }


def _serve_paced(model, params, prompts, n_replicas, gap, max_new, **kw):
    """Open-loop arrival stream: one request submitted every ``gap``
    cluster rounds (steady-state serving, not a batch drain — the regime
    where layout, not aggregate capacity, decides TTFT)."""
    cl = Cluster(model, params, n_replicas, route="round_robin", **kw)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    it = iter(reqs)
    pending = next(it)
    while pending is not None:
        if cl.rounds % gap == 0:
            cl.submit(pending)
            pending = next(it, None)
        cl.step()
    stats = cl.run()
    return reqs, stats


def disagg_compare(model, params, print_fn=print, smoke: bool = False) -> dict:
    """Mixed vs prefill/decode-disaggregated layout at equal per-replica
    slots and equal per-replica token budget, under a paced arrival
    stream.

    The mechanism being measured is prefill/decode *interference*: with
    ``token_budget=16`` (= one prefill chunk), a mixed replica's resident
    decodes eat into the chunk budget, and the block-boundary clip drops
    its prefill rate to 8 tokens/round whenever any decode is resident —
    while a prefill-role replica (its decodes migrate away every round)
    prefills at the full 16.  Faster prefill is directly lower TTFT; the
    disaggregated layout buys it by giving decodes a dedicated home.
    Slots: mixed runs 4/replica; disagg runs 2 on prefill replicas and 6
    on decode replicas — same cluster total.
    """
    n_replicas = 2 if smoke else 4
    roles = "1p+1d" if smoke else "2p+2d"
    n_requests = 10 if smoke else 20
    gap = 3 if smoke else 2          # one arrival per `gap` rounds
    mixed_slots = 4
    budget = 16
    role_kw = {"prefill": {"n_slots": 2}, "decode": {"n_slots": 6}}
    rng = np.random.default_rng(2)
    prompts = [rng.integers(1, model.cfg.vocab,
                            size=int(rng.integers(32, 49))).astype(np.int32)
               for _ in range(n_requests)]
    kw = dict(max_seq=96, cache_kind="paged", block_size=BLOCK,
              schedule="hybrid", prefill_chunk=CHUNK, token_budget=budget)
    print_fn(f"\n# disaggregated: {n_requests} requests arriving every {gap} "
             f"rounds, {n_replicas} replicas ({roles} at 2P/6D slots vs "
             f"all-mixed at {mixed_slots}), paged/hybrid, token_budget="
             f"{budget}, max_new=16")
    print_fn("layout,rounds,generated,ttft_rounds_mean,ttft_rounds_p99,"
             "migrations")
    results = {}
    for label, role_spec in (("mixed", None), ("disagg", roles)):
        reqs, stats = _serve_paced(
            model, params, prompts, n_replicas, gap, max_new=16,
            roles=role_spec, role_kw=role_kw if role_spec else None,
            n_slots=mixed_slots, **kw,
        )
        assert all(r.done for r in reqs)
        results[label] = stats
        print_fn(f"{label},{stats.rounds},{stats.generated},"
                 f"{stats.mean_ttft_rounds:.2f},"
                 f"{stats.ttft_rounds_percentile(99):.0f},{stats.migrations}")
    mixed, disagg = results["mixed"], results["disagg"]
    assert disagg.migrations > 0, "disaggregated run performed no migrations"
    assert disagg.mean_ttft_rounds <= mixed.mean_ttft_rounds, (
        f"disaggregated mean TTFT {disagg.mean_ttft_rounds:.2f} rounds above "
        f"mixed {mixed.mean_ttft_rounds:.2f}"
    )
    gain = mixed.mean_ttft_rounds / max(disagg.mean_ttft_rounds, 1e-9)
    print_fn(f"# disagg TTFT gain: {gain:.2f}x "
             f"({mixed.mean_ttft_rounds:.1f} -> {disagg.mean_ttft_rounds:.1f} "
             f"rounds, {disagg.migrations} migrations)")
    return {"disagg_ttft_gain": gain}


def main(print_fn=print, smoke: bool = False) -> dict:
    cfg = reduce_config("llama3.2-1b")
    model = build_model(cfg, Env())
    params = model.init(jax.random.key(0))
    metrics = scaling_sweep(model, params, print_fn, smoke)
    metrics.update(affinity_compare(model, params, print_fn, smoke))
    metrics.update(disagg_compare(model, params, print_fn, smoke))
    return metrics


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
