"""Fig. 1b/1c: OI roofline and MFU/MBU vs batch size (A100, Llama-2-7B)."""
from repro.core import oi
from repro.core.oi import DEVICES, LLAMA2_7B as M

A100 = DEVICES["A100"]
BATCHES = [1, 2, 4, 8, 16, 32, 64, 128, 203, 256, 512]


def rows():
    out = []
    for b in BATCHES:
        oi_gemm = oi.gemm_oi(b)
        oi_gemv = oi.gemv_oi(M.group)
        perf_gemm = oi.attainable_flops(A100, oi_gemm)
        perf_gemv = oi.attainable_flops(A100, oi_gemv)
        mfu_gemm, mbu_gemm = oi.mfu_mbu(A100, oi_gemm)
        mfu_gemv, mbu_gemv = oi.mfu_mbu(A100, oi_gemv)
        out.append(
            dict(
                batch=b,
                oi_gemm=oi_gemm,
                oi_gemv=oi_gemv,
                gemm_tflops=perf_gemm / 1e12,
                gemv_tflops=perf_gemv / 1e12,
                mfu_gemm=mfu_gemm,
                mbu_gemm=mbu_gemm,
                mfu_gemv=mfu_gemv,
                mbu_gemv=mbu_gemv,
            )
        )
    return out


def main(print_fn=print):
    print_fn("# Fig1b/1c: A100 roofline, GEMM vs GEMV OI and MFU/MBU vs batch")
    print_fn("batch,oi_gemm,oi_gemv,gemm_tflops,gemv_tflops,mfu_gemm,mbu_gemm,mfu_gemv,mbu_gemv")
    for r in rows():
        print_fn(
            f"{r['batch']},{r['oi_gemm']:.0f},{r['oi_gemv']:.0f},"
            f"{r['gemm_tflops']:.1f},{r['gemv_tflops']:.2f},"
            f"{r['mfu_gemm']:.3f},{r['mbu_gemm']:.3f},{r['mfu_gemv']:.4f},{r['mbu_gemv']:.3f}"
        )
    print_fn(f"# crossover at batch ~= ridge point {A100.ridge:.0f} (paper: 203)")
