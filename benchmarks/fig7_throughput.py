"""Fig. 7a/7b + §VI-B: throughput scaling, OOM boundary, time breakdown.

GPU-only (L40S) vs GPU+{1,2,4} HPU prototypes, Llama-2-7B, 2K context.
Normalized to GPU-only @ batch 16 like the paper.  Paper points:
hetero(4 HPU) @ {16,32,64} = {1.9x, 2.9x, 4.1x}; network share ~10%.
"""
from repro.core import oi
from repro.core.oi import DEVICES, LLAMA2_7B as M

L40S = DEVICES["L40S"]
HPUP = DEVICES["HPU-PROTO"]
SEQ_AVG = 1536
PAPER = {16: 1.9, 32: 2.9, 64: 4.1}


def rows():
    base = oi.step_time_gpu_only(L40S, M, 16, SEQ_AVG)
    base_tput = 16 / base["total"]
    out = []
    max_gpu = oi.max_batch_gpu_only(L40S, M, 2048)
    for batch in (8, 16, 32, 64):
        gpu_ok = batch <= max_gpu
        row = dict(batch=batch, gpu_only="OOM" if not gpu_ok else None)
        if gpu_ok:
            t = oi.step_time_gpu_only(L40S, M, batch, SEQ_AVG)
            row["gpu_only"] = (batch / t["total"]) / base_tput
        for n_hpu in (1, 2, 4):
            cap = n_hpu * oi.max_batch_per_hpu(HPUP, M, SEQ_AVG)
            if batch > cap:
                row[f"hpu{n_hpu}"] = "OOM"
                continue
            t = oi.step_time_hetero(L40S, HPUP, M, batch, SEQ_AVG, n_hpu=n_hpu)
            row[f"hpu{n_hpu}"] = (batch / t["total"]) / base_tput
            if n_hpu == 4:
                row["breakdown"] = t
        out.append(row)
    return out


def main(print_fn=print):
    print_fn("# Fig7a: normalized throughput (GPU-only@16 = 1.0); OOM per §VI-B")
    print_fn("batch,gpu_only,hpu1,hpu2,hpu4,paper_hpu4,dev_pct")
    for r in rows():
        def fmt(v):
            return v if isinstance(v, str) else (f"{v:.2f}" if v is not None else "-")
        paper = PAPER.get(r["batch"], "")
        dev = ""
        if paper and not isinstance(r["hpu4"], str):
            dev = f"{(r['hpu4'] - paper) / paper * 100:+.0f}%"
        print_fn(
            f"{r['batch']},{fmt(r['gpu_only'])},{fmt(r['hpu1'])},"
            f"{fmt(r['hpu2'])},{fmt(r['hpu4'])},{paper},{dev}"
        )
    print_fn("# Fig7b: generation-stage time breakdown, GPU+4HPU")
    print_fn("batch,linear_ms,attention_ms,network_ms,network_share")
    for r in rows():
        t = r.get("breakdown")
        if not t:
            continue
        print_fn(
            f"{r['batch']},{t['linear']*1e3:.2f},{t['attention']*1e3:.2f},"
            f"{t['network']*1e3:.2f},{t['network']/t['total']:.2%}"
        )
