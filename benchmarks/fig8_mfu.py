"""Fig. 8: MFU vs batch, GPU-only vs heterogeneous (linear-only GPU)."""
from repro.core import oi
from repro.core.oi import DEVICES, LLAMA2_7B as M

L40S = DEVICES["L40S"]
H100 = DEVICES["H100-NVL"]
HPUP = DEVICES["HPU-PROTO"]
HPU = DEVICES["HPU"]
SEQ_AVG = 1536


def rows():
    out = []
    for batch in (16, 32, 64, 128, 256, 512):
        r = {"batch": batch}
        for name, gpu in (("l40s", L40S), ("h100", H100)):
            t = oi.step_time_gpu_only(gpu, M, batch, SEQ_AVG)
            r[f"{name}_only"] = oi.mfu_end_to_end(gpu, M, batch, SEQ_AVG, t)
        # hetero: GPU runs only linear; enough HPUs to hold the batch
        for name, gpu, hpu in (("l40s_hpu", L40S, HPUP), ("h100_hpu", H100, HPU)):
            n_hpu = max(1, -(-batch // max(oi.max_batch_per_hpu(hpu, M, SEQ_AVG), 1)))
            t = oi.step_time_hetero(gpu, hpu, M, batch, SEQ_AVG, n_hpu=n_hpu)
            useful = M.linear_flops_per_token() * batch
            r[name] = useful / (t["total"] * gpu.flops)
        out.append(r)
    return out


def main(print_fn=print):
    print_fn("# Fig8: MFU vs batch (paper: GPU-only ~1%, L40S+HPU up to 44%, H100+HPU 39%)")
    print_fn("batch,l40s_only,h100_only,l40s_hpu,h100_hpu")
    for r in rows():
        print_fn(
            f"{r['batch']},{r['l40s_only']:.3f},{r['h100_only']:.3f},"
            f"{r['l40s_hpu']:.3f},{r['h100_hpu']:.3f}"
        )
