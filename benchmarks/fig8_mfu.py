"""Fig. 8: MFU vs batch, GPU-only vs heterogeneous (linear-only GPU).

Two sections: the paper's *analytic* roofline rows (device constants
from Table I), plus a **measured** row — a reduced-config engine run
under a sync-mode :class:`DispatchProfiler` (``sample_every=1``), whose
fenced wall-clock joins with the same analytic FLOPs/bytes into measured
MFU/MBU, printed next to the roofline ideal at the same operational
intensity.  On this CPU-backed jax the measured numbers are tiny — the
point is that the live profiler and the analytic model agree on the
*accounting* (same OI, same bytes), which is what a real-device Fig-8
reproduction would graph.
"""
import jax

from repro.core import oi
from repro.core.oi import DEVICES, LLAMA2_7B as M

L40S = DEVICES["L40S"]
H100 = DEVICES["H100-NVL"]
HPUP = DEVICES["HPU-PROTO"]
HPU = DEVICES["HPU"]
SEQ_AVG = 1536


def rows():
    out = []
    for batch in (16, 32, 64, 128, 256, 512):
        r = {"batch": batch}
        for name, gpu in (("l40s", L40S), ("h100", H100)):
            t = oi.step_time_gpu_only(gpu, M, batch, SEQ_AVG)
            r[f"{name}_only"] = oi.mfu_end_to_end(gpu, M, batch, SEQ_AVG, t)
        # hetero: GPU runs only linear; enough HPUs to hold the batch
        for name, gpu, hpu in (("l40s_hpu", L40S, HPUP), ("h100_hpu", H100, HPU)):
            n_hpu = max(1, -(-batch // max(oi.max_batch_per_hpu(hpu, M, SEQ_AVG), 1)))
            t = oi.step_time_hetero(gpu, hpu, M, batch, SEQ_AVG, n_hpu=n_hpu)
            useful = M.linear_flops_per_token() * batch
            r[name] = useful / (t["total"] * gpu.flops)
        out.append(r)
    return out


def measured_rows(n_requests: int = 6, max_new: int = 8,
                  device: str = "TPU-V5E"):
    """Measured-mode rows: a reduced engine profiled in sync mode, one
    row per (dispatch kind, bucket, decode batch) the run produced."""
    import numpy as np

    from repro.configs.reduced import reduce_config
    from repro.core.placement import Env
    from repro.models.registry import build_model
    from repro.serving.engine import Engine, Request
    from repro.serving.telemetry import DispatchProfiler

    cfg = reduce_config("llama3.2-1b")
    model = build_model(cfg, Env())
    params = model.init(jax.random.key(0))
    prof = DispatchProfiler(sample_every=1, device=device)
    eng = Engine(model, params, n_slots=4, max_seq=64, schedule="hybrid",
                 prefill_chunk=16, profiler=prof)
    rng = np.random.default_rng(0)
    for uid in range(n_requests):
        prompt = rng.integers(1, cfg.vocab,
                              size=int(rng.integers(4, 24))).astype(np.int32)
        eng.submit(Request(uid=uid, prompt=prompt, max_new_tokens=max_new))
    eng.run()
    dev = DEVICES[device]
    out = []
    for (kind, bucket, batch), row in sorted(
            prof.summary().items(), key=lambda kv: str(kv[0])):
        ideal_mfu, ideal_mbu = oi.mfu_mbu(dev, max(row["oi"], 1e-9))
        out.append({
            "kind": kind, "bucket": bucket, "batch": batch,
            "n": int(row["n"]), "oi": row["oi"],
            "roofline_mfu": ideal_mfu, "roofline_mbu": ideal_mbu,
            "measured_mfu": row["measured_mfu"],
            "measured_mbu": row["measured_mbu"],
            "achieved_gbps": row["achieved_gbps"],
        })
    return out


def main(print_fn=print, smoke: bool = False):
    print_fn("# Fig8: MFU vs batch (paper: GPU-only ~1%, L40S+HPU up to 44%, H100+HPU 39%)")
    print_fn("batch,l40s_only,h100_only,l40s_hpu,h100_hpu")
    for r in rows():
        print_fn(
            f"{r['batch']},{r['l40s_only']:.3f},{r['h100_only']:.3f},"
            f"{r['l40s_hpu']:.3f},{r['h100_hpu']:.3f}"
        )
    print_fn("# measured (reduced engine, sync profiler) vs roofline ideal "
             "at the same OI")
    print_fn("kind,bucket,batch,n,oi,roofline_mfu,measured_mfu,"
             "roofline_mbu,measured_mbu,achieved_gbps")
    mrows = measured_rows(n_requests=3 if smoke else 6,
                          max_new=4 if smoke else 8)
    peak = 0.0
    for r in mrows:
        print_fn(
            f"{r['kind']},{r['bucket']},{r['batch']},{r['n']},"
            f"{r['oi']:.2f},{r['roofline_mfu']:.4f},"
            f"{r['measured_mfu']:.6f},{r['roofline_mbu']:.4f},"
            f"{r['measured_mbu']:.6f},{r['achieved_gbps']:.2f}"
        )
        peak = max(peak, r["measured_mbu"])
    return {"measured_rows": float(len(mrows)),
            "measured_peak_mbu": peak}
