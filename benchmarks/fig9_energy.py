"""Fig. 9: energy efficiency (tokens/s/W), normalized to L40S-only @ 16."""
from repro.core import oi
from repro.core.oi import DEVICES, LLAMA2_7B as M

L40S = DEVICES["L40S"]
H100 = DEVICES["H100-NVL"]
HPUP = DEVICES["HPU-PROTO"]
SEQ_AVG = 1536


def rows():
    base_t = oi.step_time_gpu_only(L40S, M, 16, SEQ_AVG)
    base = oi.tokens_per_joule(16, base_t, L40S)
    out = []
    for batch in (8, 16, 32, 64):
        r = {"batch": batch}
        if batch <= oi.max_batch_gpu_only(L40S, M, 2048):
            t = oi.step_time_gpu_only(L40S, M, batch, SEQ_AVG)
            r["l40s_only"] = oi.tokens_per_joule(batch, t, L40S) / base
        else:
            r["l40s_only"] = None
        t = oi.step_time_gpu_only(H100, M, batch, SEQ_AVG)
        r["h100_only"] = oi.tokens_per_joule(batch, t, H100) / base
        t = oi.step_time_hetero(L40S, HPUP, M, batch, SEQ_AVG, n_hpu=4)
        r["l40s_4hpu"] = oi.tokens_per_joule(batch, t, L40S, n_hpu=4) / base
        out.append(r)
    return out


def main(print_fn=print):
    print_fn("# Fig9: tokens/s/W normalized to L40S-only@16 (paper: 4HPU@64 = 4.58x)")
    print_fn("batch,l40s_only,h100_only,l40s_4hpu")
    for r in rows():
        lo = "OOM" if r["l40s_only"] is None else f"{r['l40s_only']:.2f}"
        print_fn(f"{r['batch']},{lo},{r['h100_only']:.2f},{r['l40s_4hpu']:.2f}")
    print_fn("# deviation note: ideal-roofline H100 beats the FPGA prototype "
             "on tokens/s/W; the paper's measured 1.92x advantage is not "
             "reproducible from Table I alone (see EXPERIMENTS.md)")
