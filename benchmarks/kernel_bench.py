"""Kernel micro-benchmarks (interpret mode on CPU -> correctness-scale
timings; TPU numbers come from the dry-run roofline, not wall clock).

``main`` returns the decode-attention row as a metrics dict — the
kernel/oracle wall-clock *ratio* (a ratio of two runs on the same
machine transfers across CI runners where absolute µs would not) and
the max abs err vs the oracle; ``benchmarks/ci_gate.py`` gates both
with wide variance bands, so only a multiple-x blowup (the "compile
path broke" regime) trips CI.  ``--smoke`` cuts the timing repetitions
for the CI run.
"""
import sys
import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, *args, n=5):
    # one warmup; block_until_ready handles arrays and pytrees uniformly
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6


def main(print_fn=print, smoke: bool = False) -> dict:
    reps = 2 if smoke else 5
    print_fn("# kernel micro-bench (CPU interpret mode): us_per_call vs jnp oracle")
    print_fn("name,us_per_call,oracle_us,max_abs_err")
    key = jax.random.key(0)
    B, S, Hkv, G, D = 2, 256, 2, 4, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Hkv * G, D), jnp.float32)
    kc = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    vc = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    lengths = jnp.full((B,), S, jnp.int32)

    t_kern = _time(lambda: ops.decode_attention(q, kc, vc, lengths, block_s=64),
                   n=reps)
    t_ref = _time(lambda: ref.naive_decode_attention(q, kc, vc, lengths), n=reps)
    err = float(
        jnp.max(jnp.abs(ops.decode_attention(q, kc, vc, lengths, block_s=64)
                        - ref.naive_decode_attention(q, kc, vc, lengths)))
    )
    print_fn(f"decode_attention_b{B}s{S}g{G},{t_kern:.0f},{t_ref:.0f},{err:.2e}")
    metrics = {"kernel_decode_vs_oracle": t_kern / max(t_ref, 1e-9),
               "kernel_decode_err": err}

    Sq = 128
    q2 = jax.random.normal(ks[0], (B, Sq, Hkv * G, D), jnp.float32)
    k2 = jax.random.normal(ks[1], (B, Sq, Hkv, D), jnp.float32)
    v2 = jax.random.normal(ks[2], (B, Sq, Hkv, D), jnp.float32)
    t_kern = _time(lambda: ops.flash_attention(q2, k2, v2, block_q=64, block_k=64),
                   n=reps)
    t_ref = _time(lambda: ref.naive_attention(q2, k2, v2), n=reps)
    err = float(jnp.max(jnp.abs(
        ops.flash_attention(q2, k2, v2, block_q=64, block_k=64)
        - ref.naive_attention(q2, k2, v2))))
    print_fn(f"flash_attention_b{B}s{Sq}g{G},{t_kern:.0f},{t_ref:.0f},{err:.2e}")

    # paged decode: same shapes as the dense decode row, KV scattered
    # across a block pool and gathered through per-sequence block tables
    bs = 64
    MB = S // bs
    N = 1 + B * MB
    kp = jax.random.normal(ks[1], (N, Hkv, bs, D), jnp.float32)
    vp = jax.random.normal(ks[2], (N, Hkv, bs, D), jnp.float32)
    tables = jnp.arange(1, N, dtype=jnp.int32).reshape(B, MB)
    t_kern = _time(lambda: ops.paged_decode_attention(q, kp, vp, tables, lengths),
                   n=reps)
    t_ref = _time(lambda: ref.paged_decode_attention(q, kp, vp, tables, lengths),
                  n=reps)
    err = float(
        jnp.max(jnp.abs(ops.paged_decode_attention(q, kp, vp, tables, lengths)
                        - ref.paged_decode_attention(q, kp, vp, tables, lengths)))
    )
    print_fn(f"paged_decode_attention_b{B}s{S}g{G}bs{bs},{t_kern:.0f},{t_ref:.0f},{err:.2e}")

    # quantized paged decode: fp8 payload + per-vector scales, dequant
    # inside the kernel.  The accuracy column is vs the *full-precision*
    # oracle — the end-to-end error the fp8 KV tier actually adds — and
    # kernel correctness itself is the tiny gap vs the dequantized oracle.
    kq, k_scale = ref.kv_quantize(kp, "fp8")
    vq, v_scale = ref.kv_quantize(vp, "fp8")
    t_kern = _time(lambda: ops.paged_decode_attention(
        q, kq, vq, tables, lengths, k_scale=k_scale, v_scale=v_scale), n=reps)
    full = ref.paged_decode_attention(q, kp, vp, tables, lengths)
    out_q = ops.paged_decode_attention(q, kq, vq, tables, lengths,
                                       k_scale=k_scale, v_scale=v_scale)
    exp_q = ref.paged_decode_attention(q, kq, vq, tables, lengths,
                                       k_scale=k_scale, v_scale=v_scale)
    q_err = float(jnp.max(jnp.abs(out_q - full)))       # quantization error
    k_err = float(jnp.max(jnp.abs(out_q - exp_q)))      # kernel-vs-oracle
    print_fn(f"paged_decode_fp8_b{B}s{S}g{G}bs{bs},{t_kern:.0f},{t_ref:.0f},{q_err:.2e}")
    metrics["kernel_decode_fp8_quant_err"] = q_err
    metrics["kernel_decode_fp8_err"] = k_err
    return metrics


def _bench_wrap(fn):
    return fn


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
