"""Dense vs paged KV cache: effective batch size and KV bytes/token.

The paper's scaling argument is that decode throughput is bound by how
many sequences the (HPU) memory pool can hold, not by compute.  This
bench quantifies what paging buys under that constraint:

* **capacity sweep** (no allocation — ``eval_shape`` on the full model):
  under the same HBM budget the dense cache reserves ``max_seq`` for
  every slot, while the paged pool charges each sequence only
  ``ceil(len/block)`` blocks — at mixed sequence lengths that multiplies
  the effective decode batch.
* **live run** (reduced config, CPU): both engine modes serve the same
  mixed-length workload; asserts identical greedy tokens and reports
  pool stats (allocs, prefix-cache hits, COW copies).
"""
from __future__ import annotations

import math

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.reduced import reduce_config
from repro.core.placement import Env
from repro.models.registry import build_model
from repro.serving.engine import Engine, Request

# mixed-length workload (tokens per sequence incl. a decode allowance)
MIXED_LENS = [64, 160, 288, 544, 1056, 2080, 4096]


def _bytes_of(tree) -> int:
    return sum(
        math.prod(v.shape) * v.dtype.itemsize for v in jax.tree.leaves(tree)
    )


def capacity_rows(arch: str, n_slots: int, max_seq: int, block_size: int,
                  print_fn=print):
    cfg = get_config(arch)
    model = build_model(cfg, Env())
    max_blocks = -(-max_seq // block_size)

    dense_bytes = _bytes_of(model.cache_shapes(n_slots, max_seq))
    # paged pool sized to the same HBM budget
    one = _bytes_of(model.paged_cache_shapes(n_slots, 2, block_size, max_blocks))
    two = _bytes_of(model.paged_cache_shapes(n_slots, 3, block_size, max_blocks))
    block_bytes = two - one
    n_blocks = max(2, dense_bytes // block_bytes)

    # greedy-pack the mixed workload into each cache until it is full
    lens, i = [], 0
    while len(lens) < n_slots:
        lens.append(MIXED_LENS[i % len(MIXED_LENS)])
        i += 1
    dense_tokens = sum(lens)

    free, paged_lens = n_blocks - 1, []
    while True:
        ln = MIXED_LENS[len(paged_lens) % len(MIXED_LENS)]
        need = -(-ln // block_size)
        if need > free:
            break
        free -= need
        paged_lens.append(ln)
    paged_tokens = sum(paged_lens)

    print_fn(
        f"{arch},dense,{n_slots},{dense_tokens},"
        f"{dense_bytes / max(dense_tokens, 1):.0f}"
    )
    print_fn(
        f"{arch},paged,{len(paged_lens)},{paged_tokens},"
        f"{dense_bytes / max(paged_tokens, 1):.0f}"
    )
    return len(paged_lens) / n_slots


def live_run(print_fn=print):
    cfg = reduce_config("llama3.2-1b")
    model = build_model(cfg, Env())
    params = model.init(jax.random.key(0))
    prompts = [np.arange(1, 6, dtype=np.int32),
               np.arange(7, 10, dtype=np.int32),
               np.arange(2, 13, dtype=np.int32),
               np.arange(2, 13, dtype=np.int32)]   # shared prefix with #2

    def serve(kind, **kw):
        eng = Engine(model, params, n_slots=2, max_seq=32, cache_kind=kind, **kw)
        reqs = [Request(uid=i, prompt=p, max_new_tokens=4)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        stats = eng.run()
        return reqs, stats, eng

    dense_reqs, dense_stats, _ = serve("dense")
    paged_reqs, paged_stats, eng = serve("paged", block_size=8)
    identical = all(
        a.out_tokens == b.out_tokens for a, b in zip(dense_reqs, paged_reqs)
    )
    print_fn(f"# live greedy tokens identical: {identical}")
    print_fn(f"# dense: {dense_stats}")
    print_fn(f"# paged: {paged_stats}")
    print_fn(f"# pool:  {eng.pool.stats}")
    assert identical, "paged decode diverged from dense"


def main(print_fn=print) -> dict:
    print_fn("# paged KV bench: same HBM budget, mixed sequence lengths")
    print_fn("arch,cache,effective_batch,resident_tokens,kv_bytes_per_token")
    gain = capacity_rows("llama3.2-1b", n_slots=32, max_seq=4096,
                         block_size=64, print_fn=print_fn)
    print_fn(f"# paged effective-batch gain at mixed lengths: {gain:.2f}x")
    live_run(print_fn)
    # deterministic (eval_shape arithmetic): gated by ci_gate.py
    return {"paged_batch_gain": gain}


if __name__ == "__main__":
    main()
