"""Dense vs paged KV cache: effective batch size and KV bytes/token.

The paper's scaling argument is that decode throughput is bound by how
many sequences the (HPU) memory pool can hold, not by compute.  This
bench quantifies what paging buys under that constraint:

* **capacity sweep** (no allocation — ``eval_shape`` on the full model):
  under the same HBM budget the dense cache reserves ``max_seq`` for
  every slot, while the paged pool charges each sequence only
  ``ceil(len/block)`` blocks — at mixed sequence lengths that multiplies
  the effective decode batch.
* **live run** (reduced config, CPU): both engine modes serve the same
  mixed-length workload; asserts identical greedy tokens and reports
  pool stats (allocs, prefix-cache hits, COW copies).

The KV-tiering rows quantify the second capacity lever: an fp8 pool
stores ~half the bytes per block (payload 1 B/elem + per-vector scales),
so the same device budget holds ~2x the sequences
(``fp8_batch_gain``, gated in CI), and a host tier turns block-pressure
preemptions into spills — the live demo serves a workload that does not
fit the device pool with zero preemptions and unchanged greedy tokens.
"""
from __future__ import annotations

import math

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.reduced import reduce_config
from repro.core.placement import Env
from repro.models.registry import build_model
from repro.serving.engine import Engine, Request

# mixed-length workload (tokens per sequence incl. a decode allowance)
MIXED_LENS = [64, 160, 288, 544, 1056, 2080, 4096]


def _bytes_of(tree) -> int:
    return sum(
        math.prod(v.shape) * v.dtype.itemsize for v in jax.tree.leaves(tree)
    )


def _pack_blocks(n_blocks: int, block_size: int) -> list[int]:
    """Greedy-pack the mixed workload into a pool until it is full."""
    free, lens = n_blocks - 1, []
    while True:
        ln = MIXED_LENS[len(lens) % len(MIXED_LENS)]
        need = -(-ln // block_size)
        if need > free:
            break
        free -= need
        lens.append(ln)
    return lens


def capacity_rows(arch: str, n_slots: int, max_seq: int, block_size: int,
                  print_fn=print):
    cfg = get_config(arch)
    model = build_model(cfg, Env())
    max_blocks = -(-max_seq // block_size)

    dense_bytes = _bytes_of(model.cache_shapes(n_slots, max_seq))

    def pool_fit(**kw) -> int:
        # paged pool sized to the same HBM budget (per-block bytes from
        # an eval_shape delta, so scale pools are charged too)
        one = _bytes_of(
            model.paged_cache_shapes(n_slots, 2, block_size, max_blocks, **kw)
        )
        two = _bytes_of(
            model.paged_cache_shapes(n_slots, 3, block_size, max_blocks, **kw)
        )
        return max(2, dense_bytes // (two - one))

    # greedy-pack the mixed workload into each cache until it is full
    lens, i = [], 0
    while len(lens) < n_slots:
        lens.append(MIXED_LENS[i % len(MIXED_LENS)])
        i += 1
    dense_tokens = sum(lens)

    paged_lens = _pack_blocks(pool_fit(), block_size)
    paged_tokens = sum(paged_lens)
    fp8_lens = _pack_blocks(pool_fit(kv_dtype="fp8"), block_size)

    print_fn(
        f"{arch},dense,{n_slots},{dense_tokens},"
        f"{dense_bytes / max(dense_tokens, 1):.0f}"
    )
    print_fn(
        f"{arch},paged,{len(paged_lens)},{paged_tokens},"
        f"{dense_bytes / max(paged_tokens, 1):.0f}"
    )
    print_fn(
        f"{arch},paged_fp8,{len(fp8_lens)},{sum(fp8_lens)},"
        f"{dense_bytes / max(sum(fp8_lens), 1):.0f}"
    )
    return (len(paged_lens) / n_slots,
            len(fp8_lens) / max(len(paged_lens), 1))


def live_run(print_fn=print):
    cfg = reduce_config("llama3.2-1b")
    model = build_model(cfg, Env())
    params = model.init(jax.random.key(0))
    prompts = [np.arange(1, 6, dtype=np.int32),
               np.arange(7, 10, dtype=np.int32),
               np.arange(2, 13, dtype=np.int32),
               np.arange(2, 13, dtype=np.int32)]   # shared prefix with #2

    def serve(kind, **kw):
        eng = Engine(model, params, n_slots=2, max_seq=32, cache_kind=kind, **kw)
        reqs = [Request(uid=i, prompt=p, max_new_tokens=4)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        stats = eng.run()
        return reqs, stats, eng

    dense_reqs, dense_stats, _ = serve("dense")
    paged_reqs, paged_stats, eng = serve("paged", block_size=8)
    identical = all(
        a.out_tokens == b.out_tokens for a, b in zip(dense_reqs, paged_reqs)
    )
    print_fn(f"# live greedy tokens identical: {identical}")
    print_fn(f"# dense: {dense_stats}")
    print_fn(f"# paged: {paged_stats}")
    print_fn(f"# pool:  {eng.pool.stats}")
    assert identical, "paged decode diverged from dense"

    # fp8 pool: greedy tokens stay faithful (prefill stages in bf16, so
    # first tokens are exact; later tokens may drift within quant noise)
    fp8_reqs, _, feng = serve("paged", block_size=8, kv_dtype="fp8")
    total = sum(len(r.out_tokens) for r in paged_reqs)
    same = sum(sum(x == y for x, y in zip(a.out_tokens, b.out_tokens))
               for a, b in zip(paged_reqs, fp8_reqs))
    print_fn(f"# fp8 pool: {same}/{total} greedy tokens identical")
    assert all(r.done for r in fp8_reqs)
    assert all(a.out_tokens[0] == b.out_tokens[0]
               for a, b in zip(paged_reqs, fp8_reqs)), "fp8 first token drifted"

    # host tier: a pool too small for both sequences spills its cold
    # prefix blocks instead of preempting, and decodes identical tokens
    tight = [np.arange(1, 10, dtype=np.int32), np.arange(3, 8, dtype=np.int32)]

    def serve2(**kw):
        eng = Engine(model, params, n_slots=2, max_seq=32, cache_kind="paged",
                     block_size=4, **kw)
        reqs = [Request(uid=i, prompt=p, max_new_tokens=10)
                for i, p in enumerate(tight)]
        for r in reqs:
            eng.submit(r)
        return reqs, eng.run(), eng

    ref_reqs, _, _ = serve2()
    sp_reqs, sp_stats, se = serve2(n_blocks=9, host_blocks=8)
    print_fn(f"# host tier: spills={sp_stats.spills} "
             f"preemptions={sp_stats.preemptions} "
             f"host_peak={se.pool.stats.host_peak_in_use} blocks")
    assert sp_stats.spills >= 1, "tight pool never spilled"
    assert sp_stats.preemptions == 0, "host tier failed to absorb pressure"
    assert all(a.out_tokens == b.out_tokens
               for a, b in zip(ref_reqs, sp_reqs)), "spilled decode diverged"


def main(print_fn=print) -> dict:
    print_fn("# paged KV bench: same HBM budget, mixed sequence lengths")
    print_fn("arch,cache,effective_batch,resident_tokens,kv_bytes_per_token")
    gain, fp8_gain = capacity_rows("llama3.2-1b", n_slots=32, max_seq=4096,
                                   block_size=64, print_fn=print_fn)
    print_fn(f"# paged effective-batch gain at mixed lengths: {gain:.2f}x")
    print_fn(f"# fp8 effective-batch gain over bf16 paged: {fp8_gain:.2f}x")
    live_run(print_fn)
    # deterministic (eval_shape arithmetic): gated by ci_gate.py
    return {"paged_batch_gain": gain, "fp8_batch_gain": fp8_gain}


if __name__ == "__main__":
    main()
