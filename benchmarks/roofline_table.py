"""§Roofline table: three terms per (arch x shape x mesh) from the dry-run
artifacts in results/dryrun (the brief's required analysis)."""
import glob
import json
import os

RESULTS = os.environ.get("DRYRUN_DIR", "results/dryrun")


def load_cells(pattern="*.json"):
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS, pattern))):
        base = os.path.basename(path)[: -len(".json")]
        if base.count(".") > 2:  # skip tagged perf-iteration cells (kv_*, ga*, kvq8...)
            continue
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def main(print_fn=print):
    cells = load_cells()
    if not cells:
        print_fn(f"# no dry-run artifacts under {RESULTS}; run "
                 "`python -m repro.launch.dryrun --all` first")
        return
    print_fn("# §Roofline: per-cell three-term roofline (seconds/step; TPU v5e "
             "constants: 197 TF bf16, 819 GB/s HBM, 50 GB/s ICI)")
    print_fn("arch,shape,mesh,chips,compute_s,memory_s,collective_s,bottleneck,"
             "model_gflops,useful_ratio,roofline_frac,peak_GiB_per_dev,kv_policy")
    from repro.analysis.roofline import recompute_cell

    for c in cells:
        r = recompute_cell(c).as_dict()
        print_fn(
            f"{c['arch']},{c['shape']},{c['mesh']},{c['n_chips']},"
            f"{r['compute_s']:.3e},{r['memory_s']:.3e},{r['collective_s']:.3e},"
            f"{r['bottleneck']},{r['model_flops']/1e9:.0f},"
            f"{r['useful_ratio']:.3f},{r['roofline_frac']:.3f},"
            f"{c['memory']['peak_bytes_per_dev']/2**30:.2f},{c['env']['kv_policy']}"
        )
