"""Benchmark harness — one module per paper table/figure.

  fig1_roofline    Fig. 1b/1c  OI roofline + MFU/MBU vs batch
  fig7_throughput  Fig. 7a/7b  throughput scaling, OOM, time breakdown
  fig8_mfu         Fig. 8      MFU vs batch, GPU-only vs heterogeneous
  fig9_energy      Fig. 9      tokens/s/W
  roofline_table   brief       3-term roofline per dry-run cell
  kernel_bench     —           Pallas kernels vs oracle (interpret mode)
  paged_bench      —           dense vs paged KV capacity + live equivalence
  scheduler_bench  —           decode-only vs hybrid chunked-prefill TTFT

``python -m benchmarks.run [name ...]`` — default runs everything.
"""
import sys

from benchmarks import (
    fig1_roofline,
    fig7_throughput,
    fig8_mfu,
    fig9_energy,
    kernel_bench,
    paged_bench,
    roofline_table,
    scheduler_bench,
)

ALL = {
    "fig1_roofline": fig1_roofline.main,
    "fig7_throughput": fig7_throughput.main,
    "fig8_mfu": fig8_mfu.main,
    "fig9_energy": fig9_energy.main,
    "roofline_table": roofline_table.main,
    "kernel_bench": kernel_bench.main,
    "paged_bench": paged_bench.main,
    "scheduler_bench": scheduler_bench.main,
}


def main() -> None:
    names = sys.argv[1:] or list(ALL)
    for name in names:
        print(f"\n==== {name} ====")
        ALL[name]()


if __name__ == "__main__":
    main()
