"""Benchmark harness — one module per paper table/figure.

  fig1_roofline    Fig. 1b/1c  OI roofline + MFU/MBU vs batch
  fig7_throughput  Fig. 7a/7b  throughput scaling, OOM, time breakdown
  fig8_mfu         Fig. 8      MFU vs batch, GPU-only vs heterogeneous
  fig9_energy      Fig. 9      tokens/s/W
  roofline_table   brief       3-term roofline per dry-run cell
  kernel_bench     —           Pallas kernels vs oracle (interpret mode)
  paged_bench      —           dense vs paged KV capacity + live equivalence
  scheduler_bench  —           decode-only vs hybrid TTFT, sync vs async
  spec_bench       —           speculative decode gain vs depth + acceptance
  cluster_bench    —           replica scale-out + prefix-affinity routing

``python -m benchmarks.run [--smoke] [name ...]`` — default runs
everything.  ``--smoke`` passes the down-sized CI workload to benches
that support it.  Every named bench runs even if an earlier one fails;
any failure makes the process exit nonzero (the CI bench gate depends on
that), and :func:`main` returns whatever metrics dicts the benches
produced (``benchmarks/ci_gate.py`` consumes them).
"""
from __future__ import annotations

import inspect
import json
import re
import sys
import traceback
from pathlib import Path

from benchmarks import (
    cluster_bench,
    fig1_roofline,
    fig7_throughput,
    fig8_mfu,
    fig9_energy,
    kernel_bench,
    paged_bench,
    roofline_table,
    scheduler_bench,
    spec_bench,
)

ALL = {
    "fig1_roofline": fig1_roofline.main,
    "fig7_throughput": fig7_throughput.main,
    "fig8_mfu": fig8_mfu.main,
    "fig9_energy": fig9_energy.main,
    "roofline_table": roofline_table.main,
    "kernel_bench": kernel_bench.main,
    "paged_bench": paged_bench.main,
    "scheduler_bench": scheduler_bench.main,
    "spec_bench": spec_bench.main,
    "cluster_bench": cluster_bench.main,
}


def run_benches(names: list[str], smoke: bool = False) -> tuple[dict, list[str]]:
    """Run the named benches; every one runs even if an earlier one
    fails.  Returns ({name: metrics-dict}, [failed names])."""
    metrics: dict = {}
    failures: list[str] = []
    for name in names:
        print(f"\n==== {name} ====")
        fn = ALL.get(name)
        if fn is None:
            print(f"unknown bench {name!r} (known: {', '.join(ALL)})",
                  file=sys.stderr)
            failures.append(name)
            continue
        kwargs = {}
        if smoke and "smoke" in inspect.signature(fn).parameters:
            kwargs["smoke"] = True
        try:
            result = fn(**kwargs)
        except Exception:
            traceback.print_exc()
            failures.append(name)
            continue
        if isinstance(result, dict):
            metrics[name] = result
    return metrics, failures


def write_trajectory(metrics: dict, root: str | Path | None = None) -> Path:
    """Persist one ``BENCH_<n>.json`` perf-trajectory snapshot at the
    repo root (next free integer after the existing snapshots), so the
    repo accumulates a comparable run-over-run record.  ``metrics`` is
    the ``{bench: metrics-dict}`` map :func:`run_benches` returns."""
    root = Path(root) if root is not None else Path(__file__).resolve().parents[1]
    taken = [
        int(m.group(1))
        for p in root.glob("BENCH_*.json")
        if (m := re.fullmatch(r"BENCH_(\d+)\.json", p.name))
    ]
    path = root / f"BENCH_{max(taken, default=0) + 1}.json"
    path.write_text(json.dumps(metrics, indent=2, sort_keys=True) + "\n")
    return path


def main(argv: list[str] | None = None) -> dict:
    """Run the named benches (all by default); return {name: metrics}.

    Raises ``SystemExit(1)`` after running everything if any bench
    raised — a sub-bench failure must not leave the harness exiting 0.
    """
    argv = list(sys.argv[1:] if argv is None else argv)
    smoke = "--smoke" in argv
    names = [a for a in argv if not a.startswith("--")] or list(ALL)
    metrics, failures = run_benches(names, smoke)
    if metrics:
        print(f"\ntrajectory snapshot: {write_trajectory(metrics)}")
    if failures:
        print(f"\nFAILED benches: {', '.join(failures)}", file=sys.stderr)
        raise SystemExit(1)
    return metrics


if __name__ == "__main__":
    main()
