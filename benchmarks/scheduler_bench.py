"""Decode-only whole-prefill vs hybrid chunked-prefill scheduling, and
synchronous vs async (dispatch-ahead) engine execution.

The paper's co-processing keeps dense GEMMs and GEMV-shaped decode
attention busy at the same time; the serving-layer analogue is the
token-budget hybrid schedule (``serving/scheduler.py``), where a prefill
chunk rides each decode step instead of stalling the batch.  This bench
serves the same mixed-prompt-length workload under both schedules and
reports, per mode:

* ``engine_steps`` — fixed hybrid-batch units of work dispatched (a
  decode-only whole prefill of L tokens counts ceil(L / chunk) units);
* mean **TTFT** in engine steps (submit -> first token);
* **tokens/step** and wall-clock tokens/s;
* jit program counts — the hybrid path compiles at most one fused and
  one solo program per chunk bucket, no matter how many distinct prompt
  lengths arrive, while decode-only compiles one prefill per length.

A second section serves a decode-heavy workload at batch >= 8 with the
engine's synchronous mode (block on logits, sample on host) vs the async
dispatch-ahead pipeline (on-device sampling, token feedback
device-to-device, iteration *t+1* dispatched before *t* is observed) and
reports the wall-clock decode-throughput ratio.  Compilation is excluded
by warming each engine on the same prompt-length set first.

Asserts greedy outputs are token-identical across schedules (dense and
paged) and across sync/async, and that hybrid's mean TTFT beats
decode-only's at mixed lengths.

``main`` returns a metrics dict (tokens/step, mean TTFT, async speedup)
consumed by ``benchmarks/ci_gate.py``.

``--smoke`` runs a down-sized workload for CI.
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from repro.configs.reduced import reduce_config
from repro.core.placement import Env
from repro.models.registry import build_model
from repro.serving.engine import Engine, Request

MAX_SEQ = 64
MAX_NEW = 8
CHUNK = 16

# >= 4 distinct prompt lengths in both sizes (the no-recompile claim)
SMOKE_LENS = [5, 12, 19, 26, 9, 23]
FULL_LENS = [5, 12, 19, 26, 30, 9, 16, 23, 7, 28, 11, 21, 14, 25, 6, 18]


def _workload(lens, vocab):
    rng = np.random.default_rng(0)
    return [rng.integers(1, vocab, size=n).astype(np.int32) for n in lens]


def serve_mode(model, params, prompts, n_slots, **kw):
    eng = Engine(model, params, n_slots=n_slots, max_seq=MAX_SEQ,
                 prefill_chunk=CHUNK, **kw)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=MAX_NEW)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    t0 = time.time()
    stats = eng.run()
    wall = time.time() - t0
    return reqs, stats, eng, wall


def _row(name, stats, wall, print_fn):
    print_fn(
        f"{name},{stats.engine_steps},{stats.mean_ttft_steps:.2f},"
        f"{stats.tokens_per_step:.3f},{wall:.2f},{stats.generated / wall:.1f}"
    )


def async_compare(model, params, print_fn=print, smoke: bool = False) -> float:
    """Sync vs async decode throughput at batch >= 8; returns the
    async/sync tokens-per-second ratio (compile time excluded)."""
    cfg = model.cfg
    n_slots = 8
    # even the smoke workload decodes a few hundred tokens per mode: the
    # gated ratio needs walls well clear of timer noise
    max_new = 24 if smoke else 32
    rng = np.random.default_rng(1)
    lens = [int(rng.integers(4, 10)) for _ in range(12 if smoke else 16)]
    prompts = [rng.integers(1, cfg.vocab, size=n).astype(np.int32) for n in lens]

    def timed(async_mode):
        eng = Engine(model, params, n_slots=n_slots, max_seq=MAX_SEQ,
                     async_mode=async_mode)
        # warmup pass covers every jit shape (same prompt-length set)
        warm = [Request(uid=1000 + i, prompt=p, max_new_tokens=2)
                for i, p in enumerate(prompts)]
        for r in warm:
            eng.submit(r)
        eng.run()
        warm_generated = eng.stats.generated
        reqs = [Request(uid=i, prompt=p, max_new_tokens=max_new)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        t0 = time.perf_counter()
        eng.run()
        wall = time.perf_counter() - t0
        generated = eng.stats.generated - warm_generated
        return reqs, generated / wall, wall

    s_reqs, s_rate, s_wall = timed(async_mode=False)
    a_reqs, a_rate, a_wall = timed(async_mode=True)
    assert all(a.out_tokens == b.out_tokens for a, b in zip(s_reqs, a_reqs)), \
        "async engine diverged from sync (greedy)"
    ratio = a_rate / s_rate
    print_fn("mode,batch,decode_tok_per_s,wall_s")
    print_fn(f"sync,{n_slots},{s_rate:.1f},{s_wall:.2f}")
    print_fn(f"async,{n_slots},{a_rate:.1f},{a_wall:.2f}")
    print_fn(f"# async dispatch-ahead speedup: {ratio:.2f}x "
             f"(on-device sampling, one-step dispatch-ahead)")
    if not smoke:
        assert ratio >= 1.15, (
            f"async decode speedup {ratio:.2f}x below the 1.15x floor at "
            f"batch {n_slots}"
        )
    return ratio


def main(print_fn=print, smoke: bool = False) -> dict:
    cfg = reduce_config("llama3.2-1b")
    model = build_model(cfg, Env())
    params = model.init(jax.random.key(0))
    lens = SMOKE_LENS if smoke else FULL_LENS
    # 4 slots in both sizes: at 2 slots the budget is chunk-dominated and
    # hybrid converges on decode-only's step counts exactly (boundary
    # packs are charged dispatches), washing out the gated TTFT margin
    n_slots = 4
    prompts = _workload(lens, cfg.vocab)

    print_fn(f"# scheduler bench: {len(prompts)} requests, "
             f"{len(set(lens))} distinct prompt lengths, {n_slots} slots, "
             f"prefill_chunk={CHUNK}")
    print_fn("mode,engine_steps,mean_ttft_steps,tokens_per_step,wall_s,tok_per_s")

    d_reqs, d_stats, _, d_wall = serve_mode(model, params, prompts, n_slots)
    _row("dense/decode-only", d_stats, d_wall, print_fn)
    h_reqs, h_stats, h_eng, h_wall = serve_mode(
        model, params, prompts, n_slots, schedule="hybrid"
    )
    _row("dense/hybrid", h_stats, h_wall, print_fn)

    assert all(a.out_tokens == b.out_tokens for a, b in zip(d_reqs, h_reqs)), \
        "hybrid diverged from decode-only (dense)"
    assert h_stats.mean_ttft_steps < d_stats.mean_ttft_steps, (
        f"hybrid TTFT {h_stats.mean_ttft_steps:.2f} not below decode-only "
        f"{d_stats.mean_ttft_steps:.2f}"
    )
    n_buckets = len(h_eng.sched.buckets)
    compiles = h_eng._fused._cache_size() + h_eng._solo._cache_size()
    assert compiles <= 2 * n_buckets, (compiles, n_buckets)
    print_fn(f"# hybrid jit programs: {compiles} "
             f"(bound 2 x {n_buckets} buckets) for {len(set(lens))} prompt lengths")

    p_reqs, p_stats, _, p_wall = serve_mode(
        model, params, prompts, n_slots,
        cache_kind="paged", block_size=8, schedule="hybrid",
    )
    _row("paged/hybrid", p_stats, p_wall, print_fn)
    assert all(a.out_tokens == b.out_tokens for a, b in zip(d_reqs, p_reqs)), \
        "hybrid diverged from decode-only (paged)"
    print_fn(f"# hybrid TTFT gain: "
             f"{d_stats.mean_ttft_steps / h_stats.mean_ttft_steps:.2f}x, "
             f"throughput gain: "
             f"{h_stats.tokens_per_step / d_stats.tokens_per_step:.2f}x (in steps)")

    print_fn(f"# hybrid TTFT percentiles (steps): "
             f"p50 {h_stats.ttft_p50_steps:.0f} p99 {h_stats.ttft_p99_steps:.0f}")

    print_fn("\n# sync vs async engine: decode-heavy workload, 8 slots")
    speedup = async_compare(model, params, print_fn, smoke)
    return {
        "tokens_per_step": h_stats.tokens_per_step,
        "mean_ttft_steps": h_stats.mean_ttft_steps,
        # exact percentiles over per-request samples; recorded in
        # BENCH_ci.json for the trajectory, not (yet) gated
        "ttft_p99_steps": h_stats.ttft_p99_steps,
        "per_token_p99_steps": h_stats.per_token_percentile(99),
        "async_speedup": speedup,
    }


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
