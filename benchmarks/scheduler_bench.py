"""Decode-only whole-prefill vs hybrid chunked-prefill scheduling.

The paper's co-processing keeps dense GEMMs and GEMV-shaped decode
attention busy at the same time; the serving-layer analogue is the
token-budget hybrid schedule (``serving/scheduler.py``), where a prefill
chunk rides each decode step instead of stalling the batch.  This bench
serves the same mixed-prompt-length workload under both schedules and
reports, per mode:

* ``engine_steps`` — fixed hybrid-batch units of work dispatched (a
  decode-only whole prefill of L tokens counts ceil(L / chunk) units);
* mean **TTFT** in engine steps (submit -> first token);
* **tokens/step** and wall-clock tokens/s;
* jit program counts — the hybrid path compiles at most one fused and
  one solo program per chunk bucket, no matter how many distinct prompt
  lengths arrive, while decode-only compiles one prefill per length.

Asserts greedy outputs are token-identical across schedules (dense and
paged) and that hybrid's mean TTFT beats decode-only's at mixed lengths.

``--smoke`` runs a down-sized workload for CI.
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from repro.configs.reduced import reduce_config
from repro.core.placement import Env
from repro.models.registry import build_model
from repro.serving.engine import Engine, Request

MAX_SEQ = 64
MAX_NEW = 8
CHUNK = 16

# >= 4 distinct prompt lengths in both sizes (the no-recompile claim)
SMOKE_LENS = [5, 12, 19, 26, 9, 23]
FULL_LENS = [5, 12, 19, 26, 30, 9, 16, 23, 7, 28, 11, 21, 14, 25, 6, 18]


def _workload(lens, vocab):
    rng = np.random.default_rng(0)
    return [rng.integers(1, vocab, size=n).astype(np.int32) for n in lens]


def serve_mode(model, params, prompts, n_slots, **kw):
    eng = Engine(model, params, n_slots=n_slots, max_seq=MAX_SEQ,
                 prefill_chunk=CHUNK, **kw)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=MAX_NEW)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    t0 = time.time()
    stats = eng.run()
    wall = time.time() - t0
    return reqs, stats, eng, wall


def _row(name, stats, wall, print_fn):
    print_fn(
        f"{name},{stats.engine_steps},{stats.mean_ttft_steps:.2f},"
        f"{stats.tokens_per_step:.3f},{wall:.2f},{stats.generated / wall:.1f}"
    )


def main(print_fn=print, smoke: bool = False):
    cfg = reduce_config("llama3.2-1b")
    model = build_model(cfg, Env())
    params = model.init(jax.random.key(0))
    lens = SMOKE_LENS if smoke else FULL_LENS
    n_slots = 2 if smoke else 4
    prompts = _workload(lens, cfg.vocab)

    print_fn(f"# scheduler bench: {len(prompts)} requests, "
             f"{len(set(lens))} distinct prompt lengths, {n_slots} slots, "
             f"prefill_chunk={CHUNK}")
    print_fn("mode,engine_steps,mean_ttft_steps,tokens_per_step,wall_s,tok_per_s")

    d_reqs, d_stats, _, d_wall = serve_mode(model, params, prompts, n_slots)
    _row("dense/decode-only", d_stats, d_wall, print_fn)
    h_reqs, h_stats, h_eng, h_wall = serve_mode(
        model, params, prompts, n_slots, schedule="hybrid"
    )
    _row("dense/hybrid", h_stats, h_wall, print_fn)

    assert all(a.out_tokens == b.out_tokens for a, b in zip(d_reqs, h_reqs)), \
        "hybrid diverged from decode-only (dense)"
    assert h_stats.mean_ttft_steps < d_stats.mean_ttft_steps, (
        f"hybrid TTFT {h_stats.mean_ttft_steps:.2f} not below decode-only "
        f"{d_stats.mean_ttft_steps:.2f}"
    )
    n_buckets = len(h_eng.sched.buckets)
    compiles = h_eng._fused._cache_size() + h_eng._solo._cache_size()
    assert compiles <= 2 * n_buckets, (compiles, n_buckets)
    print_fn(f"# hybrid jit programs: {compiles} "
             f"(bound 2 x {n_buckets} buckets) for {len(set(lens))} prompt lengths")

    p_reqs, p_stats, _, p_wall = serve_mode(
        model, params, prompts, n_slots,
        cache_kind="paged", block_size=8, schedule="hybrid",
    )
    _row("paged/hybrid", p_stats, p_wall, print_fn)
    assert all(a.out_tokens == b.out_tokens for a, b in zip(d_reqs, p_reqs)), \
        "hybrid diverged from decode-only (paged)"
    print_fn(f"# hybrid TTFT gain: "
             f"{d_stats.mean_ttft_steps / h_stats.mean_ttft_steps:.2f}x, "
             f"throughput gain: "
             f"{h_stats.tokens_per_step / d_stats.tokens_per_step:.2f}x (in steps)")


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
