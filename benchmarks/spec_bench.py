"""Speculative multi-token decoding: decode throughput vs spec depth.

Serves the same decode-heavy greedy workload through the async
dispatch-ahead engine at speculative depths 0 / 2 / 4 and reports, per
depth, engine steps, decode tokens per engine step, acceptance rate, and
wall clock.  The gained quantity is counted in **deterministic engine
steps** (dispatched device programs), the same machine-independent unit
the scheduler bench gates on: a depth-k verify window that accepts all
its drafts commits k+1 tokens against one dispatched step, so tokens per
engine step rises with the acceptance rate.

Two draft models are measured:

* **target-as-draft** (the draft *is* the target): every window accepts,
  the acceptance-rate ceiling.  ``spec_decode_gain`` — the gated metric
  — is depth-2 tokens/engine-step over depth-0 under this draft, the
  machinery's intrinsic step-count gain with proposal quality factored
  out.
* **mismatched draft** (same family, different init): proposals mostly
  miss, the honest floor.  Its acceptance rate rides along in the
  trajectory un-gated — with *trained* weights a reduced-scale draft
  lands between the two.

Wall-clock speedup additionally needs the draft's per-step cost to be
small next to the target's (the serve CLI's ``--draft`` default picks a
reduced-scale config for exactly that reason); at this bench's toy
scale both models cost the same, so wall times are reported but the
step-count gain is the claim.

Asserts greedy outputs are token-identical across all depths and both
drafts, and that the gated depth-2 gain clears the 1.2x floor.

``main`` returns a metrics dict (``spec_decode_gain``, per-depth
tokens/step, acceptance rates) consumed by ``benchmarks/ci_gate.py``.

``--smoke`` runs a down-sized workload for CI.
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from repro.configs.reduced import reduce_config
from repro.core.placement import Env
from repro.models.registry import build_model
from repro.serving.engine import Engine, Request

MAX_SEQ = 64
N_SLOTS = 8
GAIN_FLOOR = 1.2


def _workload(n_requests, vocab):
    rng = np.random.default_rng(2)
    lens = [int(rng.integers(4, 10)) for _ in range(n_requests)]
    return [rng.integers(1, vocab, size=n).astype(np.int32) for n in lens]


def serve_depth(model, params, prompts, max_new, depth, dmodel, dparams):
    kw = {}
    if depth:
        kw = dict(spec_depth=depth, draft_model=dmodel, draft_params=dparams)
    eng = Engine(model, params, n_slots=N_SLOTS, max_seq=MAX_SEQ, **kw)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    stats = eng.run()
    wall = time.perf_counter() - t0
    return reqs, stats, wall


def main(print_fn=print, smoke: bool = False) -> dict:
    cfg = reduce_config("llama3.2-1b")
    model = build_model(cfg, Env())
    params = model.init(jax.random.key(0))
    dmodel = build_model(cfg, Env())
    mismatched = dmodel.init(jax.random.key(1))

    max_new = 12 if smoke else 24
    prompts = _workload(8 if smoke else 16, cfg.vocab)
    print_fn(f"# spec bench: {len(prompts)} requests, max_new={max_new}, "
             f"{N_SLOTS} slots, async dispatch-ahead, greedy")
    print_fn("draft,depth,engine_steps,tok_per_step,accept_rate,wall_s")

    tps: dict[int, float] = {}
    accept: dict[int, float] = {}
    base_reqs = None
    for depth in (0, 2, 4):
        reqs, stats, wall = serve_depth(
            model, params, prompts, max_new, depth, dmodel, params
        )
        tps[depth] = stats.generated / stats.engine_steps
        accept[depth] = stats.acceptance_rate
        print_fn(f"target-as-draft,{depth},{stats.engine_steps},"
                 f"{tps[depth]:.3f},{accept[depth]:.2f},{wall:.2f}")
        if base_reqs is None:
            base_reqs = reqs
        else:
            assert all(a.out_tokens == b.out_tokens
                       for a, b in zip(base_reqs, reqs)), \
                f"depth {depth} diverged from non-speculative greedy"

    m_reqs, m_stats, m_wall = serve_depth(
        model, params, prompts, max_new, 2, dmodel, mismatched
    )
    m_tps = m_stats.generated / m_stats.engine_steps
    print_fn(f"mismatched,2,{m_stats.engine_steps},{m_tps:.3f},"
             f"{m_stats.acceptance_rate:.2f},{m_wall:.2f}")
    assert all(a.out_tokens == b.out_tokens
               for a, b in zip(base_reqs, m_reqs)), \
        "mismatched draft diverged from non-speculative greedy"

    gain = tps[2] / tps[0]
    print_fn(f"# spec_decode_gain (depth-2 vs depth-0, target-as-draft): "
             f"{gain:.2f}x in engine steps; depth-4: {tps[4] / tps[0]:.2f}x")
    print_fn(f"# acceptance: ceiling {accept[2]:.2f} "
             f"(target-as-draft), floor {m_stats.acceptance_rate:.2f} "
             f"(mismatched init)")
    assert accept[2] == 1.0, accept
    assert gain >= GAIN_FLOOR, (
        f"depth-2 decode gain {gain:.2f}x below the {GAIN_FLOOR}x floor"
    )
    return {
        "spec_decode_gain": gain,
        "spec_decode_gain_d4": tps[4] / tps[0],
        "spec_tokens_per_step_d0": tps[0],
        "spec_tokens_per_step_d2": tps[2],
        "spec_accept_rate_ceiling": accept[2],
        "spec_accept_rate_floor": m_stats.acceptance_rate,
    }


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
