"""Ablation: the paper's Fig. 7/8/9 sweep + the TPU-native analogue.

Part 1 reproduces the GPU/HPU analytical sweep (what the paper measured).
Part 2 compares KV placement policies for the TPU port using the balancer
(what the dry-run lowers), for each assigned architecture.

    PYTHONPATH=src python examples/offload_ablation.py
"""
from repro.configs import SHAPES, all_arch_ids, get_config
from repro.core import balance, oi
from repro.core.oi import DEVICES, LLAMA2_7B

print("== Part 1: paper sweep (Llama-2-7B, L40S + HPU prototypes) ==")
L40S, HPUP = DEVICES["L40S"], DEVICES["HPU-PROTO"]
base = oi.step_time_gpu_only(L40S, LLAMA2_7B, 16, 1536)
print(f"GPU-only@16: {16/base['total']:.0f} tok/s "
      f"(attention {base['attention']*1e3:.1f}ms of {base['total']*1e3:.1f}ms)")
for n in (1, 2, 4):
    t = oi.step_time_hetero(L40S, HPUP, LLAMA2_7B, 64, 1536, n_hpu=n)
    cap = n * oi.max_batch_per_hpu(HPUP, LLAMA2_7B, 1536)
    tag = "OOM" if 64 > cap else f"{64/t['total']:.0f} tok/s ({64/t['total']/(16/base['total']):.1f}x)"
    print(f"GPU+{n}HPU@64: {tag}")

print("\n== Part 2: TPU-native placement policies (decode_32k, 512 chips) ==")
axes = {"pod": 2, "data": 16, "model": 16}
print(f"{'arch':22s} {'policy':9s} {'shards':6s} {'t_att(ms)':9s} {'t_lin(ms)':9s} bottleneck")
for arch in all_arch_ids():
    cfg = get_config(arch)
    p = balance.plan(cfg, SHAPES["decode_32k"], axes)
    print(f"{arch:22s} {p.kv_policy:9s} {p.kv_shards:6d} "
          f"{p.t_attention*1e3:9.2f} {p.t_linear*1e3:9.2f} {p.bottleneck}")
