"""Quickstart: build a model, train a few steps, generate, checkpoint.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ParallelConfig, RunConfig, TrainConfig
from repro.configs.reduced import reduce_config
from repro.core.placement import Env
from repro.data.pipeline import DataConfig, host_batch
from repro.models.registry import build_model
from repro.training.trainer import make_train_step

# 1. pick an architecture (any of the 10 assigned ids) at smoke scale
cfg = reduce_config("llama3.2-1b")
model = build_model(cfg, Env())
print(f"model: {cfg.name}  params: {model.n_params():,}")

# 2. train a few steps on the synthetic pipeline
run = RunConfig(model=cfg, parallel=ParallelConfig(),
                train=TrainConfig(lr=3e-3, warmup_steps=2, total_steps=30))
init_state, train_step, _, _ = make_train_step(model, run)
state = init_state(jax.random.key(0))
dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
step = jax.jit(train_step, donate_argnums=(0,))
for i in range(30):
    batch = {k: jnp.asarray(v) for k, v in host_batch(dc, i, 0, 1).items()}
    state, metrics = step(state, batch)
    if i % 10 == 0:
        print(f"step {i:3d} loss {float(metrics['loss']):.4f}")

# 3. greedy generation with the KV cache
params = state["params"]
prompt = jnp.asarray(np.arange(1, 9, dtype=np.int32))[None]
cache = model.init_cache(1, 64)
logits, cache = jax.jit(model.prefill)(params, prompt, cache)
tok = jnp.argmax(logits, -1).astype(jnp.int32)
out = []
for _ in range(10):
    out.append(int(tok[0]))
    logits, cache = jax.jit(model.decode_step)(params, cache, tok)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
print("generated:", out)

# 4. checkpoint + exact restore
ck = Checkpointer("/tmp/repro_quickstart")
ck.save(30, state)
_, restored = ck.restore(jax.eval_shape(lambda: state))
ok = all(bool(jnp.array_equal(a, b))
         for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)))
print("checkpoint roundtrip exact:", ok)
