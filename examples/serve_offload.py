"""Serving with HPU-style offloaded decode + continuous batching.

Demonstrates the paper's system end to end: the balancer picks the KV
placement policy, the engine continuous-batches 12 requests through 4
decode slots, and decode attention runs through the offload layout.

    PYTHONPATH=src python examples/serve_offload.py
"""
import time

import jax
import numpy as np

from repro.configs import SHAPES
from repro.configs.reduced import reduce_config
from repro.core import balance
from repro.core.placement import Env
from repro.models.registry import build_model
from repro.serving.engine import Engine, Request
from repro.serving.sampler import SamplerConfig

cfg = reduce_config("yi-34b")   # GQA group 7 -> narrow-GEMM decode regime
axes = {"data": 1, "model": 1}  # single host; the dry-run exercises the pod
plan = balance.plan(cfg, SHAPES["decode_32k"], {"data": 16, "model": 16})
print(f"production plan for {cfg.name}: kv_policy={plan.kv_policy} "
      f"sub_batches={plan.sub_batches} bottleneck={plan.bottleneck} "
      f"kv_shards={plan.kv_shards}")

model = build_model(cfg, Env())  # CPU-local execution of the same code path
params = model.init(jax.random.key(0))
engine = Engine(model, params, n_slots=4, max_seq=48,
                sampler=SamplerConfig(), sub_batches=plan.sub_batches)

rng = np.random.default_rng(7)
for uid in range(12):
    prompt = rng.integers(1, cfg.vocab, size=int(rng.integers(4, 16))).astype(np.int32)
    engine.submit(Request(uid=uid, prompt=prompt, max_new_tokens=8))

t0 = time.time()
stats = engine.run()
print(f"prefills={stats.prefills} decode_steps={stats.decode_steps} "
      f"generated={stats.generated} peak_active={stats.peak_active} "
      f"({stats.generated/(time.time()-t0):.1f} tok/s on CPU)")
