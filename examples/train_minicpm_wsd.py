"""Train a reduced MiniCPM (MHA, WSD schedule) for a few hundred steps with
checkpoint/restart fault tolerance — the end-to-end training driver.

    PYTHONPATH=src python examples/train_minicpm_wsd.py
"""
import shutil

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ParallelConfig, RunConfig, TrainConfig
from repro.configs.reduced import reduce_config
from repro.core.placement import Env
from repro.data.pipeline import DataConfig, host_batch
from repro.distributed.fault_tolerance import Supervisor
from repro.models.registry import build_model
from repro.training.trainer import make_train_step

STEPS = 200
CKPT = "/tmp/repro_minicpm_wsd"
shutil.rmtree(CKPT, ignore_errors=True)

cfg = reduce_config("minicpm-2b")
model = build_model(cfg, Env())
run = RunConfig(
    model=cfg,
    parallel=ParallelConfig(grad_accum=2, grad_compression="int8"),
    train=TrainConfig(lr=3e-3, schedule="wsd", warmup_steps=10,
                      total_steps=STEPS, stable_frac=0.8),
)
init_state, train_step, _, _ = make_train_step(model, run)
dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=16)
ck = Checkpointer(CKPT, keep_n=2)
step_fn = jax.jit(train_step, donate_argnums=(0,))
crashed = {"done": False}


def run_fn(start):
    if start == 0:
        state = init_state(jax.random.key(0))
    else:
        tmpl = jax.eval_shape(init_state, jax.ShapeDtypeStruct((2,), jnp.uint32))
        _, state = ck.restore(tmpl, step=start)
        print(f"[recovered from checkpoint @ step {start}]")
    for i in range(start, STEPS):
        if i == 120 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("simulated node failure @ step 120")
        batch = {k: jnp.asarray(v) for k, v in host_batch(dc, i, 0, 1).items()}
        state, m = step_fn(state, batch)
        if (i + 1) % 50 == 0:
            ck.save(i + 1, state)
        if i % 25 == 0:
            print(f"step {i:4d} loss {float(m['loss']):.4f} lr {float(m['lr']):.2e}")
    return STEPS


sup = Supervisor(run_fn, ck.latest_step, max_restarts=2)
sup.run(0)
print(f"finished {STEPS} WSD steps with {sup.restarts} restart(s); "
      f"checkpoints kept: {ck.all_steps()}")
