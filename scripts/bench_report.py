"""Render the committed BENCH_<n>.json trajectory as a trend report.

  python scripts/bench_report.py [--root .] [--out bench_report.txt]
                                 [--drift-pct 25]

Each ``BENCH_<n>.json`` snapshot (written by ``benchmarks/run.py
--trajectory``) is one column; metrics are rows.  The report prints every
metric's trajectory oldest-to-newest and flags **drifts**: a metric whose
latest value moved more than ``--drift-pct`` percent from the previous
snapshot.  ``BENCH_ci.json`` (the reduced-shape CI baseline) is listed
separately — it is a different measurement shape, not a trajectory point.

This is a trend *report*, not a gate: CI uploads it as an artifact so a
reviewer can eyeball how the perf trajectory moved across PRs, while the
pass/fail bar stays ``benchmarks/ci_gate.py``.  Exits nonzero only when
no snapshots exist or a snapshot is unreadable.
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path


def load_snapshots(root: Path) -> list[tuple[int, dict]]:
    """(n, payload) for every BENCH_<n>.json under root, ordered by n."""
    snaps = []
    for p in sorted(root.glob("BENCH_*.json")):
        m = re.fullmatch(r"BENCH_(\d+)\.json", p.name)
        if not m:
            continue
        snaps.append((int(m.group(1)), json.loads(p.read_text())))
    snaps.sort(key=lambda t: t[0])
    return snaps


def flatten(payload: dict) -> dict[str, float]:
    """``{bench: {metric: value}}`` -> ``{"bench.metric": value}``."""
    flat: dict[str, float] = {}
    for bench, metrics in sorted(payload.items()):
        if not isinstance(metrics, dict):
            continue
        for name, value in sorted(metrics.items()):
            if isinstance(value, (int, float)):
                flat[f"{bench}.{name}"] = float(value)
    return flat


def drift(prev: float, cur: float) -> float:
    """Relative change in percent (0 when prev is 0 and cur is 0)."""
    if prev == 0.0:
        return 0.0 if cur == 0.0 else float("inf")
    return (cur - prev) / abs(prev) * 100.0


def render(snaps: list[tuple[int, dict]], drift_pct: float,
           ci: dict | None = None) -> str:
    """The full report: trend table + drift section (+ CI baseline)."""
    cols = [n for n, _ in snaps]
    flats = [flatten(payload) for _, payload in snaps]
    metrics = sorted(set().union(*flats)) if flats else []
    name_w = max((len(m) for m in metrics), default=6)
    lines = ["perf trajectory " +
             " -> ".join(f"BENCH_{n}" for n in cols), ""]
    header = f"{'metric':<{name_w}} " + " ".join(f"{f'#{n}':>10}"
                                                 for n in cols)
    lines += [header, "-" * len(header)]
    drifts: list[str] = []
    for m in metrics:
        cells = []
        for f in flats:
            v = f.get(m)
            cells.append(f"{v:>10.4g}" if v is not None else f"{'-':>10}")
        lines.append(f"{m:<{name_w}} " + " ".join(cells))
        have = [f[m] for f in flats if m in f]
        if len(have) >= 2:
            d = drift(have[-2], have[-1])
            if abs(d) > drift_pct:
                drifts.append(f"  {m}: {have[-2]:.4g} -> {have[-1]:.4g} "
                              f"({d:+.1f}%)")
    lines.append("")
    if drifts:
        lines.append(f"DRIFTS (> {drift_pct:g}% vs previous snapshot):")
        lines += drifts
    else:
        lines.append(f"no drifts > {drift_pct:g}% vs previous snapshot")
    if ci:
        lines += ["", "CI baseline (BENCH_ci.json, reduced shapes — not a "
                      "trajectory point):"]
        for m, v in sorted(flatten(ci).items()):
            lines.append(f"  {m} = {v:.4g}")
    return "\n".join(lines) + "\n"


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=".",
                    help="directory holding BENCH_<n>.json snapshots")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="also write the report here (the CI artifact)")
    ap.add_argument("--drift-pct", type=float, default=25.0,
                    help="flag metrics whose latest value moved more than "
                         "this percent from the previous snapshot")
    args = ap.parse_args(argv)
    root = Path(args.root)
    try:
        snaps = load_snapshots(root)
    except (OSError, ValueError) as e:
        print(f"cannot read trajectory under {root}: {e}", file=sys.stderr)
        return 1
    if not snaps:
        print(f"no BENCH_<n>.json snapshots under {root}", file=sys.stderr)
        return 1
    ci = None
    ci_path = root / "BENCH_ci.json"
    if ci_path.exists():
        ci = json.loads(ci_path.read_text())
    report = render(snaps, args.drift_pct, ci)
    print(report, end="")
    if args.out:
        Path(args.out).write_text(report)
        print(f"(written to {args.out})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
