"""CI docs checker: broken links, anchors, and stale code pointers.

  python scripts/check_docs.py [README.md docs/*.md ...]

Three checks over the repo's markdown (defaults: ``README.md`` and
``docs/*.md``):

* **links** — every relative markdown link ``[text](path)`` must point
  at a file or directory that exists (external ``http(s)://`` /
  ``mailto:`` links are not fetched);
* **anchors** — a link's ``#fragment`` must match a heading in the
  target file, using GitHub's heading-slug rules (lowercase, punctuation
  stripped, spaces to hyphens, ``-N`` suffixes for duplicates);
* **code pointers** — every backticked ``path.py:Symbol`` or
  ``path.py:Class.method`` reference must resolve: the file exists and
  defines the named class/function (``class Sym``/``def Sym`` scan, so a
  rename that orphans the docs fails CI instead of rotting).

Exits nonzero listing every problem; prints a per-file summary
otherwise.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# `src/repro/serving/engine.py:Engine.import_request` and friends
POINTER_RE = re.compile(
    r"`([\w./\-]+\.py):([A-Za-z_]\w*(?:\.[A-Za-z_]\w*)?)`"
)
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$")
CODE_FENCE_RE = re.compile(r"^(```|~~~)")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: strip markup, lowercase, drop punctuation,
    hyphenate spaces."""
    text = re.sub(r"[`*]", "", heading)
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)   # [text](url)
    text = text.strip().lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def heading_slugs(md_path: Path) -> set[str]:
    slugs: dict[str, int] = {}
    out: set[str] = set()
    in_fence = False
    for line in md_path.read_text().splitlines():
        if CODE_FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = HEADING_RE.match(line)
        if not m:
            continue
        slug = github_slug(m.group(2))
        n = slugs.get(slug, 0)
        slugs[slug] = n + 1
        out.add(slug if n == 0 else f"{slug}-{n}")
    return out


def _strip_fences(text: str) -> str:
    """Drop fenced code blocks (links/pointers inside are examples)."""
    out, in_fence = [], False
    for line in text.splitlines():
        if CODE_FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if not in_fence:
            out.append(line)
    return "\n".join(out)


def check_links(md_path: Path, text: str) -> list[str]:
    problems = []
    base = md_path.parent
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = target.partition("#")
        if path_part:
            dest = (base / path_part).resolve()
            if not dest.exists():
                problems.append(f"{md_path}: broken link -> {target}")
                continue
        else:
            dest = md_path                       # same-file #anchor
        if anchor:
            if dest.suffix.lower() not in (".md", ".markdown"):
                continue                         # e.g. file.py#L10
            if anchor not in heading_slugs(dest):
                problems.append(
                    f"{md_path}: broken anchor -> {target} "
                    f"(no heading slugs to '{anchor}' in {dest.name})"
                )
    return problems


def _defines(source: str, symbol: str) -> bool:
    parts = symbol.split(".")
    for i, part in enumerate(parts):
        kind = r"(?:class|def)" if i == 0 else r"def"
        if not re.search(rf"^\s*{kind}\s+{re.escape(part)}\b", source,
                         re.MULTILINE):
            return False
    return True


def check_pointers(md_path: Path, text: str) -> list[str]:
    problems = []
    for rel, symbol in POINTER_RE.findall(text):
        target = REPO / rel
        if not target.exists():
            problems.append(
                f"{md_path}: stale pointer `{rel}:{symbol}` (no such file)"
            )
            continue
        if not _defines(target.read_text(), symbol):
            problems.append(
                f"{md_path}: stale pointer `{rel}:{symbol}` "
                f"({symbol.split('.')[0]} not defined in {rel})"
            )
    return problems


def main(argv: list[str] | None = None) -> int:
    args = sys.argv[1:] if argv is None else argv
    files = ([Path(a) for a in args] if args
             else [REPO / "README.md", *sorted((REPO / "docs").glob("*.md"))])
    problems: list[str] = []
    for md in files:
        if not md.exists():
            problems.append(f"{md}: file not found")
            continue
        text = _strip_fences(md.read_text())
        link_p = check_links(md, text)
        ptr_p = check_pointers(md, text)
        problems += link_p + ptr_p
        n_links = len([t for t in LINK_RE.findall(text)
                       if not t.startswith(("http://", "https://"))])
        n_ptrs = len(POINTER_RE.findall(text))
        status = "FAIL" if (link_p or ptr_p) else "ok"
        print(f"{md.relative_to(REPO) if md.is_relative_to(REPO) else md}: "
              f"{n_links} links, {n_ptrs} code pointers [{status}]")
    if problems:
        print("\ndocs check FAILED:", file=sys.stderr)
        for p in problems:
            print(f"  {p}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
