"""CI trace smoke checker: assert a serve run produced a usable trace.

  PYTHONPATH=src python scripts/check_trace.py cluster_trace.json --replicas 2

Parses the Perfetto/Chrome-trace JSON a ``--trace`` serve run wrote,
runs it through :func:`repro.serving.telemetry.validate_trace`, and
asserts every expected replica contributed at least one **complete**
request span (a closed ``decode`` span whose request also has a
``finish`` marker) — the end-to-end guarantee the CI traced-serve smoke
gates on.  Exits nonzero with the problems printed otherwise.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict

from repro.serving.telemetry import validate_trace


def check(obj: dict, n_replicas: int, expect_spill_marks: bool = False,
          expect_migrate_marks: bool = False,
          expect_spec_marks: bool = False,
          expect_slo_marks: bool = False,
          expect_measured_counters: bool = False) -> list[str]:
    """Return problem strings (empty = the trace passes the smoke bar)."""
    problems = validate_trace(obj)
    if problems:
        return problems
    events = obj["traceEvents"]
    decodes: dict[int, set[int]] = defaultdict(set)   # replica -> uids
    finishes: dict[int, set[int]] = defaultdict(set)
    n_spills = 0
    n_migrates = 0
    n_proposes = 0
    n_verifies = 0
    n_slo = 0
    measured = {"measured_mfu": 0, "measured_mbu": 0, "achieved_gbps": 0}
    counter_ts: dict[tuple[int, str], float] = {}
    for e in events:
        args = e.get("args", {})
        if e["ph"] == "X" and e["name"].startswith("decode") and e["dur"] >= 0:
            decodes[e["pid"]].add(args.get("uid", -1))
        if e["ph"] == "i" and e["name"] == "finish":
            finishes[e["pid"]].add(args.get("uid", -1))
        if e["ph"] == "i" and e["name"] == "kv_spill":
            n_spills += 1
        if e["ph"] == "i" and e["name"] == "kv_migrate":
            n_migrates += 1
        if e["ph"] == "i" and e["name"] == "spec_propose":
            n_proposes += 1
        if e["ph"] == "i" and e["name"] == "spec_verify":
            n_verifies += 1
        if e["ph"] == "i" and e["name"] == "slo_breach":
            n_slo += 1
        if e["ph"] == "C":
            if e["name"] in measured:
                measured[e["name"]] += 1
            # counter tracks must advance monotonically in ts per
            # (pid, name) series or Perfetto draws garbage graphs
            key = (e["pid"], e["name"])
            prev = counter_ts.get(key)
            if prev is not None and e["ts"] < prev:
                problems.append(
                    f"counter {e['name']} pid={e['pid']}: ts regressed "
                    f"{prev} -> {e['ts']}"
                )
            counter_ts[key] = e["ts"]
    if expect_slo_marks and n_slo == 0:
        problems.append("no slo_breach marks (SLO smoke expected >= 1)")
    if expect_measured_counters:
        for name, n in measured.items():
            if n == 0:
                problems.append(
                    f"no {name} counter events (profiler smoke expected >= 1)"
                )
    if expect_spill_marks and n_spills == 0:
        problems.append("no kv_spill marks (host-tier smoke expected >= 1)")
    if expect_spec_marks and n_proposes == 0:
        problems.append(
            "no spec_propose marks (speculative smoke expected >= 1)"
        )
    if expect_spec_marks and n_verifies == 0:
        problems.append(
            "no spec_verify marks (speculative smoke expected >= 1)"
        )
    if expect_migrate_marks and n_migrates == 0:
        problems.append(
            "no kv_migrate marks (disaggregated smoke expected >= 1)"
        )
    if expect_migrate_marks:
        # disaggregated layout: prefill-role replicas hand every request
        # off before it finishes, so complete spans exist only globally
        all_complete = (set().union(*decodes.values()) if decodes else set()) \
            & (set().union(*finishes.values()) if finishes else set())
        if not all_complete:
            problems.append(
                "no complete request span on any replica "
                f"(decoded uids {sorted(set().union(*decodes.values()) if decodes else set())}, "
                f"finished uids {sorted(set().union(*finishes.values()) if finishes else set())})"
            )
        return problems
    for r in range(n_replicas):
        complete = decodes.get(r, set()) & finishes.get(r, set())
        if not complete:
            problems.append(
                f"replica {r}: no complete request span "
                f"(decoded uids {sorted(decodes.get(r, set()))}, "
                f"finished uids {sorted(finishes.get(r, set()))})"
            )
    return problems


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("trace", help="Chrome-trace JSON written by --trace")
    ap.add_argument("--replicas", type=int, default=1,
                    help="replica count that must each show a complete span")
    ap.add_argument("--expect-spill-marks", action="store_true",
                    help="require at least one kv_spill instant event "
                         "(the host-KV-tier serve smoke)")
    ap.add_argument("--expect-migrate-marks", action="store_true",
                    help="require at least one cluster-row kv_migrate "
                         "event (the disaggregated serve smoke); relaxes "
                         "the complete-span requirement from per-replica "
                         "to global, since prefill-role replicas migrate "
                         "requests away before they finish")
    ap.add_argument("--expect-spec-marks", action="store_true",
                    help="require at least one spec_propose and one "
                         "spec_verify instant event (the speculative "
                         "decoding serve smoke)")
    ap.add_argument("--expect-slo-marks", action="store_true",
                    help="require at least one slo_breach instant event "
                         "(the SLO-monitored workload serve smoke)")
    ap.add_argument("--expect-measured-counters", action="store_true",
                    help="require measured_mfu/measured_mbu/achieved_gbps "
                         "counter events (the sampled-profiler serve smoke)")
    args = ap.parse_args(argv)
    try:
        obj = json.loads(open(args.trace).read())
    except (OSError, ValueError) as e:
        print(f"cannot read trace {args.trace}: {e}", file=sys.stderr)
        return 1
    problems = check(obj, args.replicas, args.expect_spill_marks,
                     args.expect_migrate_marks, args.expect_spec_marks,
                     args.expect_slo_marks, args.expect_measured_counters)
    if problems:
        print(f"trace check FAILED for {args.trace}:", file=sys.stderr)
        for p in problems:
            print(f"  {p}", file=sys.stderr)
        return 1
    n_events = len(obj["traceEvents"])
    scope = ("cluster-wide" if args.expect_migrate_marks
             else f"{args.replicas} replica(s)")
    print(f"trace OK: {args.trace} ({n_events} events, "
          f"complete spans on {scope})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
