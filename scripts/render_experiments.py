"""Render the §Roofline summary table into EXPERIMENTS.md from results/dryrun.

    PYTHONPATH=src python scripts/render_experiments.py
"""
import glob
import json
import os
import re

from repro.analysis.roofline import recompute_cell

RESULTS = "results/dryrun"
TARGET = "EXPERIMENTS.md"
MARKER = "<!-- ROOFLINE_TABLE -->"


def recompute(c: dict) -> dict:
    return recompute_cell(c).as_dict()


def fmt(x, digits=3):
    if x >= 100:
        return f"{x:.0f}"
    if x >= 1:
        return f"{x:.2f}"
    return f"{x:.1e}".replace("e-0", "e-")


def main():
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        base = os.path.basename(path)[: -len(".json")]
        parts = base.split(".")
        # skip perf-variant tagged cells in the baseline table
        if any(p.startswith(("kv_", "off_", "sub", "sp", "bfc", "a2a",
                             "nofsdp", "ga", "kvq")) for p in parts[3:]):
            continue
        with open(path) as f:
            cells.append(json.load(f))

    lines = [
        "| arch | shape | mesh | chips | compute (s) | memory (s) | "
        "collective (s) | bottleneck | useful | frac | peak GiB/dev | policy |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    cells.sort(key=lambda c: (c["arch"], order.get(c["shape"], 9), c["mesh"]))
    for c in cells:
        r = recompute(c)
        pol = c["env"]["kv_policy"] if c["kind"] == "decode" else (
            "sp" if c["env"].get("sequence_parallel") else "-")
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | {c['n_chips']} | "
            f"{fmt(r['compute_s'])} | {fmt(r['memory_s'])} | "
            f"{fmt(r['collective_s'])} | {r['bottleneck']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_frac']:.3f} | "
            f"{c['memory']['peak_bytes_per_dev']/2**30:.1f} | {pol} |"
        )
    table = "\n".join(lines)

    with open(TARGET) as f:
        text = f.read()
    if MARKER in text:
        text = text.replace(MARKER, table)
    else:
        # replace a previously-rendered table (between the §Roofline header
        # sentinel lines) — idempotent re-render
        pat = re.compile(r"\| arch \| shape \| mesh \|.*?(?=\n\nObservations)", re.S)
        text = pat.sub(table, text)
    with open(TARGET, "w") as f:
        f.write(text)
    print(f"rendered {len(cells)} cells into {TARGET}")


if __name__ == "__main__":
    main()
