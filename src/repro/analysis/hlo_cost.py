"""HLO-text cost model with while-loop trip-count accounting.

``compiled.cost_analysis()`` counts a ``while`` body ONCE (verified in
this container), so for scan-over-layers models it understates FLOPs,
bytes, and — critically for §Roofline — collective bytes by ~n_layers x.
This walker parses the (optimized) HLO text, builds the computation call
graph, extracts static trip counts from while-condition constants, and
returns totals that weight each while body by its trip count:

  flops        2 * prod(out) * prod(contracting dims)  per dot
  bytes        operand + result bytes of top-level ops (fusion internals
               excluded: they live in registers/VMEM)
  collectives  operand bytes per all-gather / all-reduce / reduce-scatter
               / all-to-all / collective-permute, by kind

Validated against ``cost_analysis()`` on loop-free graphs in the tests.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(.*)$")
_CALLED_RE = re.compile(r"(?:calls|to_apply|body)=(%[\w\.\-]+)")
_COND_RE = re.compile(r"condition=(%[\w\.\-]+)")
_CONST_RE = re.compile(r"=\s*[su]32\[\]\s+constant\((\d+)\)")
_OPERAND_RE = re.compile(r"(%[\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    coll_count: int = 0

    def add(self, other: "Cost", times: float = 1.0):
        self.flops += other.flops * times
        self.bytes += other.bytes * times
        self.coll_bytes += other.coll_bytes * times
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] += v * times
        self.coll_count += int(other.coll_count * times)


def _shapes_bytes(text: str) -> float:
    """Sum bytes of every array shape literal in a type string (tuples ok)."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _parse_computations(hlo: str) -> dict[str, list[str]]:
    """computation name -> list of op lines."""
    comps: dict[str, list[str]] = {}
    cur: str | None = None
    for line in hlo.splitlines():
        stripped = line.strip()
        m = re.match(r"^(?:ENTRY\s+)?(%?[\w\.\-]+)\s*(?:\([^)]*\))?.*\{\s*$", stripped)
        if cur is None and m and ("->" in stripped or stripped.startswith("ENTRY")):
            name = m.group(1)
            if not name.startswith("%"):
                name = "%" + name
            cur = name
            comps[cur] = []
            continue
        if cur is not None:
            if stripped.startswith("}"):
                cur = None
                continue
            if stripped:
                comps[cur].append(stripped)
    return comps


def _entry_name(hlo: str, comps: dict[str, list[str]]) -> str:
    for line in hlo.splitlines():
        s = line.strip()
        if s.startswith("ENTRY"):
            m = re.match(r"^ENTRY\s+(%?[\w\.\-]+)", s)
            if m:
                name = m.group(1)
                return name if name.startswith("%") else "%" + name
    return next(iter(comps))


def _opcode_of(rhs: str) -> str:
    """rhs looks like 'f32[2,3]{1,0} dot(%a, %b), ...' or '(tuple...) while(...)'."""
    # strip the type (possibly a tuple type with nested parens/brackets)
    i = 0
    depth = 0
    n = len(rhs)
    # the type ends at the first space at depth 0 after any leading token
    while i < n:
        c = rhs[i]
        if c in "([{":
            depth += 1
        elif c in ")]}":
            depth -= 1
        elif c == " " and depth == 0:
            break
        i += 1
    rest = rhs[i:].strip()
    m = re.match(r"([\w\-]+)", rest)
    return m.group(1) if m else ""


def _trip_count(cond_lines: list[str]) -> int:
    consts = []
    for line in cond_lines:
        m = _CONST_RE.search(line)
        if m:
            consts.append(int(m.group(1)))
    if not consts:
        return 1
    return max(consts)


IN_PLACE_OPS = ("scatter", "dynamic-update-slice")


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps = _parse_computations(hlo_text)
        self.entry = _entry_name(hlo_text, self.comps)
        # per-computation symbol tables: %op -> type string
        self.symbols: dict[str, dict[str, str]] = {}
        for name, lines in self.comps.items():
            table = {}
            for line in lines:
                m = _DEF_RE.match(line)
                if m:
                    rhs = m.group(2)
                    # type = prefix up to opcode (see _opcode_of)
                    i, depth = 0, 0
                    while i < len(rhs):
                        c = rhs[i]
                        if c in "([{":
                            depth += 1
                        elif c in ")]}":
                            depth -= 1
                        elif c == " " and depth == 0:
                            break
                        i += 1
                    table[m.group(1)] = rhs[:i]
            self.symbols[name] = table
        self._memo: dict[tuple[str, bool], Cost] = {}

    # ----------------------------------------------------------- main walk
    def cost(self, comp: str | None = None, inside_fusion: bool = False,
             trips_ctx: int = 1) -> Cost:
        comp = comp or self.entry
        key = (comp, inside_fusion, trips_ctx)
        if key in self._memo:
            return self._memo[key]
        total = Cost()
        self._memo[key] = total  # guard cycles
        table = self.symbols.get(comp, {})
        for line in self.comps.get(comp, []):
            m = _DEF_RE.match(line)
            if not m:
                continue
            name, rhs = m.group(1), m.group(2)
            opcode = _opcode_of(rhs)
            out_type = table.get(name, "")
            args = self._operands(rhs, opcode)

            if opcode == "dot":
                total.flops += self._dot_flops(rhs, out_type, args, table)
                if not inside_fusion:
                    total.bytes += self._io_bytes(out_type, args, table)
            elif opcode in IN_PLACE_OPS:
                # XLA updates these in place (buffer aliasing): actual HBM
                # traffic is ~2x the update payload, not the whole buffer
                if not inside_fusion:
                    ops_bytes = sorted(
                        _shapes_bytes(table.get(a, "")) for a in args
                    )
                    total.bytes += 2.0 * sum(ops_bytes[:-1]) if ops_bytes else 0.0
            elif opcode in ("dynamic-slice", "gather"):
                # reads only the sliced region (~output bytes), not the
                # whole operand buffer
                if not inside_fusion:
                    total.bytes += 2.0 * _shapes_bytes(out_type)
            elif opcode in ("convolution",):
                # rare here; approximate as output-bytes only
                if not inside_fusion:
                    total.bytes += self._io_bytes(out_type, args, table)
            elif opcode == "while":
                body = _CALLED_RE.search(rhs)
                cond = _COND_RE.search(rhs)
                trips = 1
                if cond and cond.group(1) in self.comps:
                    trips = _trip_count(self.comps[cond.group(1)])
                if body and body.group(1) in self.comps:
                    total.add(
                        self.cost(body.group(1), inside_fusion, trips_ctx * trips),
                        trips,
                    )
            elif opcode == "fusion":
                called = _CALLED_RE.search(rhs)
                in_place = has_slice = False
                if called and called.group(1) in self.comps:
                    inner = self.cost(called.group(1), True, trips_ctx)
                    total.flops += inner.flops
                    total.coll_bytes += inner.coll_bytes
                    for k, v in inner.coll_by_kind.items():
                        total.coll_by_kind[k] += v
                    total.coll_count += inner.coll_count
                    inner_ops = {
                        _opcode_of(m2.group(2))
                        for l2 in self.comps[called.group(1)]
                        if (m2 := _DEF_RE.match(l2))
                    }
                    in_place = bool(inner_ops & set(IN_PLACE_OPS))
                    has_slice = bool(inner_ops & {"dynamic-slice", "gather"})
                    # XLA:CPU legalizes bf16 dots by materializing f32
                    # copies of the operands; a TPU MXU reads bf16 natively,
                    # so pure convert/layout fusions are counted free
                    # (documented in EXPERIMENTS.md §Roofline caveats).
                    if inner_ops <= {
                        "convert", "copy", "reshape", "transpose",
                        "broadcast", "bitcast", "parameter", "constant",
                    }:
                        continue
                if not inside_fusion:
                    out_b = _shapes_bytes(out_type)
                    op_bs = sorted(
                        (_shapes_bytes(table.get(a, "")) for a in args),
                        reverse=True,
                    )
                    if in_place:
                        # aliased in/out buffer: traffic ~ 2x update payload
                        b = 2.0 * sum(op_bs[1:])
                    elif has_slice:
                        # sliced reads touch ~(operand / loop-trips) of a
                        # stacked buffer per iteration (scan xs indexing),
                        # never less than the fusion output size
                        b = out_b + sum(
                            min(ob, max(out_b, ob / trips_ctx)) for ob in op_bs
                        )
                    else:
                        b = out_b + sum(op_bs)
                    total.bytes += b
            elif opcode in ("call", "conditional", "custom-call"):
                for c in _CALLED_RE.findall(rhs):
                    if c in self.comps:
                        total.add(self.cost(c, inside_fusion, trips_ctx))
                if not inside_fusion:
                    total.bytes += self._io_bytes(out_type, args, table)
            elif any(opcode.startswith(c) for c in COLLECTIVES):
                kind = next(c for c in COLLECTIVES if opcode.startswith(c))
                by = sum(_shapes_bytes(table.get(a, "")) for a in args)
                if by == 0.0:
                    by = _shapes_bytes(out_type)
                total.coll_bytes += by
                total.coll_by_kind[kind] += by
                total.coll_count += 1
                if not inside_fusion:
                    total.bytes += self._io_bytes(out_type, args, table)
            elif opcode in ("parameter", "constant", "get-tuple-element", "tuple",
                            "bitcast", "copy-start", "copy-done"):
                continue
            else:
                if not inside_fusion:
                    total.bytes += _shapes_bytes(out_type)
        return total

    # -------------------------------------------------------------- helpers
    @staticmethod
    def _operands(rhs: str, opcode: str) -> list[str]:
        i = rhs.find(opcode)
        if i < 0:
            return []
        j = rhs.find("(", i)
        if j < 0:
            return []
        depth = 0
        for k in range(j, len(rhs)):
            if rhs[k] == "(":
                depth += 1
            elif rhs[k] == ")":
                depth -= 1
                if depth == 0:
                    inner = rhs[j + 1 : k]
                    return _OPERAND_RE.findall(inner)
        return []

    def _io_bytes(self, out_type: str, args: list[str], table: dict[str, str]) -> float:
        b = _shapes_bytes(out_type)
        for a in args:
            b += _shapes_bytes(table.get(a, ""))
        return b

    def _dot_flops(self, rhs: str, out_type: str, args: list[str], table: dict) -> float:
        out_elems = 1.0
        shapes = _SHAPE_RE.findall(out_type)
        if shapes:
            dt, dims = shapes[0]
            if dims:
                for d in dims.split(","):
                    if d:
                        out_elems *= int(d)
        contract = 1.0
        m = _CONTRACT_RE.search(rhs)
        if m and args:
            lhs_type = table.get(args[0], "")
            lhs_shapes = _SHAPE_RE.findall(lhs_type)
            if lhs_shapes:
                _, dims = lhs_shapes[0]
                dim_list = [int(d) for d in dims.split(",") if d]
                for idx in m.group(1).split(","):
                    if idx and int(idx) < len(dim_list):
                        contract *= dim_list[int(idx)]
        return 2.0 * out_elems * contract


    # ------------------------------------------------------ attribution
    def attribute(self, top: int = 20) -> list[tuple[float, str, str]]:
        """Top byte-moving ops (walker rules), as (bytes, opcode, out_type).
        Used by the §Perf loop to find what to optimize next."""
        rows: list[tuple[float, str, str]] = []

        def walk(comp: str, weight: float, trips_ctx: int):
            table = self.symbols.get(comp, {})
            for line in self.comps.get(comp, []):
                m = _DEF_RE.match(line)
                if not m:
                    continue
                name, rhs = m.group(1), m.group(2)
                opcode = _opcode_of(rhs)
                out_type = table.get(name, "")
                args = self._operands(rhs, opcode)
                if opcode == "while":
                    body = _CALLED_RE.search(rhs)
                    cond = _COND_RE.search(rhs)
                    trips = 1
                    if cond and cond.group(1) in self.comps:
                        trips = _trip_count(self.comps[cond.group(1)])
                    if body and body.group(1) in self.comps:
                        walk(body.group(1), weight * trips, trips_ctx * trips)
                    continue
                if opcode in ("parameter", "constant", "get-tuple-element",
                              "tuple", "bitcast", "copy-start", "copy-done"):
                    continue
                b = 0.0
                if opcode in IN_PLACE_OPS:
                    ops_bytes = sorted(_shapes_bytes(table.get(a, "")) for a in args)
                    b = 2.0 * sum(ops_bytes[:-1]) if ops_bytes else 0.0
                elif opcode in ("dynamic-slice", "gather"):
                    b = 2.0 * _shapes_bytes(out_type)
                elif opcode == "fusion":
                    called = _CALLED_RE.search(rhs)
                    in_place = has_slice = False
                    if called and called.group(1) in self.comps:
                        inner_ops = {
                            _opcode_of(m2.group(2))
                            for l2 in self.comps[called.group(1)]
                            if (m2 := _DEF_RE.match(l2))
                        }
                        in_place = bool(inner_ops & set(IN_PLACE_OPS))
                        has_slice = bool(inner_ops & {"dynamic-slice", "gather"})
                        if inner_ops <= {"convert", "copy", "reshape", "transpose",
                                         "broadcast", "bitcast", "parameter", "constant"}:
                            continue
                    out_b = _shapes_bytes(out_type)
                    op_bs = sorted((_shapes_bytes(table.get(a, "")) for a in args), reverse=True)
                    if in_place:
                        b = 2.0 * sum(op_bs[1:])
                    elif has_slice:
                        b = out_b + sum(min(ob, max(out_b, ob / trips_ctx)) for ob in op_bs)
                    else:
                        b = out_b + sum(op_bs)
                else:
                    b = self._io_bytes(out_type, args, table) if opcode == "dot" else _shapes_bytes(out_type)
                if b:
                    rows.append((weight * b, opcode, out_type[:64]))

        walk(self.entry, 1.0, 1)
        rows.sort(reverse=True)
        return rows[:top]


def analyze(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).cost()
