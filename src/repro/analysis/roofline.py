"""Three-term roofline from a compiled dry-run artifact.

Per (arch x shape x mesh) cell:

  compute    = HLO_FLOPs_per_chip / peak_FLOPs_per_chip
  memory     = HLO_bytes_per_chip / HBM_bw_per_chip
  collective = wire_bytes_per_chip / ICI_bw_per_chip

The SPMD HLO module IS the per-chip program, so walker totals are already
per-chip (equivalently: total/chips).  Wire bytes apply ring factors:
all-reduce moves ~2x its operand bytes per chip, the others ~1x.

MODEL_FLOPS (analytic "useful" FLOPs) uses 6·N·D for training (N = active
params for MoE) and 2·N_active per generated token for decode, plus the
attention term; the ratio MODEL_FLOPS / (HLO_FLOPs_per_chip * chips)
exposes remat/padding/redundancy waste.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.balance import _active_params, kv_bytes_per_seq
from repro.core.oi import DEVICES

V5E = DEVICES["TPU-V5E"]
WIRE_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


@dataclasses.dataclass(frozen=True)
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    hlo_flops_total: float
    useful_ratio: float
    step_s: float            # max of the three terms (perfect overlap bound)
    roofline_frac: float     # useful compute time / bound step time

    def as_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


def wire_bytes(coll_by_kind: dict[str, float]) -> float:
    return sum(WIRE_FACTOR.get(k, 1.0) * v for k, v in coll_by_kind.items())


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Analytic useful FLOPs for one step of this cell (global)."""
    n_active = _active_params(cfg)
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens = B * S
        flops = 6.0 * n_active * tokens
        # causal attention: fwd 2*2*L*H*Dh*S^2/2 per seq; x3 for bwd
        if cfg.family not in ("rwkv6",):
            Dh = cfg.resolved_head_dim()
            flops += 3.0 * B * 2 * 2 * cfg.n_layers * cfg.n_heads * Dh * S * S / 2
        return flops
    if shape.kind == "prefill":
        tokens = B * S
        flops = 2.0 * n_active * tokens
        if cfg.family not in ("rwkv6",):
            Dh = cfg.resolved_head_dim()
            flops += B * 2 * 2 * cfg.n_layers * cfg.n_heads * Dh * S * S / 2
        return flops
    # decode: one token per sequence vs full cache
    flops = 2.0 * n_active * B
    if cfg.family == "rwkv6":
        H = cfg.d_model // cfg.rwkv.head_dim
        flops += B * cfg.n_layers * H * cfg.rwkv.head_dim**2 * 6
    else:
        Dh = cfg.resolved_head_dim()
        flops += B * 2 * 2 * cfg.n_layers * cfg.n_heads * Dh * S
    return flops


def dispatch_flops_bytes(
    cfg: ModelConfig,
    n_decode: int,
    kv_tokens: int,
    prefill_tokens: int = 0,
    prefill_ctx_tokens: int = 0,
    n_params: float | None = None,
) -> tuple[float, float]:
    """Analytic FLOPs and HBM bytes for ONE fused serving dispatch.

    This is the live-timeline counterpart of :func:`model_flops` /
    :func:`model_bytes`: the serving engine's step timeline
    (``serving/telemetry/timeline.py``) calls it per dispatch so each
    step's operational intensity ties back to the same Fig-1 roofline
    accounting the offline analysis uses.

    * ``n_decode`` — decode lanes in the batch (one token each);
    * ``kv_tokens`` — total KV positions the decode lanes attend over
      (sum of per-lane context lengths);
    * ``prefill_tokens`` — real tokens in the fused prefill chunk(s);
    * ``prefill_ctx_tokens`` — total context positions the chunk's
      queries attend over (``sum_i (start + i)`` for a causal chunk at
      offset ``start``).

    FLOPs: every token (decode or prefill) streams the active linear
    params once (``2 * N_active`` per token), plus the attention term
    ``2 * 2 * L * H * Dh`` per attended position (QK^T and PV).  Bytes:
    the weight stream is read **once per dispatch** — that shared read
    is exactly the paper's co-processing win, prefill GEMMs riding the
    decode weight stream — plus per-position KV reads, per-token KV
    writes, and one activation write+read per layer.
    """
    n_active = _active_params(cfg)
    n_params = n_active if n_params is None else n_params
    Dh = cfg.resolved_head_dim()
    tokens = n_decode + prefill_tokens
    attended = kv_tokens + prefill_ctx_tokens
    flops = 2.0 * n_active * tokens
    flops += 2.0 * 2.0 * cfg.n_layers * cfg.n_heads * Dh * attended
    kv_tok = kv_bytes_per_seq(cfg, 1)
    bytes_ = 2.0 * n_params                      # bf16 weight stream, once
    bytes_ += kv_tok * attended                  # KV reads (decode + chunk)
    bytes_ += kv_tok * tokens                    # KV writes
    bytes_ += 2.0 * tokens * cfg.d_model * cfg.n_layers * 2.0
    return flops, bytes_


def model_bytes(
    cfg: ModelConfig,
    shape: ShapeConfig,
    n_params: float,
    n_chips: int = 256,
    model_shards: int = 16,
) -> float:
    """Analytic minimal *per-chip* HBM traffic per step x n_chips.

    Layout-aware: in the serving layout weights are sharded over `model`
    but replicated over `data`, so each chip must read params/model_shards
    per step regardless of batch — the reachable floor, not 6N/B idealism.
    Training (FSDP) shards weights over all chips.
    """
    B, S = shape.global_batch, shape.seq_len
    p_bytes = n_params * 2.0
    act = 2.0 * B * S * cfg.d_model * cfg.n_layers * 2.0  # write+read once/layer
    if shape.kind == "train":
        # params read (fwd+bwd) + grad write + adam m,v read/write (fp32):
        # fully sharded (FSDP) -> global count
        return 3.0 * p_bytes + 16.0 * n_params + 2.0 * act
    per_chip_weights = p_bytes / max(model_shards, 1)
    if shape.kind == "prefill":
        cache_w = kv_bytes_per_seq(cfg, S) * B
        return per_chip_weights * n_chips + act + cache_w
    # decode: per-chip weight-shard read + sharded cache read
    return per_chip_weights * n_chips + kv_bytes_per_seq(cfg, S) * B


def model_wire_bytes(cfg: ModelConfig, shape: ShapeConfig, n_params: float) -> float:
    """Analytic minimal global interconnect traffic per step."""
    B = shape.global_batch
    if shape.kind == "train":
        return 4.0 * n_params  # ring all-reduce of bf16 grads ~ 2 x 2 bytes
    Dh = cfg.resolved_head_dim()
    return (
        cfg.n_layers * B * (2 * cfg.n_heads + 2 * cfg.n_kv_heads) * Dh * 2.0
    )  # paper's boundary Q/KV/out vectors


def roofline(
    cfg: ModelConfig,
    shape: ShapeConfig,
    n_chips: int,
    flops_per_chip: float,
    bytes_per_chip: float,
    coll_by_kind: dict[str, float],
    n_params: float | None = None,
    dev=V5E,
    weight_shards: int | None = None,
) -> Roofline:
    compute_s = flops_per_chip / dev.flops
    memory_s = bytes_per_chip / dev.bw
    collective_s = wire_bytes(coll_by_kind) / dev.net
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_total = flops_per_chip * n_chips
    useful = mf / hlo_total if hlo_total else 0.0
    step = max(terms.values())
    n_params = n_params if n_params is not None else _active_params(cfg)
    if weight_shards is not None:
        model_shards = weight_shards
    else:
        model_shards = 16 if n_chips >= 256 else max(n_chips // 16, 1)
    useful_times = {
        "compute": mf / (n_chips * dev.flops),
        "memory": model_bytes(cfg, shape, n_params, n_chips, model_shards)
        / (n_chips * dev.bw),
        "collective": model_wire_bytes(cfg, shape, n_params) / (n_chips * dev.net),
    }
    return Roofline(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops=mf,
        hlo_flops_total=hlo_total,
        useful_ratio=useful,
        step_s=step,
        roofline_frac=useful_times[bottleneck] / step if step else 0.0,
    )


def recompute_cell(cell: dict) -> Roofline:
    """Re-derive a dry-run JSON cell's roofline with layout-correct weight
    shards (wide-EP cells shard expert weights over all chips)."""
    from repro.configs import SHAPES, get_config

    cfg = get_config(cell["arch"])
    shape = SHAPES[cell["shape"]]
    w = cell["walker"]
    n = cell["n_chips"]
    ws = n if cell["env"].get("ep_wide") else (16 if n >= 256 else max(n // 16, 1))
    return roofline(
        cfg, shape, n, w["flops_per_dev"], w["bytes_per_dev"],
        w["coll_by_kind"], n_params=cell["n_params"], weight_shards=ws,
    )
