"""Fault-tolerant checkpointing (no orbax in this container).

Design (multi-host ready, exercised single-host here):
  * step-atomic: write into ``<dir>/tmp.<step>/``, fsync, then
    ``os.rename`` to ``step_<N>`` — a crash never leaves a readable
    half-checkpoint.
  * manifest.json records the flattened tree structure, dtypes, shapes,
    mesh metadata, and step, so restore can re-shard onto a *different*
    mesh/device count (elastic restart).
  * async: ``save(..., blocking=False)`` snapshots to host memory
    (device_get) and writes on a daemon thread; ``wait()`` joins.
  * keep_n garbage collection.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import ml_dtypes
import numpy as np

Pytree = Any

_SEP = "/"

# numpy's npz cannot round-trip ml_dtypes (bf16/fp8); store as uint views
_EXOTIC: dict[np.dtype, np.dtype] = {
    np.dtype(ml_dtypes.bfloat16): np.dtype(np.uint16),
    np.dtype(ml_dtypes.float8_e4m3fn): np.dtype(np.uint8),
    np.dtype(ml_dtypes.float8_e5m2): np.dtype(np.uint8),
}


def _flatten(tree: Pytree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        arr = np.asarray(leaf)
        if arr.dtype in _EXOTIC:
            arr = arr.view(_EXOTIC[arr.dtype])
        flat[key] = arr
    return flat


def _unflatten_into(template: Pytree, flat: dict[str, np.ndarray]) -> Pytree:
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, tmpl in paths:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = flat[key]
        want = np.dtype(tmpl.dtype) if hasattr(tmpl, "dtype") else arr.dtype
        if want in _EXOTIC and arr.dtype == _EXOTIC[want]:
            arr = arr.view(want)
        leaves.append(arr.astype(want, copy=False))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class Checkpointer:
    def __init__(self, directory: str, keep_n: int = 3):
        self.dir = directory
        self.keep_n = keep_n
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Pytree, meta: dict | None = None, blocking: bool = True):
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        if blocking:
            self._write(step, host_state, meta or {})
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_state, meta or {}), daemon=True
            )
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_state: Pytree, meta: dict):
        tmp = os.path.join(self.dir, f"tmp.{step}")
        final = os.path.join(self.dir, f"step_{step:010d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(host_state)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "keys": sorted(flat),
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "meta": meta,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep_n]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                if os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        template: Pytree,
        step: int | None = None,
        shardings: Pytree | None = None,
    ) -> tuple[int, Pytree]:
        """Restore into the structure of ``template``.  If ``shardings``
        (NamedSharding pytree) is given, leaves are placed sharded — this
        is the elastic-restart path (the new mesh may differ from the one
        that saved)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:010d}")
        with np.load(os.path.join(path, "arrays.npz")) as npz:
            flat = {k: npz[k] for k in npz.files}
        state = _unflatten_into(template, flat)
        if shardings is not None:
            state = jax.tree.map(
                lambda arr, sh: jax.device_put(arr, sh), state, shardings
            )
        return step, state

    def manifest(self, step: int) -> dict:
        with open(os.path.join(self.dir, f"step_{step:010d}", "manifest.json")) as f:
            return json.load(f)
