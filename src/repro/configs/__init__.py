"""Architecture registry: ``--arch <id>`` -> ModelConfig.

Arch ids accept dashes or underscores or dots interchangeably.
"""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401  (re-exported)
    DEEPSEEK,
    DENSE,
    ENCDEC,
    FAMILIES,
    MOE,
    RWKV6,
    SHAPES,
    ZAMBA2,
    HybridConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    ParallelConfig,
    RunConfig,
    RWKVConfig,
    ShapeConfig,
    SSMConfig,
    TrainConfig,
    shapes_for,
)

# arch id -> module name under repro.configs
ARCHS: dict[str, str] = {
    "yi-34b": "yi_34b",
    "llama3.2-1b": "llama3_2_1b",
    "llama3.2-3b": "llama3_2_3b",
    "minicpm-2b": "minicpm_2b",
    "rwkv6-7b": "rwkv6_7b",
    "zamba2-1.2b": "zamba2_1p2b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "internvl2-76b": "internvl2_76b",
    "seamless-m4t-medium": "seamless_m4t_medium",
}


def _canon(arch: str) -> str:
    key = arch.strip().lower().replace("_", "-")
    for k in ARCHS:
        if key == k or key == k.replace(".", "-") or key.replace("-", "") == k.replace(
            ".", ""
        ).replace("-", ""):
            return k
    raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{ARCHS[_canon(arch)]}")
    return mod.CONFIG


def all_arch_ids() -> list[str]:
    return list(ARCHS)
