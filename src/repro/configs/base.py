"""Config system.

Plain dataclasses (no external deps).  One ``ModelConfig`` instance per
assigned architecture lives in ``repro/configs/<arch>.py``; the registry in
``repro/configs/__init__.py`` resolves ``--arch <id>`` names (dashes or
underscores) to configs.

Shape sets (same four for every LM arch, per the brief):

    train_4k     seq 4096   global_batch 256   -> train_step
    prefill_32k  seq 32768  global_batch 32    -> prefill (serve)
    decode_32k   seq 32768  global_batch 128   -> serve_step (1 new token)
    long_500k    seq 524288 global_batch 1     -> serve_step (sub-quadratic only)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any

# ---------------------------------------------------------------------------
# model families
# ---------------------------------------------------------------------------
DENSE = "dense"        # llama-style decoder (yi, llama3.2, minicpm, internvl backbone)
MOE = "moe"            # moonshot (GQA + MoE FFN)
DEEPSEEK = "deepseek"  # deepseek-v3: MLA + MoE + MTP
RWKV6 = "rwkv6"        # attention-free
ZAMBA2 = "zamba2"      # mamba2 hybrid + shared attention blocks
ENCDEC = "encdec"      # seamless-m4t backbone

FAMILIES = (DENSE, MOE, DEEPSEEK, RWKV6, ZAMBA2, ENCDEC)


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0              # routed experts
    top_k: int = 0
    n_shared: int = 0               # shared (always-on) experts
    d_expert: int = 0               # per-expert FFN hidden dim
    router_aux_coef: float = 0.001  # load-balance aux loss
    router_dtype: str = "float32"
    capacity_factor: float = 1.25   # dropping MoE capacity (tests may raise)
    # deepseek-v3 style bias-based aux-free balancing knob (kept simple):
    score_func: str = "softmax"     # softmax | sigmoid (dsv3 uses sigmoid)
    moe_layer_start: int = 0        # dense layers before MoE starts (dsv3: 3)


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64        # N
    d_head: int = 64         # P (mamba2 head dim)
    n_groups: int = 1        # B/C groups
    d_conv: int = 4
    chunk: int = 128         # chunked-scan block length
    expand: int = 2          # d_inner = expand * d_model


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64     # rank of data-dependent decay LoRA
    mix_lora: int = 32       # rank of token-shift mix LoRA


@dataclass(frozen=True)
class HybridConfig:
    shared_block_period: int = 6   # a shared attention block every N mamba blocks
    lora_rank: int = 8             # per-slot LoRA on the shared block
    concat_input: bool = True      # zamba: shared block sees [x, x_embed0]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                  # 0 -> d_model // n_heads
    max_seq: int = 4096                # RoPE base table length (extended at runtime)
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # encoder-decoder (seamless)
    n_enc_layers: int = 0
    # multimodal stub frontends (internvl patches / seamless frames)
    frontend: str = "none"             # none | patches | frames
    frontend_len: int = 0              # stub embedding sequence length
    # sub-family extras
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    rwkv: RWKVConfig | None = None
    hybrid: HybridConfig | None = None
    mtp_depth: int = 0                 # deepseek multi-token-prediction heads
    # numerics
    dtype: str = "bfloat16"
    kv_quant: bool = False             # int8 KV cache (dense family): 2x capacity
    # applicability of the paper's technique (DESIGN.md §4)
    attention_offload: bool = True     # False for attention-free archs
    subquadratic: bool = False         # True -> runs long_500k

    @property
    def kv_group(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def padded_vocab(self, multiple: int = 256) -> int:
        """Vocab padded to a mesh-shardable multiple (pad logits are masked
        to -inf at unembed; pad rows are never looked up).  Without this,
        odd vocabs (minicpm 122753, seamless 256206) replicate the
        embedding table AND the fp32 logits across the model axis."""
        return -(-self.vocab // multiple) * multiple

    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def with_overrides(self, **kw: Any) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# input shapes
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shapes_for(cfg: ModelConfig) -> list[ShapeConfig]:
    """The shape cells that apply to an architecture (brief rules)."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.subquadratic:
        out.append(SHAPES["long_500k"])
    return out


# ---------------------------------------------------------------------------
# run / parallelism config
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ParallelConfig:
    # mesh axis sizes are owned by launch/mesh.py; these are policies.
    kv_policy: str = "batch"        # "batch" | "head"   (paper Fig. 4)
    offload: str = "hpu"            # "hpu" (disaggregated) | "none" (baseline)
    sub_batches: int = 2            # sub-batch pipelining factor (paper Fig. 3)
    sequence_parallel: bool = False # beyond-paper: SP for train/prefill
    zero_stage: int = 1             # 0: replicated opt state, 1: sharded over data
    remat: str = "block"            # "none" | "block" | "full"
    grad_accum: int = 1
    grad_compression: str = "none"  # "none" | "int8"
    grad_accum_dtype: str = "float32"  # accumulator/wire dtype ("bfloat16" halves AR bytes)
    optimizer_dtype: str = "float32"  # adam moments dtype ("bfloat16" for huge models)


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    schedule: str = "cosine"   # "cosine" | "wsd" (minicpm) | "const"
    warmup_steps: int = 100
    total_steps: int = 1000
    stable_frac: float = 0.8   # WSD stable phase fraction


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    train: TrainConfig = field(default_factory=TrainConfig)
