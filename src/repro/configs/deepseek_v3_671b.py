"""deepseek-v3-671b — MLA + MoE(1 shared + 256 routed, top-8) + MTP
[arXiv:2412.19437].

Brief's d_ff=2048 is the per-expert intermediate dim; the first
``moe_layer_start`` layers are dense with d_ff = d_expert*(top_k+n_shared)
= 18432 (matches the DeepSeek-V3 paper).  The offloaded decode cache is the
compressed latent (kv_lora 512 + rope 64) using the absorbed formulation.
"""
from repro.configs.base import DEEPSEEK, MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family=DEEPSEEK,
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,       # MLA: all heads read the shared latent cache
    d_ff=18432,           # dense-layer FFN dim (= 2048 * 9)
    vocab=129280,
    head_dim=128,         # v head dim; qk dims come from MLAConfig
    rope_theta=10_000.0,
    moe=MoEConfig(
        n_experts=256,
        top_k=8,
        n_shared=1,
        d_expert=2048,
        score_func="sigmoid",
        moe_layer_start=3,
    ),
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    mtp_depth=1,
)
