"""internvl2-76b — InternViT + LM backbone (llama3-70b-like) [arXiv:2404.16821].

Per the brief, only the transformer BACKBONE is modeled; the InternViT
frontend is a stub — ``launch/specs.py`` provides precomputed patch
embeddings of length ``frontend_len`` which are prepended to the token
embeddings.
"""
from repro.configs.base import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family=DENSE,
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    head_dim=128,
    rope_theta=500_000.0,
    frontend="patches",
    frontend_len=256,
)
