"""minicpm-2b — WSD schedule, llama-like arch [arXiv:2404.06395].

kv=36 == n_heads -> MHA: the paper's own prototype regime (group=1, pure
GEMV attention, OI ~ 1).  Trained with the WSD schedule, which is
implemented in ``repro.training.optimizer``.
"""
from repro.configs.base import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family=DENSE,
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab=122753,
    rope_theta=10_000.0,
    tie_embeddings=True,
)
