"""moonshot-v1-16b-a3b — kimi/moonlight MoE, 64 experts top-6
[hf:moonshotai/Moonlight-16B-A3B].

Brief lists GQA 16H kv=16 and expert dim 1408; we add the Moonlight shared
experts (2) and a single leading dense layer with
d_ff = d_expert*(top_k+n_shared) = 11264.
"""
from repro.configs.base import MOE, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family=MOE,
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=11264,           # dense-layer FFN dim (= 1408 * 8)
    vocab=163840,
    head_dim=128,
    rope_theta=50_000.0,
    moe=MoEConfig(
        n_experts=64,
        top_k=6,
        n_shared=2,
        d_expert=1408,
        score_func="sigmoid",
        moe_layer_start=1,
    ),
)
