"""Reduced (smoke-test scale) variants of every assigned architecture.

Same family/topology, tiny dims: the smoke tests instantiate these on CPU
and run a real forward/train/decode step; the FULL configs are only ever
lowered via ShapeDtypeStructs in the dry-run.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.configs.base import (
    HybridConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    RWKVConfig,
    SSMConfig,
)


def reduce_config(arch: str, vocab: int = 512) -> ModelConfig:
    cfg = get_config(arch)
    kw = dict(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=128,
        vocab=vocab,
        head_dim=16,
        frontend_len=8 if cfg.frontend != "none" else 0,
    )
    if cfg.family == "encdec":
        kw["n_enc_layers"] = 2
    if cfg.moe is not None:
        kw["moe"] = MoEConfig(
            n_experts=8,
            top_k=2,
            n_shared=cfg.moe.n_shared and 1,
            d_expert=32,
            score_func=cfg.moe.score_func,
            moe_layer_start=1,
            capacity_factor=2.0,
        )
        kw["n_layers"] = 3
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(
            q_lora_rank=48, kv_lora_rank=32,
            qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
        )
    if cfg.rwkv is not None:
        kw["rwkv"] = RWKVConfig(head_dim=16, decay_lora=8, mix_lora=8)
        kw["n_kv_heads"] = 4
    if cfg.ssm is not None:
        kw["ssm"] = SSMConfig(d_state=8, d_head=8, n_groups=1, d_conv=4, chunk=8, expand=2)
        kw["n_kv_heads"] = 4
        kw["n_layers"] = 5
    if cfg.hybrid is not None:
        kw["hybrid"] = HybridConfig(shared_block_period=2, lora_rank=4)
    if cfg.mtp_depth:
        kw["mtp_depth"] = 1
    return cfg.with_overrides(**kw)
