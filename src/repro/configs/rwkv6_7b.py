"""rwkv6-7b — "Finch", attention-free, data-dependent decay [arXiv:2404.05892].

No KV cache / SDPA, so the paper's technique is inapplicable in original
form (DESIGN.md §4); the WKV state recurrence is handled by the generalized
memory-bound-offload path.  Sub-quadratic -> runs long_500k.
"""
from repro.configs.base import RWKV6, ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family=RWKV6,
    n_layers=32,
    d_model=4096,
    n_heads=64,           # 4096 / head_dim 64
    n_kv_heads=64,
    d_ff=14336,
    vocab=65536,
    head_dim=64,
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, mix_lora=32),
    attention_offload=False,
    subquadratic=True,
)
