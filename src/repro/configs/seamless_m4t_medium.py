"""seamless-m4t-medium — enc-dec multimodal backbone [arXiv:2308.11596].

12 encoder + 12 decoder layers on the text/unit backbone.  The speech
frontend is a stub: ``launch/specs.py`` provides precomputed frame
embeddings (B, frontend_len, d_model) as encoder input.  Decoder
self-attention KV is offloaded per the paper; cross-attention KV is static
after encode (write-once/read-every-step — the ideal offload case,
DESIGN.md §4).
"""
from repro.configs.base import ENCDEC, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family=ENCDEC,
    n_layers=12,           # decoder layers
    n_enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    head_dim=64,
    rope_theta=10_000.0,
    frontend="frames",
    frontend_len=512,
)
