"""zamba2-1.2b — Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

38 mamba2 blocks; one *shared* (weight-tied) attention+MLP block is invoked
every ``shared_block_period`` layers with per-slot LoRA deltas, seeing
[x, x_embed] concatenated (d_model*2 -> d_model per the Zamba design).
Hybrid -> runs long_500k; the shared attention blocks carry ordinary KV
caches and are offloaded per the paper.
"""
from repro.configs.base import ZAMBA2, HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family=ZAMBA2,
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    head_dim=64,          # shared attention block head dim (2048*2/64H)
    rope_theta=10_000.0,
    ssm=SSMConfig(d_state=64, d_head=64, n_groups=1, d_conv=4, chunk=128, expand=2),
    hybrid=HybridConfig(shared_block_period=6, lora_rank=8, concat_input=True),
    subquadratic=True,
)
