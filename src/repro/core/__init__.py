"""The paper's contribution as composable JAX modules.

  oi         - operational-intensity & perf model (paper §III, Table I)
  placement  - KV partitioning policies (paper Fig. 4) + sharding rules
  offload    - disaggregated decode attention (GPU-HPU split as layouts)
  pipeline   - staggered sub-batch pipelining (paper Fig. 3)
  balance    - attention/linear load balancing (paper §IV-C)
"""
from repro.core import balance, offload, oi, pipeline, placement  # noqa: F401
from repro.core.placement import Env  # noqa: F401
