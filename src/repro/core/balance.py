"""GPU/HPU-analogue load balancing (paper §IV-C).

The paper tunes batch size / sequence mix so HPU attention time matches
GPU linear time.  On the TPU mesh the knobs are: the KV placement policy
(how many chips' HBM serve the attention GEMV), the number of pipelined
sub-batches, and batch-per-chip.  ``plan()`` does the napkin math from the
hardware constants and returns the chosen configuration plus expected
stage times, so launch scripts and the serving engine can self-configure.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.oi import BYTES_PER_EL, DEVICES, Device
from repro.core.placement import kv_rules, lanes
from repro.models.common import resolve_spec


@dataclasses.dataclass(frozen=True)
class Plan:
    kv_policy: str
    sub_batches: int
    t_linear: float          # s per decode step, compute side
    t_attention: float       # s per decode step, HPU-layout side
    t_boundary: float        # s, Q/KV boundary collective
    bottleneck: str
    kv_shards: int           # chips the cache actually spans


def _active_params(cfg: ModelConfig) -> float:
    """Per-token active linear params (MoE counts top-k + shared only)."""
    D, F, L, V = cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.vocab
    Dh = cfg.resolved_head_dim()
    if cfg.mla is not None:
        a = cfg.mla
        attn = D * a.q_lora_rank + a.q_lora_rank * cfg.n_heads * (
            a.qk_nope_head_dim + a.qk_rope_head_dim
        )
        attn += D * a.kv_lora_rank + D * a.qk_rope_head_dim
        attn += a.kv_lora_rank * cfg.n_heads * (a.qk_nope_head_dim + a.v_head_dim)
        attn += cfg.n_heads * a.v_head_dim * D
    else:
        attn = D * (cfg.n_heads + 2 * cfg.n_kv_heads) * Dh + cfg.n_heads * Dh * D
    if cfg.moe is not None:
        m = cfg.moe
        ffn_moe = 3 * D * m.d_expert * (m.top_k + m.n_shared)
        Lm = L - m.moe_layer_start
        ffn = (m.moe_layer_start * 3 * D * F + Lm * ffn_moe) / L
    else:
        ffn = 3 * D * F
    return L * (attn + ffn) + 2 * V * D


def kv_bytes_per_seq(cfg: ModelConfig, seq: int) -> float:
    if cfg.family == "rwkv6":
        H = cfg.d_model // cfg.rwkv.head_dim
        return cfg.n_layers * (H * cfg.rwkv.head_dim**2 * 4 + 2 * cfg.d_model * BYTES_PER_EL)
    if cfg.family == "zamba2":
        n_slots = max(cfg.n_layers // cfg.hybrid.shared_block_period, 1)
        attn = 2 * n_slots * seq * cfg.n_kv_heads * (2 * cfg.d_model // cfg.n_heads) * BYTES_PER_EL
        d_inner = cfg.ssm.expand * cfg.d_model
        ssm = cfg.n_layers * (d_inner // cfg.ssm.d_head) * cfg.ssm.d_head * cfg.ssm.d_state * 4
        return attn + ssm
    if cfg.mla is not None:
        return cfg.n_layers * seq * (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim) * BYTES_PER_EL
    return 2 * cfg.n_layers * seq * cfg.n_kv_heads * cfg.resolved_head_dim() * BYTES_PER_EL


def plan(
    cfg: ModelConfig,
    shape: ShapeConfig,
    axes: dict[str, int],
    dev: Device = DEVICES["TPU-V5E"],
) -> Plan:
    """Pick kv policy + sub-batch count for a decode shape on a mesh."""
    B, S = shape.global_batch, shape.seq_len
    n_chips = lanes(axes)

    # shards the cache spans under each policy (via the same resolver the
    # models use, so the plan matches what actually lowers)
    def shards(policy: str) -> int:
        rules = kv_rules(policy)
        if cfg.mla is not None:  # latent cache has no head axis
            logical = ("kv_batch", "kv_seq", None)
            dims = (B, S, cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim)
        elif cfg.family == "rwkv6":  # state cache: (B, H, N, N)
            logical = ("kv_batch", "state", None, None)
            H = cfg.d_model // cfg.rwkv.head_dim
            dims = (B, H, cfg.rwkv.head_dim, cfg.rwkv.head_dim)
        else:
            logical = ("kv_batch", "kv_seq", "kv_heads", "head_dim")
            dims = (B, S, max(cfg.n_kv_heads, 1), cfg.resolved_head_dim())
        spec = resolve_spec(logical, rules, axes, dims)
        n = 1
        for part in spec:
            if part is None:
                continue
            for ax in (part if isinstance(part, tuple) else (part,)):
                n *= axes[ax]
        return n

    candidates = {}
    for policy in ("batch", "head", "sequence", "batch_seq"):
        n = shards(policy)
        kv_by = kv_bytes_per_seq(cfg, S) * B
        t_attn = kv_by / (n * dev.bw)
        candidates[policy] = (t_attn, n)
    # paper Fig. 4: prefer batch over head on merge cost when tied
    order = {"batch": 0, "batch_seq": 1, "sequence": 2, "head": 3}
    best = min(candidates, key=lambda p: (candidates[p][0], order[p]))
    t_attn, n_shards = candidates[best]

    t_linear = 2 * _active_params(cfg) * B / (n_chips * dev.flops)
    t_linear = max(
        t_linear, _active_params(cfg) * BYTES_PER_EL / (n_chips * dev.bw)
    )
    # boundary: per-token q/k/v + output vectors over ICI
    Dh = cfg.resolved_head_dim()
    bound = cfg.n_layers * B * (2 * cfg.n_heads + 2 * cfg.n_kv_heads) * Dh * BYTES_PER_EL
    t_bound = bound / (n_chips * dev.net)

    sub = 2 if min(t_linear, t_attn) > 0.2 * max(t_linear, t_attn) else 1
    bottleneck = "attention" if t_attn >= t_linear else "linear"
    return Plan(best, sub, t_linear, t_attn, t_bound, bottleneck, n_shards)
