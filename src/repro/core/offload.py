"""Disaggregated ("offloaded") decode attention — the paper's core mechanism.

The GPU↔HPU split becomes a *layout* split on the TPU mesh:

  compute side   activations sharded [batch -> (pod,data), heads -> model]
                 (linear layers are TP over `model`, DP over `data`)
  HPU side       KV cache + attention sharded per a placement policy
                 (``repro.core.placement``), maximizing the aggregate HBM
                 bandwidth serving the memory-bound GEMV-shaped attention.

The boundary resharding of per-token Q (and the freshly produced K/V) is
the analogue of the paper's PCIe Q/K/V descriptor transfer: a few
``batch*heads*head_dim`` vectors per layer per step, negligible next to
the KV cache itself.  We emit it as ``with_sharding_constraint`` and let
GSPMD schedule the all-to-all; the big cache is *already resident* in the
HPU layout (its in_sharding comes from ``cache_specs``), so no bulk data
moves — exactly the paper's design point.

``offload="none"`` runs everything in the compute layout (the GPU-only
baseline of the paper's evaluation).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.placement import Env
from repro.models import attention as attn


def _wsc(x: jax.Array, spec: P) -> jax.Array:
    if spec == P() or not spec:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_cache(env: Env, k_cache: jax.Array, v_cache: jax.Array):
    """Pin caches to the policy layout (idempotent when already resident)."""
    if not env.axes:
        return k_cache, v_cache
    spec = env.kv_spec(("kv_batch", "kv_seq", "kv_heads", "head_dim"), k_cache.shape)
    return _wsc(k_cache, spec), _wsc(v_cache, spec)


def decode_attention(
    env: Env,
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    *,
    scale: float | None = None,
) -> jax.Array:
    """One decode step of attention, routed through the HPU layout.

    q (B, Hq, D); caches (B, S, Hkv, D); lengths (B,) -> (B, Hq, D).
    """
    if env.axes and env.offload == "hpu":
        # --- boundary transfer (PCIe analogue): per-token Q to HPU layout
        q = _wsc(q, env.kv_spec(("kv_batch", "kv_heads", "head_dim"), q.shape))
        k_cache, v_cache = constrain_cache(env, k_cache, v_cache)
    acc = jnp.bfloat16 if env.bf16_combine else jnp.float32
    if env.use_pallas:
        from repro.kernels import ops

        out = ops.decode_attention(q, k_cache, v_cache, lengths, scale=scale)
    else:
        out = attn.decode_attention(
            q, k_cache, v_cache, lengths, scale=scale, acc_dtype=acc
        )
    if env.axes and env.offload == "hpu":
        # --- gather results back to the compute layout (contiguous merge;
        # the paper's preferred batch-parallel merge order)
        out = _wsc(out, env.act_spec(("batch", "heads", "head_dim"), out.shape))
    return out


def paged_decode_attention(
    env: Env,
    q: jax.Array,             # (B, Hq, D)
    k_pool: jax.Array,        # (N_blocks, Hkv, block_size, D) — kernel-native
    v_pool: jax.Array,        # (N_blocks, Hkv, block_size, D)
    block_tables: jax.Array,  # (B, max_blocks) int32
    lengths: jax.Array,       # (B,)
    *,
    scale: float | None = None,
    starts: jax.Array | None = None,    # (B,) first hot position
    k_scale: jax.Array | None = None,   # (N_blocks, Hkv, block_size) f32
    v_scale: jax.Array | None = None,
    return_lse: bool = False,
):
    """One decode step against the paged block pool, in the HPU layout.

    The pool's *block* axis (not the batch axis) is what the HPU lanes
    split — a physical block lives wholly on one lane, so a sequence's
    block-table gather fans out across whichever lanes hold its blocks
    and the boundary traffic stays the per-token Q/K/V descriptors.

    Tiered-KV params (see ``kernels/ops.paged_decode_attention``):
    ``k_scale``/``v_scale`` mark an int8/fp8 pool dequantized in-kernel,
    ``starts`` restricts attention to the hot window ``[start, length)``,
    and ``return_lse`` returns ``(out, lse (B,Hkv,G))`` for the
    log-sum-exp merge with a cold-tier partial.
    """
    if env.axes and env.offload == "hpu":
        from repro.core.placement import PAGED_KV_CACHE_AXES

        q = _wsc(q, env.kv_spec(("kv_batch", "kv_heads", "head_dim"), q.shape))
        pool_spec = env.kv_spec(PAGED_KV_CACHE_AXES, k_pool.shape)
        k_pool = _wsc(k_pool, pool_spec)
        v_pool = _wsc(v_pool, pool_spec)
    if env.use_pallas:
        from repro.kernels import ops

        out = ops.paged_decode_attention(
            q, k_pool, v_pool, block_tables, lengths, scale=scale,
            starts=starts, k_scale=k_scale, v_scale=v_scale,
            return_lse=return_lse,
        )
    else:
        # gather-to-contiguous oracle path: identical math to the dense
        # decode (valid positions land at the same indices, pad is masked)
        from repro.kernels import ref

        if starts is None and k_scale is None and not return_lse:
            k = ref.gather_paged_cache(k_pool, block_tables)
            v = ref.gather_paged_cache(v_pool, block_tables)
            out = attn.decode_attention(
                q, k, v, lengths, scale=scale,
                acc_dtype=jnp.bfloat16 if env.bf16_combine else jnp.float32,
            )
        else:
            out = ref.paged_decode_attention(
                q, k_pool, v_pool, block_tables, lengths, scale=scale,
                starts=starts, k_scale=k_scale, v_scale=v_scale,
                return_lse=return_lse,
            )
    if env.axes and env.offload == "hpu":
        if return_lse:
            o, lse = out
            o = _wsc(o, env.act_spec(("batch", "heads", "head_dim"), o.shape))
            return o, lse
        out = _wsc(out, env.act_spec(("batch", "heads", "head_dim"), out.shape))
    return out


def mla_decode_attention(
    env: Env,
    q_latent: jax.Array,
    q_rope: jax.Array,
    ckv_cache: jax.Array,
    krope_cache: jax.Array,
    lengths: jax.Array,
    *,
    scale: float,
) -> jax.Array:
    """MLA absorbed decode through the HPU layout (cache = compressed latent).

    The latent cache has no head axis, so the `head` policy degrades to
    `sequence` automatically (resolve_spec drops non-existent axes).
    """
    if env.axes and env.offload == "hpu":
        q_latent = _wsc(
            q_latent, env.kv_spec(("kv_batch", "kv_heads", None), q_latent.shape)
        )
        q_rope = _wsc(q_rope, env.kv_spec(("kv_batch", "kv_heads", None), q_rope.shape))
        cspec = env.kv_spec(("kv_batch", "kv_seq", None), ckv_cache.shape)
        ckv_cache = _wsc(ckv_cache, cspec)
        krope_cache = _wsc(
            krope_cache, env.kv_spec(("kv_batch", "kv_seq", None), krope_cache.shape)
        )
    out = attn.mla_decode_attention(
        q_latent, q_rope, ckv_cache, krope_cache, lengths, scale=scale,
        acc_dtype=jnp.bfloat16 if env.bf16_combine else jnp.float32,
    )
    if env.axes and env.offload == "hpu":
        out = _wsc(out, env.act_spec(("batch", "heads", None), out.shape))
    return out


def verify_attention(
    env: Env,
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    *,
    scale: float | None = None,
    chunk: int = 1024,
) -> jax.Array:
    """Multi-position draft-verify attention through the HPU layout.

    q (B, T, Hq, D) scores T speculative positions per slot against the
    live cache (B, S, Hkv, D); query ``t`` of slot ``b`` sits at absolute
    position ``lengths[b] + t`` (its K/V must already be written there).
    This is the decode-side twin of :func:`prefill_attention`'s
    ``q_offset`` continuation, generalized to *per-slot* offsets — the
    GEMM-shaped pass that lets one weight stream verify ``T`` tokens.

    The serving engine's verify path deliberately does NOT use this:
    greedy speculation must be bitwise token-identical to plain
    decoding, and this differently-shaped program rounds bf16 logits
    differently than the per-token decode attention, flipping argmax on
    near-ties — so ``dense.verify_step`` unrolls per-position decode
    passes instead.  Kept as the batched pass for future tree/batch
    verification where sampling absorbs the rounding.  No Pallas kernel:
    T is tiny, so the exact jnp flash path is used on every backend.
    """
    if env.axes and env.offload == "hpu":
        q = _wsc(q, env.kv_spec(("kv_batch", None, "kv_heads", "head_dim"), q.shape))
        k_cache, v_cache = constrain_cache(env, k_cache, v_cache)
    out = attn.chunked_attention(
        q, k_cache, v_cache, causal=True, q_offset=lengths, scale=scale, chunk=chunk
    )
    if env.axes and env.offload == "hpu":
        out = _wsc(out, env.act_spec(("batch", "seq", "heads", "head_dim"), out.shape))
    return out


def prefill_attention(
    env: Env,
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    q_offset=0,
    chunk: int = 1024,
) -> jax.Array:
    """Prefill/train attention (compute-side; flash-chunked).

    ``q_offset`` (scalar, may be traced) places q[:, 0] at an absolute
    position for chunked-prefill continuation: k/v then cover the full
    cache window and only positions `<= q_offset + i` contribute to query
    ``i``.  Both the Pallas kernel and the jnp path honor it.

    With ``env.sequence_parallel`` the q/output sequence axis is sharded
    over `model` (context parallelism): the rule set gives `seq -> model`
    and GSPMD partitions the global attention math, all-gathering the much
    smaller K/V instead of replicating the O(S^2) compute.  This is how
    archs whose head count does not divide the model axis (yi-34b 56H,
    minicpm 36H, llama3.2-3b 24H on a 16-way axis) avoid 16x redundant
    attention FLOPs.
    """
    if env.axes:
        spec = env.act_spec(("batch", "seq", "heads", "head_dim"), q.shape)
        q = _wsc(q, spec)
    if env.use_pallas:
        from repro.kernels import ops

        out = ops.flash_attention(q, k, v, causal=True, q_offset=q_offset)
    else:
        out = attn.chunked_attention(q, k, v, causal=True, q_offset=q_offset, chunk=chunk)
    if env.axes:
        out = _wsc(out, env.act_spec(("batch", "seq", "heads", "head_dim"), out.shape))
    return out
