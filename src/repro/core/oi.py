"""Operational-intensity & performance model — the paper's §III analytics.

Everything here is seeded ONLY by Table I device constants and model
dimensions; it reproduces Fig. 1b/c (roofline & MFU/MBU vs batch),
Fig. 7a/b (throughput & breakdown), Fig. 8 (MFU scaling) and Fig. 9
(energy efficiency), and is validated against the paper's own headline
numbers in ``tests/test_paper_claims.py`` / ``benchmarks``.

Calibration constants (documented, not fitted per-figure):
  * ``MEM_EFF`` = 0.73 — the prototype's measured HBM utilization (§V-A).
  * ``HPU_DYN_W`` = 60 W — U55C dynamic power (TDP 150 W is never reached;
    §VI-E wall-power deltas imply ~60 W under load).
  * KV reads average over the generation phase: sequence grows from
    S_in to S_in+S_out, so mean KV length = S_in + S_out/2.
"""
from __future__ import annotations

import dataclasses

# ---------------------------------------------------------------------------
# Table I (+ A100 from §III, + TPU v5e target from the brief)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Device:
    name: str
    bw: float          # HBM bytes/s
    flops: float       # peak FP16/BF16 FLOP/s
    mem: float         # HBM bytes
    tdp: float         # W
    net: float         # host link bytes/s (PCIe / NVLink / ICI per link)

    @property
    def ridge(self) -> float:
        """perf/BW ratio = OI at which the device transitions regimes."""
        return self.flops / self.bw


DEVICES: dict[str, Device] = {
    "A100": Device("A100", 1.55e12, 312e12, 40e9, 400.0, 64e9),
    "L40S": Device("L40S", 864e9, 362.1e12, 48e9, 350.0, 16e9),
    "H100-NVL": Device("H100-NVL", 3.9e12, 835.5e12, 96e9, 400.0, 900e9),
    "HPU": Device("HPU", 4.9e12, 39.3e12, 144e9, 120.0, 64e9),
    "HPU-PROTO": Device("HPU-PROTO", 460e9, 0.46e12, 16e9, 150.0, 16e9),
    "TPU-V5E": Device("TPU-V5E", 819e9, 197e12, 16e9, 200.0, 50e9),
}

MEM_EFF = 0.73       # §V-A measured HBM utilization of the prototype
HPU_DYN_W = 60.0     # U55C dynamic power under load (W)
GPU_DYN_FRAC = 1.0   # GPU dynamic power fraction of TDP when busy
BYTES_PER_EL = 2     # fp16/bf16


# ---------------------------------------------------------------------------
# model workload (defaults = Llama-2 7B, the paper's benchmark model)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LMShape:
    n_layers: int = 32
    d_model: int = 4096
    n_heads: int = 32
    n_kv_heads: int = 32
    d_ff: int = 11008
    vocab: int = 32000

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def group(self) -> int:
        return self.n_heads // self.n_kv_heads

    def linear_params(self) -> int:
        """Per-layer linear weights (attn proj + FFN) + embeddings."""
        attn = self.d_model * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim
        attn += self.n_heads * self.head_dim * self.d_model
        ffn = 3 * self.d_model * self.d_ff
        return self.n_layers * (attn + ffn) + 2 * self.vocab * self.d_model

    def weight_bytes(self) -> float:
        return self.linear_params() * BYTES_PER_EL

    def kv_bytes_per_seq(self, seq: int) -> float:
        return 2 * self.n_layers * seq * self.n_kv_heads * self.head_dim * BYTES_PER_EL

    def linear_flops_per_token(self) -> float:
        return 2 * self.linear_params()

    def attn_flops_per_token(self, seq: int) -> float:
        # QK^T + PV over the cache, all heads
        return 2 * 2 * self.n_layers * self.n_heads * seq * self.head_dim


LLAMA2_7B = LMShape()


# ---------------------------------------------------------------------------
# Fig. 1b/c — OI, MFU, MBU vs batch
# ---------------------------------------------------------------------------
def gemm_oi(batch: int) -> float:
    """Weight-streaming GEMM: 2*W*b FLOPs per W*2 bytes -> OI ~ b."""
    return float(batch)


def gemv_oi(group: int = 1) -> float:
    """Decode attention: each KV byte feeds `group` query heads."""
    return float(group)


def attainable_flops(dev: Device, oi: float) -> float:
    return min(dev.flops, oi * dev.bw)


def mfu_mbu(dev: Device, oi: float) -> tuple[float, float]:
    """Model FLOPS / bandwidth utilization at a given OI (roofline ideal)."""
    perf = attainable_flops(dev, oi)
    mfu = perf / dev.flops
    mbu = (perf / oi) / dev.bw
    return mfu, mbu


# ---------------------------------------------------------------------------
# decode-step time model
# ---------------------------------------------------------------------------
def time_linear(dev: Device, m: LMShape, batch: int) -> float:
    fl = m.linear_flops_per_token() * batch
    by = m.weight_bytes()
    return max(fl / dev.flops, by / (dev.bw * MEM_EFF))


def time_attention(dev: Device, m: LMShape, batch: int, seq: int, n_dev: int = 1) -> float:
    by = m.kv_bytes_per_seq(seq) * batch / n_dev
    fl = m.attn_flops_per_token(seq) * batch / n_dev
    return max(fl / dev.flops, by / (dev.bw * MEM_EFF))


def boundary_bytes_per_step(m: LMShape, batch: int) -> float:
    """Per-token Q/K/V vectors + attention output (the PCIe transfer)."""
    per_tok = (m.n_heads + 2 * m.n_kv_heads + m.n_heads) * m.head_dim * BYTES_PER_EL
    return m.n_layers * per_tok * batch


def step_time_gpu_only(gpu: Device, m: LMShape, batch: int, seq: int) -> dict:
    tl = time_linear(gpu, m, batch)
    ta = time_attention(gpu, m, batch, seq)
    return {"linear": tl, "attention": ta, "network": 0.0, "total": tl + ta}


def step_time_hetero(
    gpu: Device,
    hpu: Device,
    m: LMShape,
    batch: int,
    seq: int,
    n_hpu: int = 4,
    pipelined: bool = True,
) -> dict:
    tl = time_linear(gpu, m, batch)
    ta = time_attention(hpu, m, batch, seq, n_dev=n_hpu)
    tn = boundary_bytes_per_step(m, batch) / hpu.net
    if pipelined:
        # staggered sub-batches (Fig. 3): network and the shorter stage hide
        total = max(tl, ta) + tn
    else:
        total = tl + ta + tn
    return {"linear": tl, "attention": ta, "network": tn, "total": total}


def max_batch_gpu_only(gpu: Device, m: LMShape, seq: int) -> int:
    """OOM boundary (§VI-B): weights + activations margin + KV caches."""
    free = gpu.mem * 0.95 - m.weight_bytes()
    return max(int(free / m.kv_bytes_per_seq(seq)), 0)


def max_batch_per_hpu(hpu: Device, m: LMShape, seq: int) -> int:
    """The card holds ONLY KV (no weights/activations) -> full HBM usable."""
    return max(int(hpu.mem / m.kv_bytes_per_seq(seq)), 0)


# ---------------------------------------------------------------------------
# energy model (Fig. 9)
# ---------------------------------------------------------------------------
def energy_per_step(gpu: Device, times: dict, n_hpu: int = 0, hpu_dyn: float = HPU_DYN_W) -> float:
    """Joules per decode step: dynamic power x busy time per device."""
    total = times["total"]
    gpu_busy = times["linear"] + (times["attention"] if n_hpu == 0 else 0.0)
    e = gpu.tdp * GPU_DYN_FRAC * min(gpu_busy, total)
    if n_hpu:
        e += n_hpu * hpu_dyn * min(times["attention"], total)
    return e


def tokens_per_joule(batch: int, times: dict, gpu: Device, n_hpu: int = 0) -> float:
    return batch / energy_per_step(gpu, times, n_hpu) if times["total"] else 0.0


# ---------------------------------------------------------------------------
# end-to-end MFU (Fig. 8)
# ---------------------------------------------------------------------------
def mfu_end_to_end(gpu: Device, m: LMShape, batch: int, seq: int, times: dict) -> float:
    useful = (m.linear_flops_per_token() + m.attn_flops_per_token(seq)) * batch
    return useful / (times["total"] * gpu.flops)
