"""Sub-batch pipelining (paper Fig. 3) as JAX program structure.

The paper staggers sub-batches so the HPU computes attention for sub-batch
*i* while the GPU runs linear layers for sub-batch *j*.  Under XLA there
are no explicit command queues; instead we split the batch into
``n_sub`` *data-independent* step computations.  Because the sub-batches
share no activations, XLA's latency-hiding scheduler is free to overlap
the HPU-layout collectives (the boundary "transfers") and attention of one
sub-batch with the FFN GEMMs of another — the same pipeline, expressed as
available instruction-level parallelism instead of device queues.

``pipelined_step`` is the generic wrapper used by the serving engine and
the dry-run when ``parallel.sub_batches > 1``.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

Pytree = Any


def _nbatch(tree: Pytree) -> int:
    leaves = [l for l in jax.tree.leaves(tree) if hasattr(l, "shape") and l.ndim]
    return leaves[0].shape[0]


def tree_split(tree: Pytree, n_sub: int, axis: int = 0) -> list[Pytree]:
    """Split every leaf along ``axis`` into n_sub equal parts."""

    def split_leaf(leaf):
        return jnp.split(leaf, n_sub, axis=axis)

    parts = jax.tree.map(split_leaf, tree)
    return [jax.tree.map(lambda p, i=i: p[i], parts, is_leaf=lambda x: isinstance(x, list)) for i in range(n_sub)]


def tree_concat(trees: list[Pytree], axis: int = 0) -> Pytree:
    return jax.tree.map(lambda *ls: jnp.concatenate(ls, axis=axis), *trees)


def split_cache(cache: Pytree, n_sub: int, batch_axes: dict[str, int]) -> list[Pytree]:
    """Split a cache pytree on each leaf's batch axis (leaf-name -> axis)."""
    subs: list[dict] = [dict() for _ in range(n_sub)]
    for k, v in cache.items():
        ax = batch_axes.get(k, 1)  # stacked-layer caches carry batch at 1
        parts = jnp.split(v, n_sub, axis=ax)
        for i in range(n_sub):
            subs[i][k] = parts[i]
    return subs


def merge_cache(subs: list[Pytree], batch_axes: dict[str, int]) -> Pytree:
    out = {}
    for k in subs[0]:
        ax = batch_axes.get(k, 1)
        out[k] = jnp.concatenate([s[k] for s in subs], axis=ax)
    return out


def default_batch_axes(cache: Pytree) -> dict[str, int]:
    """lengths is (B,); stacked per-layer caches are (L, B, ...)."""
    return {k: (0 if k == "lengths" else 1) for k in cache}


def pipelined_step(
    decode_fn: Callable[[Pytree, Pytree, jax.Array], tuple[jax.Array, Pytree]],
    n_sub: int,
) -> Callable[[Pytree, Pytree, jax.Array], tuple[jax.Array, Pytree]]:
    """Wrap a decode step so it runs as ``n_sub`` staggered sub-batches."""
    if n_sub <= 1:
        return decode_fn

    def step(params, cache, tokens):
        axes = default_batch_axes(cache)
        cache_subs = split_cache(cache, n_sub, axes)
        token_subs = jnp.split(tokens, n_sub, axis=0)
        outs = []
        new_caches = []
        for c, t in zip(cache_subs, token_subs):
            logits, nc = decode_fn(params, c, t)
            outs.append(logits)
            new_caches.append(nc)
        return jnp.concatenate(outs, 0), merge_cache(new_caches, axes)

    return step
