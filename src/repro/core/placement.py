"""KV-cache partitioning policies (paper Fig. 4) + activation sharding rules.

The paper distributes the KV cache across HPU cards in two ways:

  * **batch-parallel** (paper-preferred): each HPU owns whole sequences
    (all heads) for a slice of the batch; results merge contiguously.
  * **head-parallel**: each HPU owns a slice of the heads for the whole
    batch; merging interleaves per-head vectors (host-side overhead in the
    prototype).

On the TPU mesh we add a third, beyond-paper policy:

  * **sequence-parallel** ("flash-decoding" style): the cache is sharded
    along the sequence axis; partial softmax statistics are merged with a
    log-sum-exp combine (GSPMD inserts the small all-reduces).  This is
    the only policy whose shardable dimension is guaranteed divisible for
    every architecture (S >> #chips), so the balancer falls back to it.

A policy is a rules dict mapping *logical* axes of cache/boundary tensors
to mesh axes; ``repro.models.common.resolve_spec`` drops mesh axes that
would over-pad.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from jax.sharding import PartitionSpec as P

from repro.models.common import resolve_spec

POLICIES = ("batch", "head", "sequence", "batch_seq", "none")

# logical axes used by caches / boundary tensors
KV_CACHE_AXES = ("kv_batch", "kv_seq", "kv_heads", "head_dim")
# paged pool leaves (kernel-native layout, heads before positions): the
# physical block axis replaces the batch axis as the unit the HPU lanes
# split (a block belongs to exactly one lane)
PAGED_KV_CACHE_AXES = ("kv_blocks", "kv_heads", "kv_seq", "head_dim")


def kv_rules(policy: str) -> dict[str, tuple[str, ...]]:
    if policy == "batch":
        return {
            "kv_batch": ("pod", "data"),
            "kv_blocks": ("pod", "data"),  # paged pool: blocks across HPU lanes
            "kv_heads": ("model",),
            "kv_seq": (),
            "head_dim": (),
            "state": ("model",),  # rwkv/mamba state channels
        }
    if policy == "head":
        return {
            "kv_batch": ("pod",),
            "kv_blocks": ("pod",),
            "kv_heads": ("data", "model"),
            "kv_seq": (),
            "head_dim": (),
            "state": ("data", "model"),
        }
    if policy == "sequence":
        return {
            "kv_batch": ("pod",),
            "kv_blocks": ("data", "model"),
            "kv_heads": (),
            "kv_seq": ("data", "model"),
            "head_dim": (),
            "state": ("data", "model"),
        }
    if policy == "batch_seq":
        # beyond-paper 2D policy: batch over (pod,data), sequence over
        # model.  The flash-decoding LSE combine then reduces a tensor that
        # is batch-sharded (16x smaller) over only the model group — §Perf
        # iteration 3 on the deepseek cell.
        return {
            "kv_batch": ("pod", "data"),
            "kv_blocks": ("pod", "data", "model"),
            "kv_seq": ("model",),
            "kv_heads": (),
            "head_dim": (),
            "state": ("model",),
        }
    if policy == "none":
        return {
            "kv_batch": ("pod", "data"),
            "kv_blocks": (),
            "kv_heads": (),
            "kv_seq": (),
            "head_dim": (),
            "state": (),
        }
    raise ValueError(f"unknown kv policy {policy!r}")


def activation_rules(sequence_parallel: bool = False) -> dict[str, tuple[str, ...]]:
    """Sharding rules for the compute (GPU-analogue) side: TP over `model`,
    DP over `pod`+`data`; optional sequence-parallel on the seq axis."""
    return {
        "batch": ("pod", "data"),
        "seq": ("model",) if sequence_parallel else (),
        "heads": ("model",),
        "kv_heads": ("model",),
        "mlp": ("model",),
        "vocab": ("model",),
        "experts": ("model",),
        "embed": (),
        "head_dim": (),
        "layers": (),
        "state": (),
        # training-side cache axes (unused) map like activations
        "kv_batch": ("pod", "data"),
        "kv_seq": (),
    }


def param_rules(sequence_parallel: bool = False, fsdp: bool = False) -> dict[str, tuple[str, ...]]:
    """Weight sharding: TP over `model`; with ``fsdp`` the d_model axis of
    every weight is additionally sharded over (`pod`,`data`) (ZeRO-3 —
    GSPMD all-gathers per scanned layer)."""
    rules = dict(activation_rules(sequence_parallel))
    rules["embed"] = ("pod", "data") if fsdp else ()
    rules["batch"] = ()  # weights have no batch axis; guard misuse
    return rules


@dataclass(frozen=True)
class Env:
    """Everything the model code needs to know about the runtime context.

    ``axes`` is ``{mesh_axis_name: size}`` (empty dict = single device, no
    sharding constraints emitted).  Threaded explicitly: no ambient-mesh
    magic, so CPU unit tests and 512-device dry-runs share one code path.
    """
    axes: dict[str, int] = field(default_factory=dict)
    kv_policy: str = "batch"
    offload: str = "hpu"        # "hpu" | "none"
    sub_batches: int = 1
    sequence_parallel: bool = False
    fsdp: bool = False
    ep_wide: bool = False       # inference: experts over (data, model) — the
                                # DeepSeek deployment layout; tokens reach
                                # their expert shard via all-to-all
    bf16_combine: bool = False  # carry cross-shard attention LSE-combine
                                # partials in bf16 (halves wire bytes)
    moe_a2a: bool = False       # §Perf iter.4 (refuted on XLA:CPU: lowers
                                # to all-gather, not all-to-all; see
                                # EXPERIMENTS.md §Perf)
    use_pallas: bool = False

    def act_rules(self) -> dict[str, tuple[str, ...]]:
        rules = activation_rules(self.sequence_parallel)
        if self.ep_wide:
            rules = {**rules, "experts": ("pod", "data", "model")}
        return rules

    def param_rules(self) -> dict[str, tuple[str, ...]]:
        rules = param_rules(self.sequence_parallel, self.fsdp)
        if self.ep_wide:
            rules = {**rules, "experts": ("pod", "data", "model")}
        return rules

    def kv_spec(self, logical: tuple[str | None, ...], shape) -> P:
        policy = self.kv_policy if self.offload == "hpu" else "none"
        return resolve_spec(logical, kv_rules(policy), self.axes, tuple(shape))

    def act_spec(self, logical: tuple[str | None, ...], shape) -> P:
        return resolve_spec(logical, self.act_rules(), self.axes, tuple(shape))


def lanes(axes: dict[str, int]) -> int:
    """Number of 'HPU lanes' = chips the KV pool spans."""
    n = 1
    for v in axes.values():
        n *= v
    return n
