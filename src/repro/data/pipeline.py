"""Deterministic synthetic token pipeline (+ binary-file reader).

Synthetic batches are a pure function of (seed, step, host) so every
restart — including elastic restarts on a different host count — replays
the identical global stream: host h of H draws the global batch and takes
its slice, which keeps the global data order invariant under rescale.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2      # token distribution skew (LM-ish)


def _rng(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))


def global_batch(cfg: DataConfig, step: int) -> dict[str, np.ndarray]:
    """(inputs, targets, mask) for one step; targets are inputs shifted."""
    rng = _rng(cfg, step)
    # zipf over vocab, clipped; +1 so 0 can serve as pad/eos
    toks = rng.zipf(cfg.zipf_a, size=(cfg.global_batch, cfg.seq_len + 1))
    toks = np.minimum(toks, cfg.vocab - 1).astype(np.int32)
    return {
        "inputs": toks[:, :-1],
        "targets": toks[:, 1:],
        "mask": np.ones((cfg.global_batch, cfg.seq_len), np.float32),
    }


def host_batch(cfg: DataConfig, step: int, host: int, n_hosts: int) -> dict[str, np.ndarray]:
    g = global_batch(cfg, step)
    per = cfg.global_batch // n_hosts
    sl = slice(host * per, (host + 1) * per)
    return {k: v[sl] for k, v in g.items()}


def batches(cfg: DataConfig, start_step: int = 0, host: int = 0, n_hosts: int = 1) -> Iterator[dict]:
    step = start_step
    while True:
        yield host_batch(cfg, step, host, n_hosts)
        step += 1


class TokenFileDataset:
    """Memory-mapped pre-tokenized corpus (flat int32 tokens)."""

    def __init__(self, path: str, seq_len: int, batch: int, seed: int = 0):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.seq_len = seq_len
        self.batch = batch
        self.seed = seed
        self.n_windows = (len(self.tokens) - 1) // seq_len

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step]))
        idx = rng.integers(0, self.n_windows, size=self.batch)
        starts = idx * self.seq_len
        inp = np.stack([self.tokens[s : s + self.seq_len] for s in starts])
        tgt = np.stack([self.tokens[s + 1 : s + 1 + self.seq_len] for s in starts])
        return {
            "inputs": inp.astype(np.int32),
            "targets": tgt.astype(np.int32),
            "mask": np.ones_like(inp, np.float32),
        }
