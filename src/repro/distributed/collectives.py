"""Collective helpers: boundary-bytes estimation + int8 compressed psum.

``int8_psum`` realizes the byte saving of the int8 gradient all-reduce
(``training.compression``) with a shard_map all-reduce over the quantized
payload — 4x fewer bytes on the `data` axis than an f32 reduce.  Summing
int8 payloads can overflow int8, so the wire format is int8 but the
reduction runs in int32 (still 4x fewer *transferred* bytes with
ring-reduce chunking; the local widening is free).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

Pytree = Any


def int8_psum(x_q: jax.Array, scale: jax.Array, mesh, axis: str) -> jax.Array:
    """All-reduce an int8 payload (+ fp32 scale) over ``axis``; returns the
    dequantized fp32 mean across the axis."""

    def body(xq, s):
        total = jax.lax.psum(xq.astype(jnp.int32), axis)
        s_max = jax.lax.pmax(s, axis)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
        return total.astype(jnp.float32) * s_max / n

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(), P()),
        out_specs=P(),
    )(x_q, scale)


def collective_bytes_of_spec(shape, dtype_bytes: int, n_shards: int, kind: str) -> float:
    """Analytic wire bytes per collective (ring algorithms)."""
    import math

    total = math.prod(shape) * dtype_bytes
    if kind == "all-reduce":
        return 2 * total * (n_shards - 1) / n_shards
    if kind in ("all-gather", "reduce-scatter"):
        return total * (n_shards - 1) / n_shards
    if kind == "all-to-all":
        return total * (n_shards - 1) / n_shards
    if kind == "collective-permute":
        return total
    raise ValueError(kind)
