"""Fault tolerance: straggler detection + elastic rescale planning.

At thousand-node scale the framework must (a) notice slow/failed workers,
(b) restart from the last step-atomic checkpoint on a smaller/larger
mesh, and (c) keep the global data order.  The pieces here are pure logic
(unit-tested on CPU); the launch scripts wire them to real processes.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable


# ---------------------------------------------------------------------------
# straggler detection (feeds the paper's §IV-C balancer re-tuning as well)
# ---------------------------------------------------------------------------
class StragglerMonitor:
    """Per-worker step-time tracker with robust outlier detection.

    A worker is a straggler when its rolling-median step time exceeds
    ``threshold`` x the fleet median for ``patience`` consecutive windows.
    """

    def __init__(self, n_workers: int, window: int = 16, threshold: float = 1.5,
                 patience: int = 3):
        self.times: list[deque] = [deque(maxlen=window) for _ in range(n_workers)]
        self.threshold = threshold
        self.patience = patience
        self.strikes = [0] * n_workers

    @staticmethod
    def _median(xs) -> float:
        s = sorted(xs)
        n = len(s)
        return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])

    def record(self, worker: int, step_time: float):
        self.times[worker].append(step_time)

    def fleet_median(self) -> float:
        per = [self._median(t) for t in self.times if t]
        return self._median(per) if per else 0.0

    def check(self) -> list[int]:
        """Returns workers currently flagged as stragglers."""
        fleet = self.fleet_median()
        flagged = []
        for w, t in enumerate(self.times):
            if not t or fleet == 0.0:
                continue
            if self._median(t) > self.threshold * fleet:
                self.strikes[w] += 1
            else:
                self.strikes[w] = 0
            if self.strikes[w] >= self.patience:
                flagged.append(w)
        return flagged


class Heartbeat:
    """Deadline-based liveness: workers report; ``dead()`` lists misses."""

    def __init__(self, n_workers: int, timeout: float):
        self.timeout = timeout
        self.last = [time.monotonic()] * n_workers

    def beat(self, worker: int, now: float | None = None):
        self.last[worker] = time.monotonic() if now is None else now

    def dead(self, now: float | None = None) -> list[int]:
        now = time.monotonic() if now is None else now
        return [w for w, t in enumerate(self.last) if now - t > self.timeout]


# ---------------------------------------------------------------------------
# elastic rescale planning
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple[int, ...]
    axes: tuple[str, ...]
    global_batch: int
    grad_accum: int


def plan_rescale(
    n_devices: int,
    model_parallel: int,
    global_batch: int,
    multi_pod_size: int | None = None,
) -> MeshPlan:
    """Re-plan the mesh after losing/gaining devices.

    Keeps the `model` axis fixed (weights layout unchanged -> cheap
    restore) and shrinks/grows `data`.  The global batch is preserved via
    grad accumulation when per-step capacity drops; this keeps training
    curves comparable across rescales.
    """
    if n_devices % model_parallel:
        # drop remainder devices (spares)
        n_devices -= n_devices % model_parallel
    if n_devices <= 0:
        raise ValueError("no usable devices for the requested model parallelism")
    data = n_devices // model_parallel
    if multi_pod_size and n_devices > multi_pod_size:
        pods = n_devices // multi_pod_size
        data = multi_pod_size // model_parallel
        shape = (pods, data, model_parallel)
        axes = ("pod", "data", "model")
        capacity = pods * data
    else:
        shape = (data, model_parallel)
        axes = ("data", "model")
        capacity = data
    # keep the global batch constant: find the smallest grad-accum factor
    # such that the per-step microbatch splits evenly over the data shards
    accum = 1
    while accum <= global_batch:
        micro = global_batch // accum
        if global_batch % accum == 0 and micro % capacity == 0:
            break
        accum += 1
    else:
        raise ValueError("cannot split batch across devices")
    return MeshPlan(shape, axes, global_batch, accum)


# ---------------------------------------------------------------------------
# supervised training loop (restart-on-failure)
# ---------------------------------------------------------------------------
class Supervisor:
    """Runs ``run_fn(start_step) -> last_step`` with restart-from-checkpoint
    on exceptions, up to ``max_restarts``.  ``run_fn`` raising simulates a
    node failure in tests; in production it's the train loop."""

    def __init__(self, run_fn: Callable[[int], int], latest_step: Callable[[], int | None],
                 max_restarts: int = 3):
        self.run_fn = run_fn
        self.latest_step = latest_step
        self.max_restarts = max_restarts
        self.restarts = 0

    def run(self, start_step: int = 0) -> int:
        step = start_step
        while True:
            try:
                return self.run_fn(step)
            except Exception:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                last = self.latest_step()
                step = 0 if last is None else last
