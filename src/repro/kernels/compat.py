"""jax-version compatibility for the Pallas TPU kernels."""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 spells it TPUCompilerParams, newer versions CompilerParams
CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
