"""Pallas TPU flash-decode kernel — the HPU attention accelerator analogue.

The paper's HPU executes decode attention with a *narrow GEMM engine
optimized for GQA* (up to 8 query heads per KV group, matching its
perf/BW ratio of 8 Ops/Byte).  On TPU we realize the same design point by
packing the GQA group into the MXU sublane dimension:

    scores(G, BLOCK_S) = q(G, D) @ k(BLOCK_S, D)^T       # narrow GEMM
    out   (G, D)       = p(G, BLOCK_S) @ v(BLOCK_S, D)

with an online softmax accumulated in VMEM scratch across sequence
blocks.  KV streams HBM->VMEM in (BLOCK_S, D) tiles (the analogue of the
prototype's 64B-interleaved multi-port HBM access); operational intensity
is ~2*G Ops/Byte — G=8 reproduces the HPU's OI=8, G=1 (MHA) the
prototype's OI~1.

Grid: (B, Hkv, S/BLOCK_S); the sequence axis iterates innermost so the
scratch accumulators carry the running max/denominator per (batch, kv
head).  ``lengths`` masks the tail of partially-filled caches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

DEFAULT_BLOCK_S = 512
NEG_INF = -1e30


def _decode_kernel(
    lengths_ref,  # SMEM (B,)
    q_ref,        # (1, 1, G, D)
    k_ref,        # (1, 1, BLOCK_S, D)
    v_ref,        # (1, 1, BLOCK_S, D)
    o_ref,        # (1, 1, G, D)
    m_ref,        # VMEM scratch (G, 1) f32
    l_ref,        # VMEM scratch (G, 1) f32
    acc_ref,      # VMEM scratch (G, D) f32
    *,
    scale: float,
    block_s: int,
):
    b = pl.program_id(0)
    s = pl.program_id(2)
    n_s = pl.num_programs(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (BLOCK_S, D)
    v = v_ref[0, 0].astype(jnp.float32)          # (BLOCK_S, D)

    length = lengths_ref[b]
    k_pos = s * block_s + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)
    valid = k_pos < length                        # (1, BLOCK_S)

    # narrow GEMM: (G, D) x (D, BLOCK_S)
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                     # (G, BLOCK_S)
    scores = jnp.where(valid, scores, NEG_INF)

    m_prev = m_ref[...]                           # (G, 1)
    m_cur = jnp.max(scores, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(scores - m_new)                   # (G, BLOCK_S)
    p = jnp.where(valid, p, 0.0)
    corr = jnp.exp(m_prev - m_new)                # (G, 1)

    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(s == n_s - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def decode_attention_pallas(
    q: jax.Array,          # (B, Hkv, G, D)  — group packed into sublanes
    k: jax.Array,          # (B, Hkv, S, D)
    v: jax.Array,          # (B, Hkv, S, D)
    lengths: jax.Array,    # (B,) int32
    *,
    scale: float,
    block_s: int = DEFAULT_BLOCK_S,
    interpret: bool = False,
) -> jax.Array:
    B, Hkv, G, D = q.shape
    S = k.shape[2]
    assert S % block_s == 0, (S, block_s)
    n_s = S // block_s

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, s, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_s, D), lambda b, h, s, lens: (b, h, s, 0)),
            pl.BlockSpec((1, 1, block_s, D), lambda b, h, s, lens: (b, h, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, s, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    kernel = functools.partial(_decode_kernel, scale=scale, block_s=block_s)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
    )(lengths, q, k, v)
