"""jit'd public wrappers around the Pallas kernels.

Handles layout conversion (model layout <-> kernel layout), GQA group
packing, shape padding to hardware-aligned blocks, and the CPU interpret
fallback (``interpret=True`` executes the identical kernel body on CPU,
which is how the kernels are validated in this container).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.paged_decode_attention import paged_decode_attention_pallas
from repro.kernels.prefill_attention import flash_attention_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("scale", "block_s"))
def decode_attention(
    q: jax.Array,        # (B, Hq, D) — model layout
    k_cache: jax.Array,  # (B, S, Hkv, D)
    v_cache: jax.Array,  # (B, S, Hkv, D)
    lengths: jax.Array,  # (B,)
    scale: float | None = None,
    block_s: int = 512,
) -> jax.Array:
    B, Hq, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    qk = q.reshape(B, Hkv, G, D)                    # pack GQA group
    kk = jnp.swapaxes(k_cache, 1, 2)                # (B, Hkv, S, D)
    vk = jnp.swapaxes(v_cache, 1, 2)
    block = min(block_s, S)
    kk = _pad_to(kk, 2, block)
    vk = _pad_to(vk, 2, block)

    out = decode_attention_pallas(
        qk, kk, vk, lengths.astype(jnp.int32),
        scale=scale, block_s=block, interpret=_interpret(),
    )
    return out.reshape(B, Hq, D)


@functools.partial(jax.jit, static_argnames=("scale", "return_lse"))
def paged_decode_attention(
    q: jax.Array,             # (B, Hq, D) — model layout
    k_pool: jax.Array,        # (N_blocks, Hkv, block_size, D) — kernel-native
    v_pool: jax.Array,        # (N_blocks, Hkv, block_size, D)
    block_tables: jax.Array,  # (B, max_blocks) int32
    lengths: jax.Array,       # (B,)
    scale: float | None = None,
    *,
    starts: jax.Array | None = None,    # (B,) first hot position
    k_scale: jax.Array | None = None,   # (N_blocks, Hkv, block_size) f32
    v_scale: jax.Array | None = None,
    return_lse: bool = False,
):
    """Tiered-KV params: ``k_scale``/``v_scale`` mark the pools as
    int8/fp8 payloads dequantized inside the kernel; ``starts`` restricts
    attention to the hot window ``[start, length)``; ``return_lse``
    additionally returns the per-row log-sum-exp ``(B, Hkv, G) f32`` for
    :func:`repro.kernels.ref.lse_merge`."""
    B, Hq, D = q.shape
    N, Hkv, bs, _ = k_pool.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    # the pool is stored kernel-native (see paged_cache_defs): only the
    # tiny per-token q needs packing, the bandwidth-bound KV streams as-is
    qk = q.reshape(B, Hkv, G, D)                  # pack GQA group
    out, lse = paged_decode_attention_pallas(
        qk, k_pool, v_pool,
        block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
        scale=scale, starts=starts, k_scale=k_scale, v_scale=v_scale,
        interpret=_interpret(),
    )
    out = out.reshape(B, Hq, D)
    if return_lse:
        return out, lse[..., 0]                   # (B, Hkv, G)
    return out


@functools.partial(jax.jit, static_argnames=("scale", "causal", "block_q", "block_k"))
def flash_attention(
    q: jax.Array,  # (B, Sq, Hq, D) — model layout
    k: jax.Array,  # (B, Sk, Hkv, D)
    v: jax.Array,  # (B, Sk, Hkv, D)
    scale: float | None = None,
    causal: bool = True,
    q_offset: jax.Array | int = 0,
    block_q: int = 512,
    block_k: int = 512,
    k_scale: jax.Array | None = None,   # (B, Sk, Hkv) f32
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """``q_offset`` (traced scalar) is the absolute position of q[:, 0] —
    chunked-prefill continuation attends a (Sq=chunk) query block against
    a (Sk=cache) KV window without recompiling per offset.
    ``k_scale``/``v_scale`` mark k/v as int8/fp8 payloads dequantized
    per stored vector inside the kernel."""
    B, Sq, Hq, D = q.shape
    Sk = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qk = jnp.swapaxes(q, 1, 2)  # (B, Hq, Sq, D)
    kk = jnp.swapaxes(k, 1, 2)
    vk = jnp.swapaxes(v, 1, 2)
    ks = None if k_scale is None else jnp.swapaxes(k_scale, 1, 2)  # (B,Hkv,Sk)
    vs = None if v_scale is None else jnp.swapaxes(v_scale, 1, 2)
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    while Sq % bq:
        bq //= 2
    while Sk % bk:
        bk //= 2
    out = flash_attention_pallas(
        qk, kk, vk, scale=scale, causal=causal, q_offset=q_offset,
        k_scale=ks, v_scale=vs,
        block_q=max(bq, 1), block_k=max(bk, 1), interpret=_interpret(),
    )
    return jnp.swapaxes(out, 1, 2)
