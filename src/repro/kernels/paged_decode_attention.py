"""Pallas TPU paged flash-decode kernel: block-table gather via scalar
prefetch.

Same narrow-GEMM/online-softmax structure as ``decode_attention.py`` (the
HPU's GQA-group-packed design point), but the KV cache is a pool of
fixed-size physical blocks shared across sequences.  The per-sequence
``block_tables`` (B, max_blocks) int32 arrive as a *scalar-prefetch*
operand, so the BlockSpec index map — which runs ahead of the kernel body
to program the HBM->VMEM DMAs — can translate logical block ``s`` of
sequence ``b`` into physical pool block ``tables[b, s]``.  This is the
TPU analogue of the HPU prototype's descriptor-driven HBM access: the
bandwidth-bound KV stream is gathered at full rate with no materialized
per-sequence copy.

Grid: ``(B, Hkv, max_blocks)``; the block axis iterates innermost so the
VMEM scratch accumulators carry running max/denominator per (batch, kv
head).  Unused table entries point at physical block 0 (the engine's
null block) — their scores are masked by ``lengths`` so the garbage they
gather never contributes.

Tiered-KV extensions (all optional, zero-cost when unused):

* **quantized pools** — when ``k_scale``/``v_scale`` pools are passed
  (``(N_blocks, Hkv, block_size)`` f32, one absmax scale per stored
  vector), the K/V pools hold int8 or fp8 payloads and the kernel
  dequantizes *inside* the block loop, right after the HBM->VMEM DMA:
  the bandwidth-bound stream moves at 1 byte/elem and widens to f32 only
  in VMEM.
* **``starts``** — per-sequence first *hot* position: positions below it
  are masked exactly like positions past ``lengths``.  This is the hot
  half of the HGCA-style hybrid: cold (host-offloaded) prefix blocks are
  attended elsewhere and merged by log-sum-exp.
* **log-sum-exp output** — the kernel always returns ``(out, lse)`` with
  ``lse = m + log(l)`` per (batch, kv head, group) row, the exact
  quantity LSE merging needs.  A window with no valid positions yields
  ``lse <= NEG_INF`` so its merge weight underflows to 0 (never NaN).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _paged_decode_kernel(
    tables_ref,   # SMEM (B, MB) int32 — consumed by the index maps
    lengths_ref,  # SMEM (B,)
    starts_ref,   # SMEM (B,) — first hot position (0 = whole sequence)
    q_ref,        # (1, 1, G, D)
    k_ref,        # (1, 1, block_size, D) — physical block tables[b, s]
    v_ref,        # (1, 1, block_size, D)
    *rest,        # [ks_ref, vs_ref,] o_ref, lse_ref, m/l/acc scratch
    scale: float,
    block_size: int,
    quantized: bool,
):
    if quantized:
        ks_ref, vs_ref, o_ref, lse_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, lse_ref, m_ref, l_ref, acc_ref = rest
    b = pl.program_id(0)
    s = pl.program_id(2)
    n_s = pl.num_programs(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (block_size, D)
    v = v_ref[0, 0].astype(jnp.float32)
    if quantized:
        # per-vector absmax scales: dequant right after the VMEM load
        k = k * ks_ref[0, 0][:, None]            # (block_size, 1)
        v = v * vs_ref[0, 0][:, None]

    length = lengths_ref[b]
    start = starts_ref[b]
    k_pos = s * block_size + jax.lax.broadcasted_iota(jnp.int32, (1, block_size), 1)
    valid = (k_pos >= start) & (k_pos < length)   # (1, block_size)

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                     # (G, block_size)
    scores = jnp.where(valid, scores, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.max(scores, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(scores - m_new)
    p = jnp.where(valid, p, 0.0)
    corr = jnp.exp(m_prev - m_new)

    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(s == n_s - 1)
    def _finalize():
        l = l_ref[...]
        out = acc_ref[...] / jnp.maximum(l, 1e-30)
        o_ref[0, 0] = out.astype(o_ref.dtype)
        lse_ref[0, 0] = (m_ref[...] + jnp.log(jnp.maximum(l, 1e-30))).astype(
            lse_ref.dtype
        )


def paged_decode_attention_pallas(
    q: jax.Array,             # (B, Hkv, G, D) — GQA group packed into sublanes
    k_pool: jax.Array,        # (N_blocks, Hkv, block_size, D)
    v_pool: jax.Array,        # (N_blocks, Hkv, block_size, D)
    block_tables: jax.Array,  # (B, max_blocks) int32, physical block ids
    lengths: jax.Array,       # (B,) int32
    *,
    scale: float,
    starts: jax.Array | None = None,    # (B,) int32 first hot position
    k_scale: jax.Array | None = None,   # (N_blocks, Hkv, block_size) f32
    v_scale: jax.Array | None = None,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns ``(out (B,Hkv,G,D), lse (B,Hkv,G,1) f32)``."""
    B, Hkv, G, D = q.shape
    _, _, block_size, _ = k_pool.shape
    MB = block_tables.shape[1]
    quantized = k_scale is not None
    if starts is None:
        starts = jnp.zeros((B,), jnp.int32)

    def _q_idx(b, h, s, tables, lens, st):
        return (b, h, 0, 0)

    def _kv_idx(b, h, s, tables, lens, st):
        return (tables[b, s], h, 0, 0)

    def _scale_idx(b, h, s, tables, lens, st):
        return (tables[b, s], h, 0)

    in_specs = [
        pl.BlockSpec((1, 1, G, D), _q_idx),
        pl.BlockSpec((1, 1, block_size, D), _kv_idx),
        pl.BlockSpec((1, 1, block_size, D), _kv_idx),
    ]
    operands = [q, k_pool, v_pool]
    if quantized:
        in_specs += [
            pl.BlockSpec((1, 1, block_size), _scale_idx),
            pl.BlockSpec((1, 1, block_size), _scale_idx),
        ]
        operands += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Hkv, MB),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, G, D), _q_idx),
            pl.BlockSpec((1, 1, G, 1), _q_idx),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _paged_decode_kernel, scale=scale, block_size=block_size,
        quantized=quantized,
    )
    out, lse = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
            jax.ShapeDtypeStruct((B, Hkv, G, 1), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
    )(block_tables, lengths, starts.astype(jnp.int32), *operands)
    return out, lse
