"""Pallas TPU causal flash attention (prefill/train path).

Standard blocked online-softmax flash attention; GQA is handled in the
BlockSpec index maps (the KV block index maps q-head -> q_head // group),
so no KV replication materializes in HBM.

Grid: (B, Hq, NQ, NK) with NK innermost; causally-skipped KV blocks
contribute nothing (masked) — the index arithmetic keeps the common
diagonal path hot.

``q_offset`` (scalar-prefetch operand, SMEM) shifts the absolute position
of q[:, 0] for chunked-prefill continuation: a (Sq, Sk) = (chunk, cache)
call attends the chunk against all earlier cache positions while staying
causal inside the chunk.  It is a traced scalar — serving one prompt at
many offsets reuses a single compiled kernel.

Optional ``k_scale``/``v_scale`` ((B, Hkv, Sk) f32, one absmax scale per
stored KV vector) mark the K/V operands as int8/fp8 payloads: the kernel
dequantizes right after the HBM->VMEM load, so a quantized KV window
streams at 1 byte/elem and widens to f32 only in VMEM (the tiered-KV
counterpart of the paged decode kernel's quantized pools).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


def _flash_kernel(
    off_ref,  # SMEM (1,) int32 — absolute position of q[:, 0]
    q_ref,    # (1, 1, BQ, D)
    k_ref,    # (1, 1, BK, D)
    v_ref,    # (1, 1, BK, D)
    *rest,    # [ks_ref, vs_ref (1, 1, BK),] o_ref, m/l/acc scratch
    scale: float,
    block_q: int,
    block_k: int,
    causal: bool,
    quantized: bool,
):
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    n_k = pl.num_programs(3)
    off = off_ref[0]

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        if quantized:
            # per-vector absmax scales: dequant right after the VMEM load
            k = k * ks_ref[0, 0][:, None]
            v = v * vs_ref[0, 0][:, None]
        scores = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                              # (BQ, BK)
        if causal:
            q_pos = off + iq * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0
            )
            k_pos = ik * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1
            )
            scores = jnp.where(q_pos >= k_pos, scores, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1, keepdims=True))
        p = jnp.exp(scores - m_new)
        if causal:
            p = jnp.where(m_new > NEG_INF / 2, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    if causal:
        # skip fully-masked blocks (k block entirely in the future);
        # dynamic in `off` — a traced predicate, not a grid prune
        @pl.when(ik * block_k <= off + iq * block_q + block_q - 1)
        def _():
            _compute()
    else:
        _compute()

    @pl.when(ik == n_k - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,   # (B, Hq, Sq, D)
    k: jax.Array,   # (B, Hkv, Sk, D)
    v: jax.Array,   # (B, Hkv, Sk, D)
    *,
    scale: float,
    causal: bool = True,
    q_offset: jax.Array | int = 0,
    k_scale: jax.Array | None = None,   # (B, Hkv, Sk) f32
    v_scale: jax.Array | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = Hq // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    off = jnp.asarray(q_offset, jnp.int32).reshape(1)
    quantized = k_scale is not None

    def _q_idx(b, h, iq, ik, off):
        return (b, h, iq, 0)

    def _kv_idx(b, h, iq, ik, off):
        return (b, h // G, ik, 0)

    def _scale_idx(b, h, iq, ik, off):
        return (b, h // G, ik)

    in_specs = [
        pl.BlockSpec((1, 1, block_q, D), _q_idx),
        pl.BlockSpec((1, 1, block_k, D), _kv_idx),
        pl.BlockSpec((1, 1, block_k, D), _kv_idx),
    ]
    operands = [q, k, v]
    if quantized:
        in_specs += [
            pl.BlockSpec((1, 1, block_k), _scale_idx),
            pl.BlockSpec((1, 1, block_k), _scale_idx),
        ]
        operands += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hq, Sq // block_q, Sk // block_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, block_q, D), _q_idx),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, quantized=quantized,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
    )(off, *operands)
