"""Pure-jnp oracles for the Pallas kernels (naive, O(S^2) memory).

Tiered-KV additions: per-vector absmax KV quantization helpers
(:func:`kv_quantize` / :func:`kv_dequantize` — the single definition the
device scatter path and the kernels' oracle params share), ``starts``
windows and ``return_lse`` variants on the decode oracles, and
:func:`lse_merge` — the log-sum-exp combination of partial attention
outputs the HGCA-style hybrid (hot device kernel + cold host oracle)
is validated against.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30

# kv_dtype name -> (storage dtype, absmax quantization range)
KV_DTYPES = {
    "fp8": (jnp.float8_e4m3fn, 448.0),
    "int8": (jnp.int8, 127.0),
}


def kv_quantize(x: jax.Array, kv_dtype: str) -> tuple[jax.Array, jax.Array]:
    """Per-vector absmax quantization over the trailing (head_dim) axis:
    ``x (..., D)`` -> ``(payload (..., D) int8|fp8, scale (...) f32)``
    with ``payload * scale ~= x``.  An all-zero vector gets scale 0."""
    dtype, qmax = KV_DTYPES[kv_dtype]
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = amax / qmax
    q = x.astype(jnp.float32) / jnp.maximum(scale[..., None], 1e-30)
    if kv_dtype == "int8":
        q = jnp.clip(jnp.round(q), -qmax, qmax)
    return q.astype(dtype), scale


def kv_dequantize(payload: jax.Array, scale: jax.Array,
                  out_dtype=jnp.bfloat16) -> jax.Array:
    """Inverse of :func:`kv_quantize`: ``payload (..., D)`` * ``scale
    (...)`` -> ``(..., D)`` in ``out_dtype``."""
    return (payload.astype(jnp.float32) * scale[..., None].astype(jnp.float32)
            ).astype(out_dtype)


def naive_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    q_offset: int | None = None,
    k_scale: jax.Array | None = None,   # (B, Sk, Hkv) f32
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """q (B,Sq,Hq,D), k/v (B,Sk,Hkv,D) -> (B,Sq,Hq,Dv).  fp32 softmax.

    ``q_offset`` places q[:, 0] at an absolute position (chunked-prefill
    continuation); default keeps the historical right-aligned causal mask
    (offset ``Sk - Sq``).  ``k_scale``/``v_scale`` dequantize int8/fp8
    K/V payloads per stored vector."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, G, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if k_scale is not None:
        kf = kf * k_scale.astype(jnp.float32)[..., None]
        vf = vf * v_scale.astype(jnp.float32)[..., None]
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) * scale
    if causal:
        off = Sk - Sq if q_offset is None else q_offset
        q_pos = off + jnp.arange(Sq, dtype=jnp.int32)[:, None]
        mask = q_pos >= jnp.arange(Sk, dtype=jnp.int32)[None, :]
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return o.reshape(B, Sq, Hq, vf.shape[-1]).astype(q.dtype)


def gather_paged_cache(pool: jax.Array, block_tables: jax.Array) -> jax.Array:
    """(N_blocks, Hkv, block_size, D) kernel-native pool + (B, max_blocks)
    tables -> contiguous dense-layout (B, max_blocks*block_size, Hkv, D)
    cache, positions in logical order.  The single definition of the
    block-table gather the non-Pallas paths rely on."""
    N, Hkv, bs, D = pool.shape
    B, MB = block_tables.shape
    return jnp.swapaxes(pool[block_tables], 2, 3).reshape(B, MB * bs, Hkv, D)


def gather_paged_scales(spool: jax.Array, block_tables: jax.Array) -> jax.Array:
    """(N_blocks, Hkv, block_size) scale pool + (B, max_blocks) tables ->
    dense-layout (B, max_blocks*block_size, Hkv) scales."""
    N, Hkv, bs = spool.shape
    B, MB = block_tables.shape
    return jnp.swapaxes(spool[block_tables], 2, 3).reshape(B, MB * bs, Hkv)


def paged_decode_attention(
    q: jax.Array,             # (B, Hq, D)
    k_pool: jax.Array,        # (N_blocks, Hkv, block_size, D) — kernel-native
    v_pool: jax.Array,        # (N_blocks, Hkv, block_size, D)
    block_tables: jax.Array,  # (B, max_blocks) int32
    lengths: jax.Array,       # (B,)
    *,
    scale: float | None = None,
    starts: jax.Array | None = None,
    k_scale: jax.Array | None = None,   # (N_blocks, Hkv, block_size) f32
    v_scale: jax.Array | None = None,
    return_lse: bool = False,
):
    """Oracle for the paged kernel: gather each sequence's blocks into a
    contiguous cache, then run the dense decode oracle.  Positions beyond
    ``lengths`` (including whatever the null block holds) — and below
    ``starts`` when given — are masked there.  Quantized pools are
    dequantized after the gather via the per-vector scale pools."""
    k = gather_paged_cache(k_pool, block_tables).astype(jnp.float32)
    v = gather_paged_cache(v_pool, block_tables).astype(jnp.float32)
    if k_scale is not None:
        k = k * gather_paged_scales(k_scale, block_tables)[..., None]
        v = v * gather_paged_scales(v_scale, block_tables)[..., None]
    return naive_decode_attention(q, k, v, lengths, scale=scale,
                                  starts=starts, return_lse=return_lse)


def naive_decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    *,
    scale: float | None = None,
    starts: jax.Array | None = None,
    return_lse: bool = False,
):
    """q (B,Hq,D), caches (B,S,Hkv,D), lengths (B,) -> (B,Hq,D).

    ``starts`` (B,) masks positions below it (a hot/cold attention
    window); ``return_lse`` additionally returns the per-row
    log-sum-exp ``(B, Hkv, G) f32`` for :func:`lse_merge`.  A row with
    no valid positions yields output 0 and lse <= NEG_INF (never NaN)."""
    B, Hq, D = q.shape
    _, S, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, D)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, kf) * scale
    pos = jnp.arange(S, dtype=jnp.int32)
    mask = pos[None] < lengths[:, None]            # (B,S)
    if starts is not None:
        mask &= pos[None] >= starts[:, None]
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(mask[:, None, None], jnp.exp(s - m), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, vf) / jnp.maximum(l, 1e-30)
    out = o.reshape(B, Hq, vf.shape[-1]).astype(q.dtype)
    if return_lse:
        lse = (m + jnp.log(jnp.maximum(l, 1e-30)))[..., 0]   # (B, Hkv, G)
        return out, lse
    return out


def lse_merge(parts: list) -> jax.Array:
    """Combine partial attention outputs over disjoint KV windows.

    ``parts`` is a list of ``(out (B,Hq,D), lse (B,Hkv,G))`` pairs, each
    the softmax-normalized attention over its own window; the exact
    combined attention is the lse-softmax-weighted sum.  Windows with no
    valid positions carry ``lse <= NEG_INF`` and get weight ~0; if every
    window is empty the result is 0 (never NaN)."""
    outs = jnp.stack([o.astype(jnp.float32) for o, _ in parts])  # (P,B,Hq,D)
    lses = jnp.stack([l.astype(jnp.float32) for _, l in parts])  # (P,B,Hkv,G)
    m = jnp.max(lses, axis=0)
    w = jnp.exp(lses - m[None])                                  # (P,B,Hkv,G)
    w = w / jnp.maximum(jnp.sum(w, axis=0), 1e-30)[None]
    P, B, Hkv, G = lses.shape
    wf = w.reshape(P, B, Hkv * G, 1)
    return jnp.sum(outs * wf, axis=0).astype(parts[0][0].dtype)
