"""Pure-jnp oracles for the Pallas kernels (naive, O(S^2) memory)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def naive_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    q_offset: int | None = None,
) -> jax.Array:
    """q (B,Sq,Hq,D), k/v (B,Sk,Hkv,D) -> (B,Sq,Hq,Dv).  fp32 softmax.

    ``q_offset`` places q[:, 0] at an absolute position (chunked-prefill
    continuation); default keeps the historical right-aligned causal mask
    (offset ``Sk - Sq``)."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, G, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) * scale
    if causal:
        off = Sk - Sq if q_offset is None else q_offset
        q_pos = off + jnp.arange(Sq, dtype=jnp.int32)[:, None]
        mask = q_pos >= jnp.arange(Sk, dtype=jnp.int32)[None, :]
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return o.reshape(B, Sq, Hq, vf.shape[-1]).astype(q.dtype)


def gather_paged_cache(pool: jax.Array, block_tables: jax.Array) -> jax.Array:
    """(N_blocks, Hkv, block_size, D) kernel-native pool + (B, max_blocks)
    tables -> contiguous dense-layout (B, max_blocks*block_size, Hkv, D)
    cache, positions in logical order.  The single definition of the
    block-table gather the non-Pallas paths rely on."""
    N, Hkv, bs, D = pool.shape
    B, MB = block_tables.shape
    return jnp.swapaxes(pool[block_tables], 2, 3).reshape(B, MB * bs, Hkv, D)


def paged_decode_attention(
    q: jax.Array,             # (B, Hq, D)
    k_pool: jax.Array,        # (N_blocks, Hkv, block_size, D) — kernel-native
    v_pool: jax.Array,        # (N_blocks, Hkv, block_size, D)
    block_tables: jax.Array,  # (B, max_blocks) int32
    lengths: jax.Array,       # (B,)
    *,
    scale: float | None = None,
) -> jax.Array:
    """Oracle for the paged kernel: gather each sequence's blocks into a
    contiguous cache, then run the dense decode oracle.  Positions beyond
    ``lengths`` (including whatever the null block holds) are masked
    there."""
    k = gather_paged_cache(k_pool, block_tables)
    v = gather_paged_cache(v_pool, block_tables)
    return naive_decode_attention(q, k, v, lengths, scale=scale)


def naive_decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    *,
    scale: float | None = None,
) -> jax.Array:
    """q (B,Hq,D), caches (B,S,Hkv,D), lengths (B,) -> (B,Hq,D)."""
    B, Hq, D = q.shape
    _, S, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, D)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, kf) * scale
    mask = jnp.arange(S)[None] < lengths[:, None]  # (B,S)
    s = jnp.where(mask[:, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, vf)
    return o.reshape(B, Hq, vf.shape[-1]).astype(q.dtype)
