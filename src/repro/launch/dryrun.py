import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell the production mesh is built from 512 placeholder host
devices (the two lines above MUST precede any jax import), the full-size
model is lowered against ShapeDtypeStruct inputs (no allocation), compiled,
and the artifact is analyzed:

  * ``compiled.memory_analysis()``  -> bytes/device (proves it fits)
  * ``compiled.cost_analysis()``    -> XLA's per-device FLOPs/bytes
  * ``analysis.hlo_cost``           -> trip-count-corrected FLOPs/bytes +
                                       collective bytes by kind
  * ``analysis.roofline``           -> the three roofline terms

Results are written as one JSON per cell under --out (resumable).

Usage:
  python -m repro.launch.dryrun --arch yi-34b --shape decode_32k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse
import json
import time
import traceback

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.analysis import hlo_cost
from repro.analysis.roofline import roofline
from repro.configs import SHAPES, all_arch_ids, get_config, shapes_for
from repro.configs.base import ParallelConfig, RunConfig, TrainConfig
from repro.core import balance
from repro.core.pipeline import pipelined_step
from repro.core.placement import Env
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh, mesh_axes
from repro.models.registry import build_model
from repro.training.trainer import make_train_step


def named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def build_env(cfg, shape, axes, args) -> Env:
    if shape.kind == "decode" and args.offload != "none":
        kv_policy = args.kv_policy or balance.plan(cfg, shape, axes).kv_policy
    else:
        kv_policy = args.kv_policy or "batch"
    # auto context-parallelism when q heads don't divide the model axis
    # (otherwise attention compute would replicate across `model`)
    seq_par = args.sequence_parallel
    if shape.kind in ("train", "prefill") and cfg.n_heads % axes.get("model", 1):
        seq_par = True
    return Env(
        axes=axes,
        kv_policy=kv_policy,
        offload=args.offload,
        sub_batches=args.sub_batches,
        sequence_parallel=seq_par,
        fsdp=(shape.kind == "train" and not args.no_fsdp),
        # inference of big MoE: DeepSeek-style wide EP (experts over all
        # chips) — weights would otherwise replicate over `data`
        ep_wide=(shape.kind != "train" and cfg.moe is not None
                 and args.offload == "hpu"),
        bf16_combine=args.bf16_combine,
        moe_a2a=args.moe_a2a,
    )


def lower_cell(arch: str, shape_name: str, multi_pod: bool, args):
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = mesh_axes(mesh)
    cfg = get_config(arch)
    if args.kv_quant and cfg.family == "dense":
        cfg = cfg.with_overrides(kv_quant=True)
    shape = SHAPES[shape_name]
    env = build_env(cfg, shape, axes, args)
    model = build_model(cfg, env)

    t0 = time.time()
    train_accum = 0
    if shape.kind == "train":
        accum = args.grad_accum
        if accum <= 0:
            # auto: keep per-device layer-boundary activations ~<= 6 GB
            # (remat stores one (B_micro/dev, S, D) tensor per layer)
            dp = axes.get("pod", 1) * axes.get("data", 1)
            b_dev = max(shape.global_batch // dp, 1)
            act = cfg.n_layers * b_dev * shape.seq_len * cfg.d_model * 2
            accum = 1
            while act / accum > 3e9 and accum < b_dev:
                accum *= 2
        train_accum = accum
        run = RunConfig(
            model=cfg,
            parallel=ParallelConfig(
                zero_stage=1,
                grad_accum=accum,
                grad_accum_dtype=args.grad_accum_dtype,
                optimizer_dtype="float32" if model.n_params() < 5e10 else "bfloat16",
            ),
            train=TrainConfig(),
        )
        init_state, train_step, state_specs, state_shapes = make_train_step(model, run)
        state_sds = state_shapes()
        batch_sds = S.train_batch_specs(cfg, shape)
        state_sh = named(mesh, state_specs())
        batch_sh = S.batch_shardings(cfg, batch_sds, env, mesh)
        with mesh:
            metrics_shape = jax.eval_shape(train_step, state_sds, batch_sds)[1]
            metrics_sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), metrics_shape)
            lowered = jax.jit(
                train_step,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, metrics_sh),
                donate_argnums=(0,),
            ).lower(state_sds, batch_sds)
    elif shape.kind == "prefill":
        tokens, cache, embeds = S.prefill_inputs(model, shape)
        params_sh = named(mesh, model.param_specs())
        cache_sh = S.cache_shardings(model, cache, mesh)
        tok_sh = NamedSharding(mesh, env.act_spec(("batch", None), tokens.shape))
        params_sds = model.param_shapes()
        in_shard = [params_sh, tok_sh, cache_sh]
        lower_args = [params_sds, tokens, cache]
        fn = model.prefill
        if embeds is not None:
            emb_sh = NamedSharding(mesh, env.act_spec(("batch", None, None), embeds.shape))
            in_shard.append(emb_sh)
            lower_args.append(embeds)
            def fn(p, t, c, e):
                return model.prefill(p, t, c, embeds=e)
        logits_sh = NamedSharding(
            mesh, env.act_spec(("batch", "vocab"), (shape.global_batch, cfg.padded_vocab()))
        )
        with mesh:
            lowered = jax.jit(
                fn,
                in_shardings=tuple(in_shard),
                out_shardings=(logits_sh, cache_sh),
                donate_argnums=(2,),
            ).lower(*lower_args)
    else:  # decode
        cache, tokens = S.decode_inputs(model, shape)
        params_sh = named(mesh, model.param_specs())
        cache_sh = S.cache_shardings(model, cache, mesh)
        tok_sh = NamedSharding(mesh, env.act_spec(("batch",), tokens.shape))
        logits_sh = NamedSharding(
            mesh, env.act_spec(("batch", "vocab"), (shape.global_batch, cfg.padded_vocab()))
        )
        params_sds = model.param_shapes()
        step = pipelined_step(model.decode_step, env.sub_batches)
        with mesh:
            lowered = jax.jit(
                step,
                in_shardings=(params_sh, cache_sh, tok_sh),
                out_shardings=(logits_sh, cache_sh),
                donate_argnums=(1,),
            ).lower(params_sds, cache, tokens)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    walker = hlo_cost.analyze(compiled.as_text())
    n_chips = mesh.devices.size
    rf = roofline(
        cfg, shape, n_chips, walker.flops, walker.bytes,
        dict(walker.coll_by_kind), n_params=model.n_params(),
    )

    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "n_chips": n_chips,
        "kind": shape.kind,
        "env": {
            "grad_accum": train_accum,
            "ep_wide": env.ep_wide,
            "kv_policy": env.kv_policy,
            "offload": env.offload,
            "sub_batches": env.sub_batches,
            "sequence_parallel": env.sequence_parallel,
            "fsdp": env.fsdp,
        },
        "n_params": model.n_params(),
        "time_lower_s": round(t_lower, 2),
        "time_compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes_per_dev": mem.argument_size_in_bytes,
            "output_bytes_per_dev": mem.output_size_in_bytes,
            "temp_bytes_per_dev": mem.temp_size_in_bytes,
            "alias_bytes_per_dev": mem.alias_size_in_bytes,
            "peak_bytes_per_dev": mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "xla_cost": {
            "flops_per_dev": ca.get("flops", -1.0),
            "bytes_per_dev": ca.get("bytes accessed", -1.0),
        },
        "walker": {
            "flops_per_dev": walker.flops,
            "bytes_per_dev": walker.bytes,
            "coll_bytes_per_dev": walker.coll_bytes,
            "coll_by_kind": dict(walker.coll_by_kind),
            "coll_count": walker.coll_count,
        },
        "roofline": rf.as_dict(),
    }


def cell_id(arch, shape, mesh_kind, args):
    tag = ""
    if args.kv_policy:
        tag += f".kv_{args.kv_policy}"
    if args.offload != "hpu":
        tag += f".off_{args.offload}"
    if args.sub_batches != 1:
        tag += f".sub{args.sub_batches}"
    if args.sequence_parallel:
        tag += ".sp"
    if args.bf16_combine:
        tag += ".bfc"
    if args.moe_a2a:
        tag += ".a2a"
    if args.no_fsdp:
        tag += ".nofsdp"
    if args.grad_accum_dtype != "float32":
        tag += ".ga_bf16"
    if args.kv_quant:
        tag += ".kvq8"
    if args.grad_accum > 0:
        tag += f".ga{args.grad_accum}"
    return f"{arch}.{shape}.{mesh_kind}{tag}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--kv-policy", dest="kv_policy", default=None,
                    choices=[None, "batch", "head", "sequence", "batch_seq"])
    ap.add_argument("--offload", default="hpu", choices=["hpu", "none"])
    ap.add_argument("--sub-batches", dest="sub_batches", type=int, default=1)
    ap.add_argument("--sequence-parallel", dest="sequence_parallel", action="store_true")
    ap.add_argument("--bf16-combine", dest="bf16_combine", action="store_true")
    ap.add_argument("--moe-a2a", dest="moe_a2a", action="store_true")
    ap.add_argument("--no-fsdp", dest="no_fsdp", action="store_true")
    ap.add_argument("--grad-accum-dtype", dest="grad_accum_dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--kv-quant", dest="kv_quant", action="store_true")
    ap.add_argument("--grad-accum", dest="grad_accum", type=int, default=0)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = all_arch_ids() if (args.all or not args.arch) else [args.arch]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_ok = n_fail = n_skip = 0
    for arch in archs:
        cfg = get_config(arch)
        shape_names = (
            [args.shape] if args.shape else [s.name for s in shapes_for(cfg)]
        )
        for shape_name in shape_names:
            if shape_name == "long_500k" and not cfg.subquadratic:
                print(f"SKIP {arch} x long_500k (full attention; DESIGN.md §4)")
                continue
            for mesh_kind in meshes:
                cid = cell_id(arch, shape_name, mesh_kind, args)
                path = os.path.join(args.out, cid + ".json")
                if os.path.exists(path) and not args.force:
                    n_skip += 1
                    continue
                print(f"=== {cid} ===", flush=True)
                try:
                    rec = lower_cell(arch, shape_name, mesh_kind == "multi", args)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    r = rec["roofline"]
                    print(
                        f"  ok  lower={rec['time_lower_s']}s compile={rec['time_compile_s']}s "
                        f"peak/dev={rec['memory']['peak_bytes_per_dev']/2**30:.2f}GiB "
                        f"bottleneck={r['bottleneck']} frac={r['roofline_frac']:.3f}",
                        flush=True,
                    )
                    n_ok += 1
                except Exception as e:
                    n_fail += 1
                    with open(path + ".err", "w") as f:
                        f.write(traceback.format_exc())
                    print(f"  FAIL {type(e).__name__}: {e}", flush=True)
    print(f"done ok={n_ok} fail={n_fail} skip={n_skip}")


if __name__ == "__main__":
    main()
