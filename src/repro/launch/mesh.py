"""Production mesh construction.

A function (NOT a module-level constant) so importing this module never
touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init to obtain placeholder devices.

Single pod: 16 x 16 = 256 chips (TPU v5e pod), axes (data, model).
Multi-pod: 2 x 16 x 16 = 512 chips, axes (pod, data, model) — the `pod`
axis carries only data parallelism (gradient all-reduce / batch sharding),
matching DCN-connected pods.
"""
from __future__ import annotations

import jax
import numpy as np


def compat_mesh(shape, axes):
    """``jax.make_mesh`` with explicit-Auto axis types where the installed
    jax supports them (``jax.sharding.AxisType`` arrived after 0.4.x);
    older versions treat every axis as Auto already."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Whatever this host actually has (CPU tests / reduced runs)."""
    n = jax.device_count()
    mp = model_parallel if n % model_parallel == 0 else 1
    return compat_mesh((n // mp, mp), ("data", "model"))


def mesh_axes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def replica_meshes(n_replicas: int, model_parallel: int = 1) -> list:
    """One (data, model) mesh per serving replica.

    Partitions this host's devices into ``n_replicas`` disjoint
    contiguous slices so each cluster replica (e.g. a disaggregated
    prefill or decode engine) owns its own devices.  When the host
    cannot be split that way — fewer devices than replicas, or a
    non-divisible count, i.e. the single-device CPU test environment —
    every replica shares the one host mesh instead, which keeps the
    cluster tier runnable anywhere at the cost of device isolation.
    """
    if n_replicas < 1:
        raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
    n = jax.device_count()
    if n % n_replicas != 0 or n < n_replicas:
        return [make_host_mesh(model_parallel)] * n_replicas
    per = n // n_replicas
    mp = model_parallel if per % model_parallel == 0 else 1
    devices = jax.devices()
    meshes = []
    for i in range(n_replicas):
        sl = devices[i * per:(i + 1) * per]
        grid = np.asarray(sl).reshape(per // mp, mp)
        meshes.append(jax.sharding.Mesh(grid, ("data", "model")))
    return meshes
