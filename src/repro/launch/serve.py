"""End-to-end serving driver: continuous batching with offloaded decode.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
      --requests 16 --slots 4 --max-new 12

Add ``--cache paged [--block-size 16] [--blocks N]`` to serve from the
paged block pool (admission gated on free blocks, prefix sharing,
preemption under block pressure) instead of the dense per-slot cache.
``--kv-dtype {fp8,int8}`` stores the pool quantized with per-vector
scales (~2x effective KV capacity per device byte), and
``--host-blocks N`` adds a host KV tier: cold prefix blocks spill there
instead of forcing preemption, and spilled sequences keep decoding via
LSE-merged hybrid attention over the split hot/cold KV.

Add ``--schedule hybrid [--prefill-chunk 32] [--token-budget N]`` to run
the token-budget scheduler: each iteration fuses a bucket-padded prefill
chunk of the head-of-queue prompt into the decode batch (Sarathi-style
chunked prefill — the paper's compute/bandwidth co-processing expressed
as one model step), instead of whole-prompt prefills that recompile per
prompt length and stall decode.

``--async on`` (the default) runs the dispatch-ahead pipeline: sampling
happens on device inside the fused step and iteration *t+1* is
dispatched before *t*'s tokens are observed, so the device never idles
on the host round-trip.  ``--async off`` is the conservative synchronous
fallback (greedy outputs are token-identical either way).  Sampling is
picked with ``--sample {greedy,temperature,top-k}`` plus
``--temperature`` / ``--top-k`` values.

``--spec-depth K`` turns on speculative multi-token decoding: a
reduced-scale draft model (``--draft ARCH``, default a reduced variant
of ``--arch``) proposes K tokens per decode slot each step and the
target verifies all K+1 positions in one fused pass, committing the
accepted prefix device-to-device.  Greedy outputs are token-identical
to non-speculative serving; with temperature, rejection sampling keeps
every emitted token an exact sample from the target distribution.

Add ``--replicas N [--route round_robin|least_loaded|prefix_affinity]``
to serve from a :class:`~repro.serving.cluster.Cluster` of N engine
replicas behind a shared global queue: the router places each request on
the first replica (in policy order) that can admit it now, spilling over
when the first choice is saturated.  ``prefix_affinity`` (paged cache
only in effect) routes shared-prompt traffic to the replica already
holding its prefix blocks.

``--role-map SPEC`` disaggregates the cluster into prefill/decode
replicas (``1p+1d``, ``2p+2d``, ``2p+1d+1m``, or an explicit comma list
like ``prefill,decode``): prompts are admitted to prefill-role replicas
and their KV blocks migrate to the least-loaded decode-role replica when
the last prefill chunk completes.  ``--decode-slots N`` gives the
decode-role replicas a larger slot count than ``--slots`` (their block
budget scales along).  When the host has enough devices each replica is
placed on its own mesh slice; otherwise all replicas share the host mesh.

Observability (see ``docs/observability.md``):

* ``--workload {random,poisson,bursty,chat-fan,rag,agentic}`` replaces
  the all-at-round-0 prompt list with a seeded arrival process played by
  :class:`~repro.serving.workload.WorkloadDriver` (``--arrival-rate``,
  ``--fan``, ``--turns``, ``--workload-seed`` shape it);
* ``--slo-ttft N`` / ``--slo-tpot M`` declare engine-step SLO targets:
  the run reports sliding-window p50/p99, attainment fraction and
  goodput, and the trace gains ``slo_breach`` marks;
* ``--profile N`` samples every Nth dispatch with a fenced wall-clock
  measurement (``1`` = sync mode, times everything; ``0`` = off;
  default: 8 when ``--trace`` is given) and joins it with the analytic
  cost model into measured MFU/MBU/bandwidth counter tracks;
* ``--dashboard N`` prints a terminal snapshot every N rounds.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import SHAPES, get_config
from repro.configs.reduced import reduce_config
from repro.core import balance
from repro.core.oi import DEVICES
from repro.core.placement import Env
from repro.launch.mesh import make_host_mesh, mesh_axes, replica_meshes
from repro.models.registry import build_model
from repro.serving.cluster import ROUTE_POLICIES, Cluster, parse_roles
from repro.serving.engine import Engine
from repro.serving.sampler import SamplerConfig
from repro.serving.telemetry import (
    SLOMonitor,
    Tracer,
    cluster_registry,
    engine_registry,
    make_profiler,
    render_dashboard,
    write_metrics,
    write_trace,
)
from repro.serving.workload import WORKLOADS, WorkloadDriver, build_workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--sub-batches", type=int, default=1)
    ap.add_argument("--async", dest="async_mode", choices=("on", "off"),
                    default="on",
                    help="on: dispatch-ahead pipeline with on-device "
                         "sampling; off: synchronous fallback (greedy "
                         "token-identical)")
    ap.add_argument("--sample", choices=("greedy", "temperature", "top-k"),
                    default=None,
                    help="sampling mode (temperature/top-k use the values "
                         "of --temperature / --top-k); default: greedy, or "
                         "top-k when --temperature > 0 is passed")
    ap.add_argument("--temperature", type=float, default=None,
                    help="softmax temperature (default 1.0 when --sample "
                         "temperature/top-k is given, else greedy)")
    ap.add_argument("--top-k", type=int, default=40,
                    help="top-k truncation for --sample top-k")
    ap.add_argument("--cache", choices=("dense", "paged"), default="dense")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged: tokens per physical KV block")
    ap.add_argument("--blocks", type=int, default=None,
                    help="paged: pool size incl. null block "
                         "(default: dense-equivalent budget)")
    ap.add_argument("--kv-dtype", choices=("bf16", "fp8", "int8"),
                    default="bf16",
                    help="paged: KV block storage dtype; fp8/int8 store "
                         "quantized blocks with per-vector scales (~2x KV "
                         "capacity at the same device byte budget)")
    ap.add_argument("--host-blocks", type=int, default=0,
                    help="paged: host-tier KV blocks; cold shared-prefix "
                         "blocks spill here instead of forcing preemption, "
                         "and spilled sequences keep decoding via LSE-merged "
                         "hybrid attention")
    ap.add_argument("--schedule", choices=("decode-only", "hybrid"),
                    default="decode-only",
                    help="hybrid: fuse chunked prefill into decode steps")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="hybrid: max prompt tokens prefilled per step")
    ap.add_argument("--token-budget", type=int, default=None,
                    help="hybrid: per-step token budget "
                         "(default: slots + prefill_chunk)")
    ap.add_argument("--spec-depth", type=int, default=0,
                    help="speculative decoding: draft tokens proposed per "
                         "decode step (0 = off); each step verifies k+1 "
                         "positions in one fused target pass")
    ap.add_argument("--draft", default=None, metavar="ARCH",
                    help="draft model architecture for --spec-depth > 0 "
                         "(default: a reduced-config variant of --arch; "
                         "always instantiated at reduced scale so the "
                         "draft stays cheap relative to the target)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replicas behind the shared global queue")
    ap.add_argument("--route", choices=ROUTE_POLICIES, default="round_robin",
                    help="replica routing policy (with --replicas > 1)")
    ap.add_argument("--role-map", default=None, metavar="SPEC",
                    help="disaggregated replica roles: shorthand like "
                         "'1p+1d' / '2p+2d+1m' or a comma list like "
                         "'prefill,decode' (default: all mixed)")
    ap.add_argument("--decode-slots", type=int, default=None,
                    help="slot count override for decode-role replicas "
                         "(default: --slots; their paged block budget "
                         "scales along)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record request spans + step timeline and write a "
                         "Perfetto/Chrome-trace JSON here")
    ap.add_argument("--metrics-out", default=None, metavar="OUT.json",
                    help="write the metrics-registry snapshot as flat JSON")
    ap.add_argument("--workload", choices=WORKLOADS, default="random",
                    help="arrival-process shape (random = legacy: every "
                         "request at round 0)")
    ap.add_argument("--workload-seed", type=int, default=0,
                    help="seed for the workload generator (same seed = "
                         "byte-identical schedule)")
    ap.add_argument("--arrival-rate", type=float, default=0.5,
                    help="open-loop arrival rate in requests/round for "
                         "poisson/bursty/chat-fan/rag/agentic workloads")
    ap.add_argument("--fan", type=int, default=4,
                    help="chat-fan: requests sharing each prompt prefix")
    ap.add_argument("--turns", type=int, default=3,
                    help="agentic: total turns per session (each turn "
                         "resubmits with the prior output as grown prefix)")
    ap.add_argument("--slo-ttft", type=int, default=None, metavar="STEPS",
                    help="TTFT SLO target in engine steps; enables the "
                         "attainment/goodput report and slo_breach trace "
                         "marks")
    ap.add_argument("--slo-tpot", type=float, default=None, metavar="STEPS",
                    help="per-output-token SLO target in engine steps")
    ap.add_argument("--profile", type=int, default=None, metavar="N",
                    help="fence + wall-clock every Nth dispatch and join "
                         "with the analytic cost model into measured "
                         "MFU/MBU/bandwidth (1 = sync: every dispatch; "
                         "0 = off; default: 8 with --trace, else off)")
    ap.add_argument("--profile-device", choices=sorted(DEVICES),
                    default="TPU-V5E",
                    help="device peaks used for measured MFU/MBU")
    ap.add_argument("--dashboard", type=int, default=0, metavar="N",
                    help="print a terminal snapshot every N driver rounds "
                         "(queue depth, active slots, pipeline depth, pool "
                         "util, SLO attainment, measured MFU/MBU)")
    args = ap.parse_args()

    cfg = reduce_config(args.arch) if args.reduced else get_config(args.arch)
    mesh = make_host_mesh()
    axes = mesh_axes(mesh)
    plan = balance.plan(cfg, SHAPES["decode_32k"], axes or {"data": 1, "model": 1})
    print(f"balancer: policy={plan.kv_policy} sub_batches={plan.sub_batches} "
          f"bottleneck={plan.bottleneck} "
          f"(t_att={plan.t_attention*1e3:.2f}ms t_lin={plan.t_linear*1e3:.2f}ms)")
    env = Env(axes=axes if mesh.devices.size > 1 else {}, kv_policy=plan.kv_policy)
    model = build_model(cfg, env)
    params = model.init(jax.random.key(0))

    mode = args.sample
    if mode is None:
        # pre---sample behavior: a bare --temperature > 0 meant top-40
        mode = "greedy" if not args.temperature else "top-k"
    if mode == "greedy":
        sampler = SamplerConfig()
    else:
        # an explicit sampling mode must actually sample: temperature 0
        # would silently degrade to greedy (both samplers branch on it)
        temp = args.temperature if args.temperature else 1.0
        sampler = SamplerConfig(
            temperature=temp, top_k=args.top_k if mode == "top-k" else 0
        )
    engine_kw = dict(
        n_slots=args.slots, max_seq=args.max_seq,
        sampler=sampler,
        sub_batches=args.sub_batches,
        cache_kind=args.cache, block_size=args.block_size, n_blocks=args.blocks,
        kv_dtype=args.kv_dtype, host_blocks=args.host_blocks,
        schedule=args.schedule, prefill_chunk=args.prefill_chunk,
        token_budget=args.token_budget,
        async_mode=args.async_mode == "on",
    )
    if args.spec_depth:
        # the draft shares the target's tokenizer/vocab but runs at
        # reduced scale — proposal cost stays small next to the verify
        draft_cfg = reduce_config(args.draft or args.arch, vocab=cfg.vocab)
        draft_model = build_model(draft_cfg, env)
        engine_kw.update(
            spec_depth=args.spec_depth,
            draft_model=draft_model,
            draft_params=draft_model.init(jax.random.key(1)),
        )
    # SLO monitoring rides the tracer's lifecycle hooks, so declaring a
    # target implies a tracer even without --trace (nothing is written)
    slo = None
    if args.slo_ttft is not None or args.slo_tpot is not None:
        slo = SLOMonitor(ttft_target=args.slo_ttft, tpot_target=args.slo_tpot)
    tracer = Tracer(wall=True, slo=slo) if (args.trace or slo) else None
    sample_every = args.profile
    if sample_every is None:
        sample_every = 8 if args.trace else 0
    profiler = make_profiler(sample_every, device=args.profile_device)
    roles = parse_roles(args.role_map, args.replicas) if args.role_map else None
    role_kw = ({"decode": {"n_slots": args.decode_slots}}
               if args.decode_slots else None)
    model_factory = None
    if args.replicas > 1:
        meshes = replica_meshes(args.replicas)
        if len({id(m) for m in meshes}) > 1:
            # enough devices for disjoint per-replica mesh slices: give
            # each replica engine a model built against its own slice
            def model_factory(i, _meshes=meshes):
                ax = mesh_axes(_meshes[i])
                env_i = Env(
                    axes=ax if _meshes[i].devices.size > 1 else {},
                    kv_policy=plan.kv_policy,
                )
                return build_model(cfg, env_i)
    cluster = (
        Cluster(model, params, args.replicas, route=args.route, tracer=tracer,
                profiler=profiler if profiler.enabled else None,
                roles=roles, role_kw=role_kw, model_factory=model_factory,
                **engine_kw)
        if args.replicas > 1 else None
    )
    eng = (cluster.engines[0] if cluster
           else Engine(model, params, tracer=tracer,
                       profiler=profiler if profiler.enabled else None,
                       **engine_kw))
    serv = cluster if cluster else eng
    arrivals = build_workload(
        args.workload, args.requests, vocab=cfg.vocab, max_seq=args.max_seq,
        max_new=args.max_new, seed=args.workload_seed,
        rate=args.arrival_rate, fan=args.fan, turns=args.turns,
    )
    on_round = None
    if args.dashboard:
        def on_round(r, _every=args.dashboard):
            if r % _every == 0:
                print(render_dashboard(serv, r, slo=slo, profiler=profiler))
    driver = WorkloadDriver(serv, arrivals, vocab=cfg.vocab,
                            max_seq=args.max_seq, seed=args.workload_seed,
                            on_round=on_round)

    t0 = time.time()
    rounds = driver.run()
    dt = time.time() - t0
    stats = serv.stats() if cluster else eng.stats
    n_requests = len(driver.submitted)
    # all reported numbers flow through the metrics registry — the CLI
    # printout and the --metrics-out dump read the same snapshot
    registry = (
        cluster_registry(stats) if cluster
        else engine_registry(
            stats, eng.pool.stats if args.cache == "paged" else None
        )
    )
    if slo is not None:
        slo.register(registry, elapsed=rounds)
    if profiler.enabled:
        profiler.register(registry)
    snap = registry.snapshot()
    print(f"mode: async={args.async_mode} sample={mode} "
          f"(T={sampler.temperature} top_k={sampler.top_k})")
    print(f"workload: {args.workload} seed={args.workload_seed} "
          f"submitted={n_requests} resubmits={driver.resubmits} "
          f"rounds={rounds}")
    if cluster:
        role_str = (" roles=" + ",".join(cluster.roles)
                    if args.role_map else "")
        print(f"cluster: replicas={args.replicas} route={args.route}"
              f"{role_str}")
        print(f"requests={n_requests} {stats.summary()}")
        if stats.migrations:
            print(f"disagg: migrations={stats.migrations} "
                  f"refold_moves={stats.refold_moves} "
                  f"ttft_rounds mean {stats.mean_ttft_rounds:.1f} "
                  f"p99 {stats.ttft_rounds_percentile(99):.0f}")
        print(f"latency: TTFT mean {snap['mean_ttft_steps']:.1f} "
              f"p50 {snap['ttft_steps_p50']:.0f} "
              f"p99 {snap['ttft_steps_p99']:.0f} engine steps, "
              f"per-token p99 {snap['per_token_steps_p99']:.2f} steps")
        print(f"wall {dt:.2f}s -> {stats.generated/dt:.1f} tok/s")
        if args.cache == "paged":
            for i, e in enumerate(cluster.engines):
                print(f"pool[r{i}]: {e.pool.stats}")
    else:
        print(f"requests={n_requests} prefills={stats.prefills} "
              f"prefill_chunks={stats.prefill_chunks} "
              f"boundary_packs={stats.boundary_packs} "
              f"decode_steps={stats.decode_steps} "
              f"engine_steps={stats.engine_steps} "
              f"generated={stats.generated} peak_active={stats.peak_active}")
        if args.spec_depth:
            print(f"spec: depth={args.spec_depth} "
                  f"accept_rate={stats.acceptance_rate:.2f} "
                  f"drafted={stats.drafted_tokens} "
                  f"accepted={stats.accepted_tokens} "
                  f"spec_steps={stats.spec_steps}")
        print(f"latency: TTFT mean {snap['mean_ttft_steps']:.1f} "
              f"p50 {snap['ttft_steps_p50']:.0f} "
              f"p99 {snap['ttft_steps_p99']:.0f} engine steps, "
              f"{snap['tokens_per_step']:.2f} tokens/step")
        print(f"wall {dt:.2f}s -> {stats.generated/dt:.1f} tok/s "
              f"(batch efficiency "
              f"{stats.generated/max(stats.decode_steps*args.slots,1):.0%})")
        if args.cache == "paged":
            print(f"pool: {eng.pool.stats} kv_bytes={eng.kv_bytes()}")
            if args.host_blocks:
                print(f"kv tier: spills={stats.spills} "
                      f"rehydrations={stats.rehydrations} "
                      f"host_peak={eng.pool.stats.host_peak_in_use}"
                      f"/{args.host_blocks} blocks")
    if slo is not None:
        print(slo.describe())
        print(f"goodput: {slo.goodput(rounds):.2f} SLO-attaining "
              f"tokens/round over {rounds} rounds")
    if profiler.enabled:
        print(profiler.describe())
        for key, row in sorted(profiler.summary().items()):
            kind, bucket, batch = key
            print(f"  measured {kind:10s} bucket={bucket} batch={batch}: "
                  f"n={int(row['n'])} {row['seconds']*1e3:.2f}ms "
                  f"mfu={row['measured_mfu']:.4f} "
                  f"mbu={row['measured_mbu']:.4f} "
                  f"bw={row['achieved_gbps']:.1f}GB/s")
    if args.trace:
        path = write_trace(tracer, args.trace)
        print(f"trace: {path} (open at ui.perfetto.dev)")
    if args.metrics_out:
        path = write_metrics(
            registry, args.metrics_out,
            extra={"wall_s": dt, "rounds": float(rounds),
                   "requests": float(n_requests)},
        )
        print(f"metrics: {path}")


if __name__ == "__main__":
    main()
