"""ShapeDtypeStruct input stand-ins + shardings for every (arch x shape).

``input_specs`` returns weak-type-correct, shardable stand-ins with *no*
device allocation — the dry-run lowers against these.  Modality frontends
are stubs per the brief: internvl2 gets (B, F, D) patch embeddings,
seamless gets (B, F, D) frame embeddings.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.placement import Env
from repro.models.registry import Model

Pytree = Any


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    out = {
        "inputs": sds((B, S), jnp.int32),
        "targets": sds((B, S), jnp.int32),
        "mask": sds((B, S), jnp.float32),
    }
    if cfg.frontend == "patches":
        out["embeds"] = sds((B, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        out["src_embeds"] = sds((B, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
    return out


def batch_shardings(cfg: ModelConfig, shape_names: dict[str, Any], env: Env, mesh) -> Pytree:
    """Everything in a data batch shards on its leading (batch) axis."""

    def spec_for(s):
        logical = ("batch",) + (None,) * (len(s.shape) - 1)
        return NamedSharding(mesh, env.act_spec(logical, s.shape))

    return jax.tree.map(spec_for, shape_names)


def prefill_inputs(model: Model, shape: ShapeConfig):
    """(tokens, cache, embeds?) stand-ins for a prefill lowering."""
    cfg = model.cfg
    B, S = shape.global_batch, shape.seq_len
    n_front = cfg.frontend_len if cfg.frontend == "patches" else 0
    tokens = sds((B, S - n_front if n_front else S), jnp.int32)
    cache = model.cache_shapes(B, S)
    embeds = None
    if cfg.frontend == "patches":
        embeds = sds((B, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        embeds = sds((B, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
    return tokens, cache, embeds


def decode_inputs(model: Model, shape: ShapeConfig):
    """(cache, tokens) stand-ins for one serve_step with a full KV cache."""
    B, S = shape.global_batch, shape.seq_len
    cache = model.cache_shapes(B, S)
    tokens = sds((B,), jnp.int32)
    return cache, tokens


def cache_shardings(model: Model, cache_shapes: Pytree, mesh) -> Pytree:
    specs = model.cache_specs(1, 1)  # structure-only; resolve per-leaf below
    # cache_specs mirrors cache_defs structure; recompute with real shapes
    from repro.core.placement import kv_rules
    from repro.models import common as cm

    policy = model.env.kv_policy if model.env.offload == "hpu" else "none"
    # rebuild defs at the real shapes by matching keys
    def leaf_spec(defn):
        return NamedSharding(
            mesh,
            cm.resolve_spec(defn.logical, kv_rules(policy), model.env.axes, defn.shape),
        )

    return jax.tree.map(
        leaf_spec,
        model.cache_defs(*_cache_dims(cache_shapes)),
        is_leaf=cm.is_def,
    )


def _cache_dims(cache_shapes: Pytree) -> tuple[int, int]:
    B = cache_shapes["lengths"].shape[0]
    seq = 0
    for k, v in cache_shapes.items():
        if k in ("k", "v", "ckv", "krope") and v.ndim >= 3:
            seq = max(seq, v.shape[2])
    return B, seq
