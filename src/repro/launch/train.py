"""End-to-end training driver (CPU-runnable at reduced scale).

Wires every substrate together: config -> model -> pjit train step ->
synthetic data pipeline -> checkpointing -> straggler monitor ->
supervisor (restart-from-checkpoint on failure).

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
      --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config
from repro.configs.base import ParallelConfig, RunConfig, TrainConfig
from repro.configs.reduced import reduce_config
from repro.core.placement import Env
from repro.data.pipeline import DataConfig, host_batch
from repro.distributed.fault_tolerance import StragglerMonitor, Supervisor
from repro.launch.mesh import make_host_mesh, mesh_axes
from repro.models.registry import build_model
from repro.training.trainer import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", default="cosine", choices=["cosine", "wsd", "const"])
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--grad-compression", default="none", choices=["none", "int8"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--fail-at-step", type=int, default=-1,
                    help="simulate a node failure at this step (tests recovery)")
    args = ap.parse_args()

    cfg = reduce_config(args.arch) if args.reduced else get_config(args.arch)
    mesh = make_host_mesh(args.model_parallel)
    axes = mesh_axes(mesh)
    env = Env(axes=axes if mesh.devices.size > 1 else {})
    model = build_model(cfg, env)
    print(f"arch={cfg.name} params={model.n_params():,} mesh={axes}")

    run = RunConfig(
        model=cfg,
        parallel=ParallelConfig(
            grad_accum=args.grad_accum, grad_compression=args.grad_compression
        ),
        train=TrainConfig(
            lr=args.lr, schedule=args.schedule,
            warmup_steps=max(args.steps // 20, 2), total_steps=args.steps,
        ),
    )
    init_state, train_step, state_specs, _ = make_train_step(model, run)
    dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)
    ck = Checkpointer(args.ckpt_dir, keep_n=3)
    monitor = StragglerMonitor(n_workers=1)
    step_fn = jax.jit(train_step, donate_argnums=(0,))
    failed_once = {"done": False}

    def run_fn(start_step: int) -> int:
        if start_step == 0:
            state = init_state(jax.random.key(0))
        else:
            tmpl = jax.eval_shape(init_state, jax.ShapeDtypeStruct((2,), jnp.uint32))
            _, state = ck.restore(tmpl, step=start_step)
            print(f"restored from step {start_step}")
        for step in range(start_step, args.steps):
            if step == args.fail_at_step and not failed_once["done"]:
                failed_once["done"] = True
                raise RuntimeError("simulated node failure")
            t0 = time.time()
            batch = {k: jnp.asarray(v) for k, v in host_batch(dc, step, 0, 1).items()}
            state, metrics = step_fn(state, batch)
            monitor.record(0, time.time() - t0)
            if (step + 1) % args.ckpt_every == 0 or step + 1 == args.steps:
                ck.wait()
                ck.save(step + 1, state, blocking=False)
            if step % 10 == 0 or step + 1 == args.steps:
                print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.2f} "
                      f"{time.time()-t0:.2f}s")
        ck.wait()
        return args.steps

    sup = Supervisor(run_fn, ck.latest_step, max_restarts=3)
    sup.run(ck.latest_step() or 0)
    print(f"done ({sup.restarts} restart(s)); checkpoints: {ck.all_steps()}")


if __name__ == "__main__":
    main()
