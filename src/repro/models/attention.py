"""Attention compute paths (pure jnp, chunked/flash-style).

These are the mathematically-exact CPU/dry-run implementations; the Pallas
kernels in ``repro.kernels`` implement the same contracts for TPU and are
validated against ``repro.kernels.ref`` (which in turn matches these).

Shapes:
  q        (B, Sq, Hq, D)
  k, v     (B, Sk, Hkv, D)        Hq % Hkv == 0 (GQA group G = Hq // Hkv)
  output   (B, Sq, Hq, D)

Decode: Sq == 1, caches carry per-sequence valid ``lengths``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def _pick_chunk(sk: int, want: int) -> int:
    c = min(want, sk)
    while sk % c:
        c -= 1
    return max(c, 1)


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    q_offset: jax.Array | int = 0,
    kv_lengths: jax.Array | None = None,
    chunk: int = 1024,
    scale: float | None = None,
) -> jax.Array:
    """Online-softmax attention, O(chunk) memory in Sk, fp32 accumulation.

    ``q_offset``: absolute position of q[:, 0] (scalar or (B,)) so causal
    masking works for prefill continuation and decode.
    ``kv_lengths``: (B,) number of valid KV entries (mask the rest).
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    Dv = v.shape[-1]  # may differ from D (MLA absorbed decode)
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    chunk = _pick_chunk(Sk, chunk)
    n_chunks = Sk // chunk

    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, G, D) * scale
    # accept int, traced scalar, or (B,) per-sequence offsets
    q_offset = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32), (B,))
    q_pos = q_offset[:, None] + jnp.arange(Sq, dtype=jnp.int32)[None, :]  # (B,Sq)

    kc = k.astype(jnp.float32).reshape(B, n_chunks, chunk, Hkv, D)
    vc = v.astype(jnp.float32).reshape(B, n_chunks, chunk, Hkv, Dv)
    kc = jnp.moveaxis(kc, 1, 0)  # (n, B, chunk, Hkv, D)
    vc = jnp.moveaxis(vc, 1, 0)

    m0 = jnp.full((B, Sq, Hkv, G), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, Hkv, G, Dv), jnp.float32)

    def body(carry, inputs):
        m, l, acc, j = carry[0], carry[1], carry[2], carry[3]
        kj, vj = inputs
        # scores: (B, Sq, Hkv, G, chunk)
        s = jnp.einsum("bqhgd,bchd->bqhgc", qf, kj)
        k_pos = j * chunk + jnp.arange(chunk, dtype=jnp.int32)  # (chunk,)
        mask = jnp.ones((B, Sq, chunk), bool)
        if causal:
            mask &= q_pos[:, :, None] >= k_pos[None, None, :]
        if kv_lengths is not None:
            mask &= k_pos[None, None, :] < kv_lengths[:, None, None]
        s = jnp.where(mask[:, :, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[:, :, None, None, :], p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bqhgc,bchd->bqhgd", p, vj)
        return (m_new, l, acc, j + 1), None

    (m, l, acc, _), _ = jax.lax.scan(
        body, (m0, l0, a0, jnp.int32(0)), (kc, vc), length=n_chunks
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Sq, Hq, Dv).astype(q.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    *,
    scale: float | None = None,
    acc_dtype=jnp.float32,
) -> jax.Array:
    """One-token attention vs a (possibly partially filled) KV cache.

    q (B, Hq, D); caches (B, S, Hkv, D); lengths (B,).  The new token's K/V
    must already be written into the cache at index lengths-1.

    Deliberately UNCHUNKED (single einsum over the full S axis): the score
    tensor for one query token is small, and keeping the cache's S axis
    intact lets GSPMD shard it (sequence placement policy) with only
    (B,H)-sized softmax reductions crossing chips — never the cache itself.
    """
    B, Hq, D = q.shape
    _, S, Hkv, _ = k_cache.shape
    Dv = v_cache.shape[-1]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    # keep the cache in bf16 (no fp32 materialization — that would double
    # the dominant HBM traffic); accumulate the dots in fp32 on the MXU
    qf = (q.astype(jnp.float32) * scale).astype(q.dtype).reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k_cache, preferred_element_type=jnp.float32)
    mask = jnp.arange(S, dtype=jnp.int32)[None] < lengths[:, None]  # (B,S)
    s = jnp.where(mask[:, None, None], s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - jax.lax.stop_gradient(m))
    p = jnp.where(mask[:, None, None], p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum(
        "bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=acc_dtype,
    ).astype(jnp.float32)
    o = o / jnp.maximum(l, 1e-30)
    return o.reshape(B, Hq, Dv).astype(q.dtype)


def mla_decode_attention(
    q_latent: jax.Array,
    q_rope: jax.Array,
    ckv_cache: jax.Array,
    krope_cache: jax.Array,
    lengths: jax.Array,
    *,
    scale: float,
    acc_dtype=jnp.float32,
) -> jax.Array:
    """DeepSeek MLA absorbed decode.

    q_latent (B, H, Dc): query projected into the compressed-kv latent space
    (W_UK absorbed); q_rope (B, H, Dr): rope part; ckv_cache (B, S, Dc);
    krope_cache (B, S, Dr); output (B, H, Dc) = attention-weighted latent
    (caller applies absorbed W_UV / W_O).
    """
    B, H, Dc = q_latent.shape
    S = ckv_cache.shape[1]
    # unchunked on purpose (see decode_attention): scores are (B,H,S), the
    # latent cache's S axis stays intact for the sequence placement policy;
    # bf16 cache operands, fp32 accumulation (no fp32 cache copy)
    s = jnp.einsum("bhr,bkr->bhk", q_latent.astype(ckv_cache.dtype), ckv_cache,
                   preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bhr,bkr->bhk", q_rope.astype(krope_cache.dtype), krope_cache,
                       preferred_element_type=jnp.float32)
    s = s * scale
    mask = jnp.arange(S, dtype=jnp.int32)[None] < lengths[:, None]
    s = jnp.where(mask[:, None], s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - jax.lax.stop_gradient(m))
    p = jnp.where(mask[:, None], p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    # acc_dtype=bf16 halves the wire bytes of the cross-shard LSE combine
    # when the cache's S axis is sharded (sequence policy) — §Perf iter. 2.
    # The whole combine (incl. the division) stays in acc_dtype so the
    # cross-shard reduction itself carries the narrow type.
    out = jnp.einsum("bhk,bkr->bhr", p.astype(ckv_cache.dtype), ckv_cache,
                     preferred_element_type=acc_dtype)
    out = out / jnp.maximum(l, 1e-30).astype(acc_dtype)
    return out.astype(q_latent.dtype)  # (B, H, Dc)
