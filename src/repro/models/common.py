"""Shared layers + parameter/spec machinery (pure JAX, no flax).

Parameters are nested dicts of jnp arrays.  Every family module defines its
parameter tree once as a tree of :class:`ParamDef` (shape + logical axes +
init); ``init_params`` samples it and ``logical_specs`` extracts the
logical-axis tree, so shapes and shardings can never diverge.

Logical axes (resolved to mesh axes by ``repro.core.placement``):
  "layers"      stacked scan dimension (never sharded)
  "embed"       d_model
  "vocab"       vocabulary
  "heads"       query heads
  "kv_heads"    kv heads
  "head_dim"    per-head dim
  "mlp"         FFN hidden
  "experts"     MoE expert dimension
  "batch"/"seq" activations only
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Pytree = Any

# ---------------------------------------------------------------------------
# dtype policy
# ---------------------------------------------------------------------------
def param_dtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# parameter definitions
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    logical: tuple[str | None, ...]
    init: str = "normal"        # normal | zeros | ones | small | embed
    scale: float | None = None  # override fan-in scale

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def _sample(defn: ParamDef, rng: jax.Array, dtype) -> jax.Array:
    if defn.init == "zeros":
        return jnp.zeros(defn.shape, dtype)
    if defn.init == "ones":
        return jnp.ones(defn.shape, dtype)
    # fan-in scaled normal; "embed" uses unit normal * 0.02 like GPT
    if defn.init == "embed":
        std = 0.02
    elif defn.init == "small":
        std = 1e-4
    else:
        fan_in = defn.shape[-2] if len(defn.shape) >= 2 else defn.shape[-1]
        std = defn.scale if defn.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(rng, defn.shape, jnp.float32) * std).astype(dtype)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(tree: Pytree, rng: jax.Array, dtype) -> Pytree:
    """Sample every ParamDef leaf with an independent, path-derived key."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_def)
    keys = jax.random.split(rng, len(leaves))
    vals = [_sample(d, k, dtype) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def shape_tree(tree: Pytree, dtype) -> Pytree:
    """ShapeDtypeStructs for the dry-run (no allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), tree, is_leaf=is_def
    )


def logical_tree(tree: Pytree) -> Pytree:
    return jax.tree.map(lambda d: d.logical, tree, is_leaf=is_def)


def count_params(tree: Pytree) -> int:
    return sum(
        math.prod(d.shape) for d in jax.tree.leaves(tree, is_leaf=is_def)
    )


# ---------------------------------------------------------------------------
# logical -> physical spec resolution
# ---------------------------------------------------------------------------
# default rules; core.placement builds policy-specific variants.
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "vocab": ("model",),
    "mlp": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "experts": ("model",),
    "kv_batch": ("pod", "data"),
    "kv_seq": (),
    "embed": (),
    "head_dim": (),
    "seq": (),
    "layers": (),
    "state": (),
}


def resolve_spec(
    logical: tuple[str | None, ...],
    rules: dict[str, tuple[str, ...]],
    mesh_axes: dict[str, int],
    shape: tuple[int, ...] | None = None,
) -> P:
    """Map logical axes to a PartitionSpec.

    Axes named in ``rules`` map to the mesh axes present in
    ``mesh_axes``; unknown/None logical axes are unsharded.  pjit in/out
    shardings require exact divisibility, so mesh axes that do not divide
    the dim evenly are dropped (trailing-first).
    """
    parts: list[Any] = []
    used: set[str] = set()  # a mesh axis may appear in at most one dim
    for i, name in enumerate(logical):
        if name is None or name not in rules:
            parts.append(None)
            continue
        axes = tuple(a for a in rules[name] if a in mesh_axes and a not in used)
        if not axes:
            parts.append(None)
            continue
        if shape is not None:
            dim = shape[i]
            kept: list[str] = []
            prod = 1
            for a in axes:
                if dim > 0 and dim % (prod * mesh_axes[a]) == 0:
                    kept.append(a)
                    prod *= mesh_axes[a]
            axes = tuple(kept)
            if not axes:
                parts.append(None)
                continue
        used.update(axes)
        parts.append(axes if len(axes) > 1 else axes[0])
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def _spec_axes(spec: P) -> set[str]:
    used: set[str] = set()
    for part in spec:
        if part is None:
            continue
        for a in (part if isinstance(part, tuple) else (part,)):
            used.add(a)
    return used


def resolve_param_spec(
    defn: "ParamDef", rules: dict[str, tuple[str, ...]], mesh_axes: dict[str, int]
) -> P:
    """resolve_spec + row-parallel fallback for weights.

    When a large weight loses its `model` sharding to divisibility (e.g.
    yi-34b's 56 q-heads on a 16-way model axis), shard its embed/mlp/vocab
    (contracting) dim over `model` instead — Megatron row-parallel; GSPMD
    inserts the psum after the projection.  Replication of multi-GB
    weights is never acceptable at scale.
    """
    spec = resolve_spec(defn.logical, rules, mesh_axes, defn.shape)
    if "model" not in mesh_axes or math.prod(defn.shape) < (1 << 20):
        return spec
    if "model" in _spec_axes(spec):
        return spec
    parts = list(spec) + [None] * (len(defn.shape) - len(spec))
    for i, name in enumerate(defn.logical):
        if (
            name in ("embed", "mlp", "vocab")
            and parts[i] is None
            and defn.shape[i] % mesh_axes["model"] == 0
        ):
            parts[i] = "model"
            while parts and parts[-1] is None:
                parts.pop()
            return P(*parts)
    return spec


def specs_for(
    defs: Pytree,
    rules: dict[str, tuple[str, ...]],
    mesh_axes: dict[str, int],
    params: bool = False,
) -> Pytree:
    fn = resolve_param_spec if params else (
        lambda d, r, m: resolve_spec(d.logical, r, m, d.shape)
    )
    return jax.tree.map(lambda d: fn(d, rules, mesh_axes), defs, is_leaf=is_def)


def mesh_axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


# ---------------------------------------------------------------------------
# core layers
# ---------------------------------------------------------------------------
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: (..., S, H, D), positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x32_1, x32_2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [x32_1 * cos - x32_2 * sin, x32_2 * cos + x32_1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array):
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def embed_lookup(table: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def unembed(x: jax.Array, table: jax.Array, true_vocab: int | None = None) -> jax.Array:
    """x: (..., d_model), table: (vocab_padded, d_model) -> logits.

    When the table is padded beyond ``true_vocab`` the pad logits are set
    to -inf (so sampling/CE can never select them)."""
    logits = jnp.einsum("...d,vd->...v", x, table)
    if true_vocab is not None and true_vocab < table.shape[0]:
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        logits = jnp.where(iota < true_vocab, logits, jnp.asarray(-1e30, logits.dtype))
    return logits


def cross_entropy_loss(
    logits: jax.Array, targets: jax.Array, mask: jax.Array | None = None
) -> jax.Array:
    """Mean next-token CE in fp32.  logits (B,S,V), targets (B,S).

    Written to stay vocab-sharded under GSPMD: no take_along_axis (its
    gather would all-gather the logits); the gold logit is extracted with
    a fused iota-compare-select reduction, and max/logsumexp reduce over
    the sharded vocab axis with scalar-sized all-reduces only.
    """
    V = logits.shape[-1]
    logits32 = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits32, axis=-1, keepdims=True))
    sumexp = jnp.sum(jnp.exp(logits32 - m), axis=-1)
    logz = jnp.log(sumexp) + m[..., 0]
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(
        jnp.where(vocab_iota == targets[..., None], logits32, 0.0), axis=-1
    )
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
