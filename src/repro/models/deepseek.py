"""DeepSeek-V3: Multi-head Latent Attention (MLA) + MoE + MTP.

Train path uses the naive (expanded) MLA formulation; decode uses the
*absorbed* formulation, where the cache holds only the compressed latent
(kv_lora_rank) + shared rope key — the per-token cache is 576 values
instead of 2*H*128 = 32768, which is precisely why MLA remains a
memory-bound offload target at much higher batch (DESIGN.md §4).

MTP (depth 1): one extra MLA block predicting token t+2 from
[norm(h_t); norm(embed(tok_{t+1}))], sharing embedding and output head
(loss weight 0.3, per the DeepSeek-V3 paper).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import offload
from repro.core.placement import Env
from repro.models import common as cm
from repro.models import moe as moe_mod
from repro.models.attention import chunked_attention
from repro.models.common import ParamDef

Pytree = Any

MTP_WEIGHT = 0.3


def _dims(cfg):
    a = cfg.mla
    d_qk = a.qk_nope_head_dim + a.qk_rope_head_dim
    return a, d_qk


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------
def _mla_defs(cfg, L):
    a, d_qk = _dims(cfg)
    D, H = cfg.d_model, cfg.n_heads
    return {
        "ln1": ParamDef((L, D), ("layers", "embed"), "zeros"),
        "w_dq": ParamDef((L, D, a.q_lora_rank), ("layers", "embed", None)),
        "q_norm": ParamDef((L, a.q_lora_rank), ("layers", None), "zeros"),
        "w_uq": ParamDef((L, a.q_lora_rank, H, d_qk), ("layers", None, "heads", "head_dim")),
        "w_dkv": ParamDef((L, D, a.kv_lora_rank), ("layers", "embed", None)),
        "kv_norm": ParamDef((L, a.kv_lora_rank), ("layers", None), "zeros"),
        "w_krope": ParamDef((L, D, a.qk_rope_head_dim), ("layers", "embed", None)),
        "w_uk": ParamDef((L, a.kv_lora_rank, H, a.qk_nope_head_dim), ("layers", None, "heads", "head_dim")),
        "w_uv": ParamDef((L, a.kv_lora_rank, H, a.v_head_dim), ("layers", None, "heads", "head_dim")),
        "wo": ParamDef((L, H, a.v_head_dim, D), ("layers", "heads", "head_dim", "embed")),
        "ln2": ParamDef((L, D), ("layers", "embed"), "zeros"),
    }


def param_defs(cfg) -> Pytree:
    m = cfg.moe
    Ld, Lm = m.moe_layer_start, cfg.n_layers - m.moe_layer_start
    D, V, F = cfg.d_model, cfg.padded_vocab(), cfg.d_ff
    dense_blocks = {
        **_mla_defs(cfg, Ld),
        "w_gate": ParamDef((Ld, D, F), ("layers", "embed", "mlp")),
        "w_up": ParamDef((Ld, D, F), ("layers", "embed", "mlp")),
        "w_down": ParamDef((Ld, F, D), ("layers", "mlp", "embed")),
    }
    moe_blocks = {**_mla_defs(cfg, Lm), **moe_mod.moe_ffn_defs(cfg, Lm)}
    defs: dict[str, Any] = {
        "embed": ParamDef((V, D), ("vocab", "embed"), "embed"),
        "dense_blocks": dense_blocks,
        "moe_blocks": moe_blocks,
        "final_norm": ParamDef((D,), ("embed",), "zeros"),
        "unembed": ParamDef((V, D), ("vocab", "embed"), "embed"),
    }
    if cfg.mtp_depth:
        defs["mtp"] = {
            "norm_h": ParamDef((D,), ("embed",), "zeros"),
            "norm_e": ParamDef((D,), ("embed",), "zeros"),
            "proj": ParamDef((2 * D, D), (None, "embed")),
            "block": {
                **_mla_defs(cfg, 1),
                "w_gate": ParamDef((1, D, F), ("layers", "embed", "mlp")),
                "w_up": ParamDef((1, D, F), ("layers", "embed", "mlp")),
                "w_down": ParamDef((1, F, D), ("layers", "mlp", "embed")),
            },
            "final_norm": ParamDef((D,), ("embed",), "zeros"),
        }
    return defs


# ---------------------------------------------------------------------------
# MLA attention
# ---------------------------------------------------------------------------
def _mla_train_attn(cfg, env: Env, p, x, positions):
    """Naive (expanded) MLA for train/prefill.  Returns (attn_out, ckv, krope)."""
    a, d_qk = _dims(cfg)
    H = cfg.n_heads
    h = cm.rmsnorm(x, p["ln1"], cfg.norm_eps)
    cq = cm.rmsnorm(jnp.einsum("bsd,dr->bsr", h, p["w_dq"]), p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"])  # (B,S,H,d_qk)
    q_nope, q_rope = q[..., : a.qk_nope_head_dim], q[..., a.qk_nope_head_dim :]
    q_rope = cm.rope(q_rope, positions, cfg.rope_theta)

    ckv = cm.rmsnorm(jnp.einsum("bsd,dr->bsr", h, p["w_dkv"]), p["kv_norm"], cfg.norm_eps)
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", ckv, p["w_uv"])
    krope = cm.rope(
        jnp.einsum("bsd,dk->bsk", h, p["w_krope"])[:, :, None, :], positions, cfg.rope_theta
    )  # (B,S,1,Dr)

    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    kf = jnp.concatenate([k_nope, jnp.broadcast_to(krope, k_nope.shape[:3] + (a.qk_rope_head_dim,))], axis=-1)
    o = chunked_attention(qf, kf, v, causal=True, scale=1.0 / math.sqrt(d_qk))
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, ckv, krope[:, :, 0, :]


def _mla_decode_attn(cfg, env: Env, p, x, ckv_cache, krope_cache, lengths):
    """Absorbed MLA decode.  Returns (attn_out (B,D), ckv_cache, krope_cache)."""
    a, d_qk = _dims(cfg)
    B = x.shape[0]
    pos = lengths[:, None]
    bidx = jnp.arange(B)
    h = cm.rmsnorm(x, p["ln1"], cfg.norm_eps)
    cq = cm.rmsnorm(jnp.einsum("bd,dr->br", h, p["w_dq"]), p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("br,rhk->bhk", cq, p["w_uq"])
    q_nope, q_rope = q[..., : a.qk_nope_head_dim], q[..., a.qk_nope_head_dim :]
    q_rope = cm.rope(q_rope[:, None], pos, cfg.rope_theta)[:, 0]

    ckv_t = cm.rmsnorm(jnp.einsum("bd,dr->br", h, p["w_dkv"]), p["kv_norm"], cfg.norm_eps)
    krope_t = cm.rope(
        jnp.einsum("bd,dk->bk", h, p["w_krope"])[:, None, None, :], pos, cfg.rope_theta
    )[:, 0, 0]
    ckv_cache = ckv_cache.at[bidx, lengths].set(ckv_t.astype(ckv_cache.dtype))
    krope_cache = krope_cache.at[bidx, lengths].set(krope_t.astype(krope_cache.dtype))

    q_latent = jnp.einsum("bhn,rhn->bhr", q_nope, p["w_uk"])  # absorb W_UK
    out_latent = offload.mla_decode_attention(
        env, q_latent, q_rope, ckv_cache, krope_cache, lengths + 1,
        scale=1.0 / math.sqrt(d_qk),
    )
    v_out = jnp.einsum("bhr,rhn->bhn", out_latent.astype(jnp.float32), p["w_uv"].astype(jnp.float32))
    out = jnp.einsum("bhn,hnd->bd", v_out, p["wo"].astype(jnp.float32)).astype(x.dtype)
    return out, ckv_cache, krope_cache


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------
def _block_train(cfg, env, p, x, positions, is_moe):
    o, _, _ = _mla_train_attn(cfg, env, p, x, positions)
    x = x + o
    h = cm.rmsnorm(x, p["ln2"], cfg.norm_eps)
    if is_moe:
        B, S, D = h.shape
        y, aux = moe_mod.moe_ffn(cfg, env, p, h.reshape(B * S, D))
        x = x + y.reshape(B, S, D)
    else:
        x = x + cm.swiglu(h, p["w_gate"], p["w_up"], p["w_down"])
        aux = jnp.float32(0.0)
    if env.axes:
        x = jax.lax.with_sharding_constraint(
            x, env.act_spec(("batch", "seq", "embed"), x.shape)
        )
    return x, aux


def hidden_states(cfg, env: Env, params, tokens, embeds=None, remat: bool = True):
    x = cm.embed_lookup(params["embed"], tokens)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    dense_blk = partial(_block_train, cfg, env, is_moe=False)
    moe_blk = partial(_block_train, cfg, env, is_moe=True)
    if remat:
        dense_blk = jax.checkpoint(dense_blk, policy=jax.checkpoint_policies.nothing_saveable)
        moe_blk = jax.checkpoint(moe_blk, policy=jax.checkpoint_policies.nothing_saveable)

    def dense_body(xc, p):
        xc, _ = dense_blk(p, xc, positions)
        return xc, None

    def moe_body(carry, p):
        xc, aux = carry
        xc, a = moe_blk(p, xc, positions)
        return (xc, aux + a), None

    x, _ = jax.lax.scan(dense_body, x, params["dense_blocks"])
    (x, aux), _ = jax.lax.scan(moe_body, (x, jnp.float32(0.0)), params["moe_blocks"])
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, aux / max(cfg.n_layers - cfg.moe.moe_layer_start, 1)


def loss_fn(cfg, env: Env, params, batch):
    hid, aux = hidden_states(cfg, env, params, batch["inputs"])
    table = params["unembed"]
    logits = cm.unembed(hid, table, cfg.vocab)
    ce = cm.cross_entropy_loss(logits, batch["targets"], batch.get("mask"))
    loss = ce + cfg.moe.router_aux_coef * aux
    metrics = {"loss": loss, "ce": ce, "aux": aux}

    if cfg.mtp_depth and "mtp" in params:
        mp = params["mtp"]
        inp, tgt = batch["inputs"], batch["targets"]
        # combine h_t with embed(tok_{t+1}) == embed(targets[:, :-1]) for t<S-1
        h_in = cm.rmsnorm(hid[:, :-1], mp["norm_h"], cfg.norm_eps)
        e_in = cm.rmsnorm(
            cm.embed_lookup(params["embed"], tgt[:, :-1]), mp["norm_e"], cfg.norm_eps
        )
        x = jnp.einsum("bsd,dk->bsk", jnp.concatenate([h_in, e_in], -1), mp["proj"])
        B, S1 = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S1, dtype=jnp.int32)[None], (B, S1))

        def mtp_body(xc, p):
            xc, _ = _block_train(cfg, env, p, xc, positions, is_moe=False)
            return xc, None

        x, _ = jax.lax.scan(mtp_body, x, mp["block"])
        x = cm.rmsnorm(x, mp["final_norm"], cfg.norm_eps)
        mtp_logits = cm.unembed(x, table, cfg.vocab)
        mask = batch.get("mask")
        mtp_ce = cm.cross_entropy_loss(
            mtp_logits, tgt[:, 1:], None if mask is None else mask[:, 1:]
        )
        loss = loss + MTP_WEIGHT * mtp_ce
        metrics["mtp_ce"] = mtp_ce
        metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# cache / prefill / decode
# ---------------------------------------------------------------------------
def cache_defs(cfg, batch: int, max_seq: int) -> Pytree:
    a = cfg.mla
    L = cfg.n_layers
    return {
        "ckv": ParamDef(
            (L, batch, max_seq, a.kv_lora_rank),
            ("layers", "kv_batch", "kv_seq", None),
            "zeros",
        ),
        "krope": ParamDef(
            (L, batch, max_seq, a.qk_rope_head_dim),
            ("layers", "kv_batch", "kv_seq", None),
            "zeros",
        ),
        "lengths": ParamDef((batch,), ("kv_batch",), "zeros"),
    }


def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16) -> Pytree:
    defs = cache_defs(cfg, batch, max_seq)
    return {
        k: jnp.zeros(d.shape, jnp.int32 if k == "lengths" else dtype)
        for k, d in defs.items()
    }


def _split_cache(cfg, cache):
    Ld = cfg.moe.moe_layer_start
    return (
        {k: (v[:Ld] if k != "lengths" else v) for k, v in cache.items()},
        {k: (v[Ld:] if k != "lengths" else v) for k, v in cache.items()},
    )


def prefill(cfg, env: Env, params, tokens, cache, embeds=None):
    x = cm.embed_lookup(params["embed"], tokens)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    dcache, mcache = _split_cache(cfg, cache)

    def body(is_moe):
        def f(xc, xs):
            p, ckv_l, kr_l = xs
            o, ckv, krope = _mla_train_attn(cfg, env, p, xc, positions)
            xc = xc + o
            h = cm.rmsnorm(xc, p["ln2"], cfg.norm_eps)
            if is_moe:
                y, _ = moe_mod.moe_ffn(cfg, env, p, h.reshape(B * S, -1))
                xc = xc + y.reshape(B, S, -1)
            else:
                xc = xc + cm.swiglu(h, p["w_gate"], p["w_up"], p["w_down"])
            ckv_l = jax.lax.dynamic_update_slice(ckv_l, ckv.astype(ckv_l.dtype), (0, 0, 0))
            kr_l = jax.lax.dynamic_update_slice(kr_l, krope.astype(kr_l.dtype), (0, 0, 0))
            return xc, (ckv_l, kr_l)

        return f

    x, (cd, kd) = jax.lax.scan(
        body(False), x, (params["dense_blocks"], dcache["ckv"], dcache["krope"])
    )
    x, (cmo, kmo) = jax.lax.scan(
        body(True), x, (params["moe_blocks"], mcache["ckv"], mcache["krope"])
    )
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = cm.unembed(x[:, -1], params["unembed"], cfg.vocab)
    new_cache = {
        "ckv": jnp.concatenate([cd, cmo], 0),
        "krope": jnp.concatenate([kd, kmo], 0),
        "lengths": jnp.full((B,), S, jnp.int32),
    }
    return logits, new_cache


def decode_step(cfg, env: Env, params, cache, tokens):
    lengths = cache["lengths"]
    x = cm.embed_lookup(params["embed"], tokens)
    dcache, mcache = _split_cache(cfg, cache)

    def body(is_moe):
        def f(xc, xs):
            p, ckv_l, kr_l = xs
            o, ckv_l, kr_l = _mla_decode_attn(cfg, env, p, xc, ckv_l, kr_l, lengths)
            xc = xc + o
            h = cm.rmsnorm(xc, p["ln2"], cfg.norm_eps)
            if is_moe:
                y, _ = moe_mod.moe_ffn(cfg, env, p, h)
                xc = xc + y
            else:
                xc = xc + cm.swiglu(h, p["w_gate"], p["w_up"], p["w_down"])
            return xc, (ckv_l, kr_l)

        return f

    x, (cd, kd) = jax.lax.scan(
        body(False), x, (params["dense_blocks"], dcache["ckv"], dcache["krope"])
    )
    x, (cmo, kmo) = jax.lax.scan(
        body(True), x, (params["moe_blocks"], mcache["ckv"], mcache["krope"])
    )
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = cm.unembed(x, params["unembed"], cfg.vocab)
    new_cache = {
        "ckv": jnp.concatenate([cd, cmo], 0),
        "krope": jnp.concatenate([kd, kmo], 0),
        "lengths": lengths + 1,
    }
    return logits, new_cache
