"""Dense llama-family decoder LM (yi-34b, llama3.2-1b/3b, minicpm-2b,
internvl2-76b backbone).

Layers are *stacked* on a leading dim and iterated with ``lax.scan`` so
60-80 layer models lower/compile quickly on the dry-run host.  The decode
path routes attention through ``repro.core.offload`` (the paper's
technique); train/prefill use chunked flash-style attention.

Multimodal stub (internvl2): ``batch["embeds"]`` (B, F, d_model) patch
embeddings are prepended to the token embeddings; the loss covers token
positions only.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import offload
from repro.core.placement import Env
from repro.kernels import ref
from repro.models import common as cm
from repro.models.common import ParamDef
from repro.serving.sampler import sample_on_device

Pytree = Any


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------
def param_defs(cfg) -> Pytree:
    L, D, V, F = cfg.n_layers, cfg.d_model, cfg.padded_vocab(), cfg.d_ff
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim()
    defs: dict[str, Any] = {
        "embed": ParamDef((V, D), ("vocab", "embed"), "embed"),
        "blocks": {
            "ln1": ParamDef((L, D), ("layers", "embed"), "zeros"),
            "wq": ParamDef((L, D, Hq, Dh), ("layers", "embed", "heads", "head_dim")),
            "wk": ParamDef((L, D, Hkv, Dh), ("layers", "embed", "kv_heads", "head_dim")),
            "wv": ParamDef((L, D, Hkv, Dh), ("layers", "embed", "kv_heads", "head_dim")),
            "wo": ParamDef((L, Hq, Dh, D), ("layers", "heads", "head_dim", "embed")),
            "ln2": ParamDef((L, D), ("layers", "embed"), "zeros"),
            "w_gate": ParamDef((L, D, F), ("layers", "embed", "mlp")),
            "w_up": ParamDef((L, D, F), ("layers", "embed", "mlp")),
            "w_down": ParamDef((L, F, D), ("layers", "mlp", "embed")),
        },
        "final_norm": ParamDef((D,), ("embed",), "zeros"),
    }
    if not cfg.tie_embeddings:
        defs["unembed"] = ParamDef((V, D), ("vocab", "embed"), "embed")
    return defs


def _unembed_table(params):
    return params.get("unembed", params["embed"])


# ---------------------------------------------------------------------------
# forward (train / prefill shared block)
# ---------------------------------------------------------------------------
def _qkv(cfg, p, h):
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
    return q, k, v


def _block_train(cfg, env: Env, p, x, positions, chunk=1024):
    h = cm.rmsnorm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _qkv(cfg, p, h)
    q = cm.rope(q, positions, cfg.rope_theta)
    k = cm.rope(k, positions, cfg.rope_theta)
    o = offload.prefill_attention(env, q, k, v, chunk=chunk)
    x = x + jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    h = cm.rmsnorm(x, p["ln2"], cfg.norm_eps)
    # Megatron-style SP: only the attention section runs sequence-sharded;
    # the FFN gathers the sequence (small activations) so its weights stay
    # tensor-parallel over `model` — otherwise every chip computes with the
    # FULL (D,F) weight and its gradient all-reduces over all chips
    # (measured 2x ~1.6 TiB/chip per step on yi-34b; EXPERIMENTS.md §Perf).
    if env.axes and env.sequence_parallel:
        h = jax.lax.with_sharding_constraint(
            h, env.act_spec(("batch", None, "embed"), h.shape)
        )
    ffn = cm.swiglu(h, p["w_gate"], p["w_up"], p["w_down"])
    if env.axes:
        ffn = jax.lax.with_sharding_constraint(
            ffn, env.act_spec(("batch", "seq", "embed"), ffn.shape)
        )
        x = jax.lax.with_sharding_constraint(
            x, env.act_spec(("batch", "seq", "embed"), x.shape)
        )
    x = x + ffn
    return x


def hidden_states(cfg, env: Env, params, tokens, embeds=None, remat: bool = True):
    """Token (+ optional prepended frontend) embeddings -> final hidden."""
    x = cm.embed_lookup(params["embed"], tokens)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    block = partial(_block_train, cfg, env)
    if remat:
        block = jax.checkpoint(
            block, policy=jax.checkpoint_policies.nothing_saveable
        )

    def scan_body(xc, p_slice):
        return block(p_slice, xc, positions), None

    x, _ = jax.lax.scan(scan_body, x, params["blocks"])
    return cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)


def loss_fn(cfg, env: Env, params, batch):
    hid = hidden_states(cfg, env, params, batch["inputs"], batch.get("embeds"))
    n_front = 0 if "embeds" not in batch else batch["embeds"].shape[1]
    hid = hid[:, n_front:]
    logits = cm.unembed(hid, _unembed_table(params), cfg.vocab)
    if env.axes:
        logits = jax.lax.with_sharding_constraint(
            logits, env.act_spec(("batch", "seq", "vocab"), logits.shape)
        )
    loss = cm.cross_entropy_loss(logits, batch["targets"], batch.get("mask"))
    return loss, {"loss": loss}


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------
def cache_defs(cfg, batch: int, max_seq: int) -> Pytree:
    L, Hkv, Dh = cfg.n_layers, cfg.n_kv_heads, cfg.resolved_head_dim()
    kv = ParamDef(
        (L, batch, max_seq, Hkv, Dh),
        ("layers", "kv_batch", "kv_seq", "kv_heads", "head_dim"),
        "zeros",
    )
    defs = {
        "k": kv,
        "v": kv,
        "lengths": ParamDef((batch,), ("kv_batch",), "zeros"),
    }
    if cfg.kv_quant:
        sc = ParamDef(
            (L, batch, max_seq, Hkv),
            ("layers", "kv_batch", "kv_seq", "kv_heads"),
            "zeros",
        )
        defs["k_scale"] = sc
        defs["v_scale"] = sc
    return defs


def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16) -> Pytree:
    defs = cache_defs(cfg, batch, max_seq)
    if cfg.kv_quant:
        dt = {"k": jnp.int8, "v": jnp.int8, "k_scale": jnp.bfloat16,
              "v_scale": jnp.bfloat16, "lengths": jnp.int32}
        return {k: jnp.zeros(d.shape, dt[k]) for k, d in defs.items()}
    return {
        k: jnp.zeros(d.shape, dtype if k != "lengths" else jnp.int32)
        for k, d in defs.items()
    }


# ---------------------------------------------------------------------------
# paged cache (block pool + per-slot block tables; serving/paged/)
# ---------------------------------------------------------------------------
def paged_cache_defs(
    cfg, n_slots: int, n_blocks: int, block_size: int, max_blocks: int,
    kv_dtype: str = "bf16", host_blocks: int = 0,
) -> Pytree:
    """Physical KV as a pool of fixed-size blocks shared by all slots.

    ``k``/``v`` carry the *block* axis where the dense cache carries the
    batch axis — that axis is what the HPU lanes split (placement rule
    ``kv_blocks``).  ``block_tables`` maps (slot, logical block) ->
    physical block; entry 0 is the engine's null block.

    The pool is stored kernel-native — ``(blocks, kv_heads, block, head_
    dim)``, heads *before* positions, unlike the dense ``(B, S, H, D)``
    cache — so the per-layer decode attention consumes it with zero
    relayout.  A transposed layout would materialize a full-pool copy
    per layer per token: exactly the HBM traffic the paper's design
    removes.

    Tiered-KV extensions: ``kv_dtype`` in {"fp8", "int8"} stores the
    pool quantized with per-vector f32 absmax scale pools
    (``k_scale``/``v_scale``, one scale per stored (head, position)
    vector — ~``4/(2*Dh)`` relative overhead); ``host_blocks > 0`` adds a
    host-tier pool (``host_k``/``host_v`` + per-slot ``host_tables`` and
    ``cold_lengths``) holding spilled cold prefix blocks, with host id 0
    reserved as the null block like the device pool.  Host leaves are
    deliberately *unsharded* (block axis placement ``None``): they model
    host DRAM, not HBM, and their bytes are excluded from the device KV
    budget accounting.
    """
    L, Hkv, Dh = cfg.n_layers, cfg.n_kv_heads, cfg.resolved_head_dim()
    kv = ParamDef(
        (L, n_blocks, Hkv, block_size, Dh),
        ("layers", "kv_blocks", "kv_heads", "kv_seq", "head_dim"),
        "zeros",
    )
    defs = {
        "k": kv,
        "v": kv,
        "block_tables": ParamDef((n_slots, max_blocks), ("kv_batch", None), "zeros"),
        "lengths": ParamDef((n_slots,), ("kv_batch",), "zeros"),
    }
    quant = kv_dtype in ("fp8", "int8")
    if quant:
        sc = ParamDef(
            (L, n_blocks, Hkv, block_size),
            ("layers", "kv_blocks", "kv_heads", "kv_seq"),
            "zeros",
        )
        defs["k_scale"] = sc
        defs["v_scale"] = sc
    if host_blocks > 0:
        hkv = ParamDef(
            (L, host_blocks + 1, Hkv, block_size, Dh),
            ("layers", None, "kv_heads", "kv_seq", "head_dim"),
            "zeros",
        )
        defs["host_k"] = hkv
        defs["host_v"] = hkv
        defs["host_tables"] = ParamDef(
            (n_slots, max_blocks), ("kv_batch", None), "zeros"
        )
        defs["cold_lengths"] = ParamDef((n_slots,), ("kv_batch",), "zeros")
        if quant:
            hsc = ParamDef(
                (L, host_blocks + 1, Hkv, block_size),
                ("layers", None, "kv_heads", "kv_seq"),
                "zeros",
            )
            defs["host_k_scale"] = hsc
            defs["host_v_scale"] = hsc
    return defs


# kv_dtype name -> pool storage dtype (scales are always f32)
PAGED_KV_DTYPES = {
    "bf16": jnp.bfloat16,
    "fp8": jnp.float8_e4m3fn,
    "int8": jnp.int8,
}


def _kv_dtype_name(dtype) -> str | None:
    """Storage dtype -> quantization name (None = unquantized)."""
    if dtype == jnp.int8:
        return "int8"
    if dtype == jnp.float8_e4m3fn:
        return "fp8"
    return None


def init_paged_cache(
    cfg, n_slots: int, n_blocks: int, block_size: int, max_blocks: int,
    dtype=jnp.bfloat16, kv_dtype: str = "bf16", host_blocks: int = 0,
) -> Pytree:
    if cfg.kv_quant and kv_dtype == "bf16":
        kv_dtype = "int8"           # cfg-level quant maps onto the int8 tier
    defs = paged_cache_defs(cfg, n_slots, n_blocks, block_size, max_blocks,
                            kv_dtype=kv_dtype, host_blocks=host_blocks)
    pool_dt = PAGED_KV_DTYPES[kv_dtype] if kv_dtype != "bf16" else dtype
    dt = {
        "block_tables": jnp.int32, "lengths": jnp.int32,
        "host_tables": jnp.int32, "cold_lengths": jnp.int32,
        "k": pool_dt, "v": pool_dt, "host_k": pool_dt, "host_v": pool_dt,
        "k_scale": jnp.float32, "v_scale": jnp.float32,
        "host_k_scale": jnp.float32, "host_v_scale": jnp.float32,
    }
    return {k: jnp.zeros(d.shape, dt.get(k, dtype)) for k, d in defs.items()}


def paged_decode_step(cfg, env: Env, params, cache, tokens):
    """One autoregressive step against the paged pool.

    Identical math to ``decode_step``; only the KV addressing differs:
    the new token's K/V scatter to ``(tables[b, len//bs], len % bs)`` and
    attention gathers each sequence's blocks through its table.  Inactive
    slots (length 0, table all-null) write to the null block and their
    logits are ignored by the engine.

    The cache pytree's own leaves select the tier statically at trace
    time: a quantized pool (int8/fp8 ``k`` with ``k_scale``) appends
    quantized and dequantizes inside the kernel; a host tier (``host_k``
    present) runs HGCA-style hybrid attention — the device kernel over
    the hot window ``[cold_len, len]``, the host/oracle path over the
    spilled cold prefix ``[0, cold_len)``, merged by log-sum-exp — so a
    spilled sequence keeps decoding without a re-prefill.
    """
    lengths = cache["lengths"]          # (B,) current KV counts
    tables = cache["block_tables"]      # (B, max_blocks) int32
    bs = cache["k"].shape[3]
    B = tokens.shape[0]
    quant = _kv_dtype_name(cache["k"].dtype)     # None | "fp8" | "int8"
    hosted = "host_k" in cache
    cold = cache["cold_lengths"] if hosted else None
    x = cm.embed_lookup(params["embed"], tokens)  # (B, D)
    pos = lengths[:, None]
    bidx = jnp.arange(B)
    phys = tables[bidx, lengths // bs]  # (B,) physical append block
    off = lengths % bs

    def scan_body(xc, xs):
        p = xs["p"]
        k_l, v_l = xs["k"], xs["v"]     # (n_blocks, Hkv, bs, Dh)
        h = cm.rmsnorm(xc, p["ln1"], cfg.norm_eps)
        q = jnp.einsum("bd,dhk->bhk", h, p["wq"])
        k = jnp.einsum("bd,dhk->bhk", h, p["wk"])
        v = jnp.einsum("bd,dhk->bhk", h, p["wv"])
        q = cm.rope(q[:, None], pos, cfg.rope_theta)[:, 0]
        k = cm.rope(k[:, None], pos, cfg.rope_theta)[:, 0]
        # advanced indices (phys, off) straddle the head slice, so the
        # selected (B, Hkv, Dh) lands batch-first — matching k/v directly
        ks_l = vs_l = None
        if quant:
            kq, ksc = ref.kv_quantize(k, quant)
            vq, vsc = ref.kv_quantize(v, quant)
            ks_l = xs["ks"].at[phys, :, off].set(ksc)
            vs_l = xs["vs"].at[phys, :, off].set(vsc)
            k_l = k_l.at[phys, :, off].set(kq)
            v_l = v_l.at[phys, :, off].set(vq)
        else:
            k_l = k_l.at[phys, :, off].set(k.astype(k_l.dtype))
            v_l = v_l.at[phys, :, off].set(v.astype(v_l.dtype))
        if hosted:
            # hybrid: device kernel over the hot window, host/oracle path
            # over the cold prefix, exact log-sum-exp merge
            o, lse_h = offload.paged_decode_attention(
                env, q, k_l, v_l, tables, lengths + 1, starts=cold,
                k_scale=ks_l, v_scale=vs_l, return_lse=True,
            )
            o_c, lse_c = ref.paged_decode_attention(
                q, xs["hk"], xs["hv"], cache["host_tables"], cold,
                k_scale=xs.get("hks"), v_scale=xs.get("hvs"),
                return_lse=True,
            )
            o = ref.lse_merge([(o, lse_h), (o_c, lse_c)])
        else:
            o = offload.paged_decode_attention(
                env, q, k_l, v_l, tables, lengths + 1,
                k_scale=ks_l, v_scale=vs_l,
            )
        xc = xc + jnp.einsum("bhk,hkd->bd", o, p["wo"])
        h = cm.rmsnorm(xc, p["ln2"], cfg.norm_eps)
        xc = xc + cm.swiglu(h, p["w_gate"], p["w_up"], p["w_down"])
        ys = {"k": k_l, "v": v_l}
        if quant:
            ys |= {"ks": ks_l, "vs": vs_l}
        return xc, ys

    xs = {"p": params["blocks"], "k": cache["k"], "v": cache["v"]}
    if quant:
        xs |= {"ks": cache["k_scale"], "vs": cache["v_scale"]}
    if hosted:
        xs |= {"hk": cache["host_k"], "hv": cache["host_v"]}
        if quant:
            xs |= {"hks": cache["host_k_scale"], "hvs": cache["host_v_scale"]}
    x, ys = jax.lax.scan(scan_body, x, xs)
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = cm.unembed(x, _unembed_table(params), cfg.vocab)
    new_cache = dict(cache)
    new_cache |= {"k": ys["k"], "v": ys["v"], "lengths": lengths + 1}
    if quant:
        new_cache |= {"k_scale": ys["ks"], "v_scale": ys["vs"]}
    return logits, new_cache


# ---------------------------------------------------------------------------
# int8 KV quantization (beyond-paper: 2x cache capacity — the paper's
# scalability axis §VI-B — at ~1e-2 relative attention error)
# ---------------------------------------------------------------------------
def _kv_quantize(x: jax.Array):
    """x (..., Dh) -> (int8 values, per-vector scale)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127
    ).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def _kv_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    # on TPU this convert-multiply fuses into the attention dot's operand
    # read; the resident cache stays int8 (capacity win is in the args)
    return (q.astype(jnp.bfloat16) * scale[..., None].astype(jnp.bfloat16))


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------
def prefill(cfg, env: Env, params, tokens, cache, embeds=None):
    """Fill the cache with S context tokens; return last-position logits.

    With a frontend, the prepended embeds also occupy cache positions (the
    KV cache covers the full multimodal prefix).
    """
    x = cm.embed_lookup(params["embed"], tokens)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    quant = cfg.kv_quant

    def scan_body(xc, xs):
        if quant:
            p, k_l, v_l, ks_l, vs_l = xs
        else:
            p, k_l, v_l = xs
        h = cm.rmsnorm(xc, p["ln1"], cfg.norm_eps)
        q, k, v = _qkv(cfg, p, h)
        q = cm.rope(q, positions, cfg.rope_theta)
        k = cm.rope(k, positions, cfg.rope_theta)
        o = offload.prefill_attention(env, q, k, v)
        xc = xc + jnp.einsum("bshk,hkd->bsd", o, p["wo"])
        h = cm.rmsnorm(xc, p["ln2"], cfg.norm_eps)
        xc = xc + cm.swiglu(h, p["w_gate"], p["w_up"], p["w_down"])
        if quant:
            kq, ksc = _kv_quantize(k)
            vq, vsc = _kv_quantize(v)
            k_l = jax.lax.dynamic_update_slice(k_l, kq, (0, 0, 0, 0))
            v_l = jax.lax.dynamic_update_slice(v_l, vq, (0, 0, 0, 0))
            ks_l = jax.lax.dynamic_update_slice(ks_l, ksc, (0, 0, 0))
            vs_l = jax.lax.dynamic_update_slice(vs_l, vsc, (0, 0, 0))
            return xc, (k_l, v_l, ks_l, vs_l)
        k_l = jax.lax.dynamic_update_slice(k_l, k.astype(k_l.dtype), (0, 0, 0, 0))
        v_l = jax.lax.dynamic_update_slice(v_l, v.astype(v_l.dtype), (0, 0, 0, 0))
        if env.axes:
            k_l, v_l = offload.constrain_cache(env, k_l, v_l)
        return xc, (k_l, v_l)

    if quant:
        x, (k_new, v_new, ks_new, vs_new) = jax.lax.scan(
            scan_body, x,
            (params["blocks"], cache["k"], cache["v"], cache["k_scale"], cache["v_scale"]),
        )
    else:
        x, (k_new, v_new) = jax.lax.scan(
            scan_body, x, (params["blocks"], cache["k"], cache["v"])
        )
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = cm.unembed(x[:, -1], _unembed_table(params), cfg.vocab)
    new_cache = {
        "k": k_new,
        "v": v_new,
        "lengths": jnp.full((B,), S, jnp.int32),
    }
    if quant:
        new_cache["k_scale"] = ks_new
        new_cache["v_scale"] = vs_new
    return logits, new_cache


# ---------------------------------------------------------------------------
# chunked prefill (Sarathi-style continuation; serving/scheduler.py)
# ---------------------------------------------------------------------------
def prefill_step(cfg, env: Env, params, cache, tokens, slot, q_offset, n_valid):
    """Prefill continuation: one chunk of one slot's prompt against the
    live cache.

    ``tokens`` (1, C) is the chunk padded to a bucket size; ``slot``,
    ``q_offset`` and ``n_valid`` are traced scalars (no recompile across
    slots/offsets/prompt lengths — only the bucket C is a shape).  The
    chunk's K/V are written at absolute positions ``q_offset ..
    q_offset+C-1`` of ``slot``'s cache stripe (out-of-range pad positions
    drop; in-range pad garbage is causally masked and overwritten by the
    next chunk or decode append), attention runs at ``q_offset`` against
    the stripe, and the slot length becomes ``q_offset + n_valid``.
    Returns next-token logits (1, V) at chunk position ``n_valid - 1``
    and the updated cache.  This is the GEMM-shaped half of a fused
    hybrid step: one weight stream serves it and the GEMV-shaped decode
    batch together (the paper's co-processing, on one mesh).
    """
    if cfg.kv_quant:
        raise NotImplementedError("chunked prefill does not support kv_quant yet")
    C = tokens.shape[1]
    x = cm.embed_lookup(params["embed"], tokens)                  # (1, C, D)
    q_offset = jnp.asarray(q_offset, jnp.int32)
    slot = jnp.asarray(slot, jnp.int32)
    positions = q_offset + jnp.arange(C, dtype=jnp.int32)[None]   # (1, C)

    def scan_body(xc, xs):
        p, k_l, v_l = xs                   # k_l/v_l (B, S, Hkv, Dh)
        h = cm.rmsnorm(xc, p["ln1"], cfg.norm_eps)
        q, k, v = _qkv(cfg, p, h)
        q = cm.rope(q, positions, cfg.rope_theta)
        k = cm.rope(k, positions, cfg.rope_theta)
        k_l = k_l.at[slot, positions[0]].set(k[0].astype(k_l.dtype))
        v_l = v_l.at[slot, positions[0]].set(v[0].astype(v_l.dtype))
        k_row = jax.lax.dynamic_index_in_dim(k_l, slot, axis=0, keepdims=True)
        v_row = jax.lax.dynamic_index_in_dim(v_l, slot, axis=0, keepdims=True)
        o = offload.prefill_attention(env, q, k_row, v_row, q_offset=q_offset)
        xc = xc + jnp.einsum("bshk,hkd->bsd", o, p["wo"])
        h = cm.rmsnorm(xc, p["ln2"], cfg.norm_eps)
        xc = xc + cm.swiglu(h, p["w_gate"], p["w_up"], p["w_down"])
        if env.axes:
            k_l, v_l = offload.constrain_cache(env, k_l, v_l)
        return xc, (k_l, v_l)

    x, (k_new, v_new) = jax.lax.scan(
        scan_body, x, (params["blocks"], cache["k"], cache["v"])
    )
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    # unembed only the last valid position (the chunk's next-token logits)
    h_last = jax.lax.dynamic_slice(
        x, (jnp.int32(0), jnp.asarray(n_valid, jnp.int32) - 1, jnp.int32(0)),
        (1, 1, x.shape[-1]),
    )[:, 0]
    logits = cm.unembed(h_last, _unembed_table(params), cfg.vocab)
    lengths = cache["lengths"].at[slot].set(q_offset + jnp.asarray(n_valid, jnp.int32))
    return logits, {"k": k_new, "v": v_new, "lengths": lengths}


# ---------------------------------------------------------------------------
# speculative verify (draft-verify decoding; serving/engine.py)
# ---------------------------------------------------------------------------
def verify_step(cfg, env: Env, params, cache, tokens):
    """Score T speculative tokens per slot against the live cache in one
    dispatched program.

    ``tokens`` (B, T) are each slot's next inputs ``[t0, d_1 .. d_{T-1}]``
    (the fed-back token followed by draft proposals); input ``t`` of slot
    ``b`` lands at absolute cache position ``lengths[b] + t``.  K/V for
    all T positions are written unconditionally — rejected tails are
    garbage *past* the committed length, causally invisible, and
    overwritten by whatever writes those positions next — and ``lengths``
    is returned unchanged: the caller commits the accepted prefix by
    setting ``lengths + n_accept + 1`` (the KV "rollback" is just not
    advancing past it).  Returns logits (B, T, V), position ``t`` scoring
    the successor of input ``t``, and the updated cache.

    Internally this is T statically-unrolled :func:`decode_step` passes
    — the *same* arithmetic, op for op, as non-speculative decoding —
    NOT one T-wide attention GEMM.  That choice is deliberate: greedy
    speculative serving must be token-identical to the plain engine, and
    a differently-shaped attention program (batched verify vs per-token
    decode) rounds bf16 logits differently, flipping argmax near ties.
    The speculative win this repo measures is dispatch-count (one
    program, one host round-trip, one scheduler step per k+1 tokens);
    the weights still stream T times within the program.
    """
    if cfg.kv_quant:
        raise NotImplementedError("verify_step does not support kv_quant yet")
    lengths = cache["lengths"]
    T = tokens.shape[1]
    step = {"k": cache["k"], "v": cache["v"], "lengths": lengths}
    logits = []
    for t in range(T):
        lg, step = decode_step(cfg, env, params, step, tokens[:, t])
        logits.append(lg)
    return jnp.stack(logits, axis=1), {
        "k": step["k"], "v": step["v"], "lengths": lengths,
    }


def paged_verify_step(cfg, env: Env, params, cache, tokens):
    """Paged-pool analogue of :func:`verify_step`: T statically-unrolled
    :func:`paged_decode_step`-equivalent passes in one dispatched program
    — the same arithmetic, op for op, as non-speculative paged decoding
    (see :func:`verify_step` for why bitwise-identical decode math is
    load-bearing for greedy serving).

    One addressing difference from the plain decode body: a position past
    the block table (speculative overshoot at the cache edge) is routed
    to the null block 0, the pool's designated garbage sink.  Plain
    decode can never append out of table (the engine finishes or preempts
    first), but a verify window writes k+1 positions ahead of the
    committed length, so the edge is reachable and a clamped gather would
    otherwise silently corrupt a live block.  Quantized pools and the
    host tier are not supported under speculation (the engine validates).
    """
    if _kv_dtype_name(cache["k"].dtype):
        raise NotImplementedError("paged_verify_step: quantized pools unsupported")
    if "host_k" in cache:
        raise NotImplementedError("paged_verify_step: host KV tier unsupported")
    lengths0 = cache["lengths"]         # (B,)
    tables = cache["block_tables"]      # (B, max_blocks) int32
    bs = cache["k"].shape[3]
    max_blocks = tables.shape[1]
    B, T = tokens.shape
    bidx = jnp.arange(B)
    k_pool, v_pool = cache["k"], cache["v"]
    lengths = lengths0
    logits = []
    for t in range(T):
        x = cm.embed_lookup(params["embed"], tokens[:, t])  # (B, D)
        pos = lengths[:, None]
        blk = lengths // bs
        phys = jnp.where(blk < max_blocks,
                         tables[bidx, jnp.minimum(blk, max_blocks - 1)], 0)
        off = lengths % bs

        def scan_body(xc, xs, pos=pos, phys=phys, off=off, lengths=lengths):
            p, k_l, v_l = xs            # pools (n_blocks, Hkv, bs, Dh)
            h = cm.rmsnorm(xc, p["ln1"], cfg.norm_eps)
            q = jnp.einsum("bd,dhk->bhk", h, p["wq"])
            k = jnp.einsum("bd,dhk->bhk", h, p["wk"])
            v = jnp.einsum("bd,dhk->bhk", h, p["wv"])
            q = cm.rope(q[:, None], pos, cfg.rope_theta)[:, 0]
            k = cm.rope(k[:, None], pos, cfg.rope_theta)[:, 0]
            # advanced indices (phys, off) straddle the head slice, so
            # the selected (B, Hkv, Dh) lands batch-first — matching k/v
            k_l = k_l.at[phys, :, off].set(k.astype(k_l.dtype))
            v_l = v_l.at[phys, :, off].set(v.astype(v_l.dtype))
            o = offload.paged_decode_attention(
                env, q, k_l, v_l, tables, lengths + 1
            )
            xc = xc + jnp.einsum("bhk,hkd->bd", o, p["wo"])
            h = cm.rmsnorm(xc, p["ln2"], cfg.norm_eps)
            xc = xc + cm.swiglu(h, p["w_gate"], p["w_up"], p["w_down"])
            return xc, (k_l, v_l)

        x, (k_pool, v_pool) = jax.lax.scan(
            scan_body, x, (params["blocks"], k_pool, v_pool)
        )
        x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits.append(cm.unembed(x, _unembed_table(params), cfg.vocab))
        lengths = lengths + 1
    new_cache = dict(cache)
    new_cache |= {"k": k_pool, "v": v_pool, "lengths": lengths0}
    return jnp.stack(logits, axis=1), new_cache


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def decode_step(cfg, env: Env, params, cache, tokens):
    """One autoregressive step.  tokens (B,) -> logits (B, V), updated cache."""
    lengths = cache["lengths"]  # (B,) current counts; new token at index lengths
    B = tokens.shape[0]
    x = cm.embed_lookup(params["embed"], tokens)  # (B, D)
    pos = lengths[:, None]  # (B, 1)
    bidx = jnp.arange(B)

    quant = cfg.kv_quant

    def scan_body(xc, xs):
        if quant:
            p, k_l, v_l, ks_l, vs_l = xs
        else:
            p, k_l, v_l = xs
        h = cm.rmsnorm(xc, p["ln1"], cfg.norm_eps)
        q = jnp.einsum("bd,dhk->bhk", h, p["wq"])
        k = jnp.einsum("bd,dhk->bhk", h, p["wk"])
        v = jnp.einsum("bd,dhk->bhk", h, p["wv"])
        q = cm.rope(q[:, None], pos, cfg.rope_theta)[:, 0]
        k = cm.rope(k[:, None], pos, cfg.rope_theta)[:, 0]
        if quant:
            kq, ksc = _kv_quantize(k)
            vq, vsc = _kv_quantize(v)
            k_l = k_l.at[bidx, lengths].set(kq)
            v_l = v_l.at[bidx, lengths].set(vq)
            ks_l = ks_l.at[bidx, lengths].set(ksc)
            vs_l = vs_l.at[bidx, lengths].set(vsc)
            o = offload.decode_attention(
                env, q, _kv_dequantize(k_l, ks_l), _kv_dequantize(v_l, vs_l),
                lengths + 1,
            )
        else:
            k_l = k_l.at[bidx, lengths].set(k.astype(k_l.dtype))
            v_l = v_l.at[bidx, lengths].set(v.astype(v_l.dtype))
            o = offload.decode_attention(env, q, k_l, v_l, lengths + 1)
        xc = xc + jnp.einsum("bhk,hkd->bd", o, p["wo"])
        h = cm.rmsnorm(xc, p["ln2"], cfg.norm_eps)
        xc = xc + cm.swiglu(h, p["w_gate"], p["w_up"], p["w_down"])
        if quant:
            return xc, (k_l, v_l, ks_l, vs_l)
        return xc, (k_l, v_l)

    if quant:
        x, (k_new, v_new, ks_new, vs_new) = jax.lax.scan(
            scan_body, x,
            (params["blocks"], cache["k"], cache["v"], cache["k_scale"], cache["v_scale"]),
        )
    else:
        x, (k_new, v_new) = jax.lax.scan(
            scan_body, x, (params["blocks"], cache["k"], cache["v"])
        )
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = cm.unembed(x, _unembed_table(params), cfg.vocab)
    new_cache = {"k": k_new, "v": v_new, "lengths": lengths + 1}
    if quant:
        new_cache["k_scale"] = ks_new
        new_cache["v_scale"] = vs_new
    return logits, new_cache


# ---------------------------------------------------------------------------
# sampled steps (async engine): tokens in -> sampled tokens out, on device
# ---------------------------------------------------------------------------
# The async engine never reads logits on the host: each step returns the
# sampled next-token ids plus a per-slot EOS hit flag, so only [batch]
# ints cross the host boundary and the next step's inputs can be fed back
# device-to-device (serving/engine.py dispatch-ahead pipeline).  ``rng``
# is a traced key (unused for greedy); ``eos_ids`` is a per-slot int32
# vector (-1 = never stops); ``sampler`` must be static under jit.

def decode_sample_step(cfg, env: Env, params, cache, tokens, rng, eos_ids, *, sampler):
    """One decode step with sampling fused: (tokens', eos_hit, cache)."""
    logits, cache = decode_step(cfg, env, params, cache, tokens)
    tok = sample_on_device(logits, rng, sampler)
    return tok, tok == eos_ids, cache


def paged_decode_sample_step(cfg, env: Env, params, cache, tokens, rng, eos_ids, *, sampler):
    """Paged-pool analogue of :func:`decode_sample_step`."""
    logits, cache = paged_decode_step(cfg, env, params, cache, tokens)
    tok = sample_on_device(logits, rng, sampler)
    return tok, tok == eos_ids, cache


def prefill_sample_step(cfg, env: Env, params, cache, tokens, slot, q_offset,
                        n_valid, rng, *, sampler):
    """Chunked-prefill continuation with the first generated token sampled
    on device: returns (token (1,), cache).  Only meaningful on a prompt's
    final chunk; earlier chunks' sampled token is dead and ignored."""
    logits, cache = prefill_step(cfg, env, params, cache, tokens, slot, q_offset, n_valid)
    return sample_on_device(logits, rng, sampler), cache
