"""Seamless-M4T backbone: speech encoder (stub frontend) + AR text decoder.

The speech frontend is a stub per the brief: ``batch["src_embeds"]``
(B, frontend_len, d_model) precomputed frame embeddings feed the encoder.
The decoder has causal self-attention (cached, offloaded) and
cross-attention whose KV is computed once at prefill — the write-once/
read-every-step "ideal offload" case noted in DESIGN.md §4.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import offload
from repro.core.placement import Env
from repro.models import common as cm
from repro.models.attention import chunked_attention
from repro.models.common import ParamDef

Pytree = Any


def _dims(cfg):
    return cfg.d_model, cfg.n_heads, cfg.resolved_head_dim()


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------
def _attn(cfg, L, prefix=""):
    D, H, Dh = _dims(cfg)
    return {
        prefix + "wq": ParamDef((L, D, H, Dh), ("layers", "embed", "heads", "head_dim")),
        prefix + "wk": ParamDef((L, D, H, Dh), ("layers", "embed", "kv_heads", "head_dim")),
        prefix + "wv": ParamDef((L, D, H, Dh), ("layers", "embed", "kv_heads", "head_dim")),
        prefix + "wo": ParamDef((L, H, Dh, D), ("layers", "heads", "head_dim", "embed")),
    }


def _mlp(cfg, L):
    D, F = cfg.d_model, cfg.d_ff
    return {
        "w_gate": ParamDef((L, D, F), ("layers", "embed", "mlp")),
        "w_up": ParamDef((L, D, F), ("layers", "embed", "mlp")),
        "w_down": ParamDef((L, F, D), ("layers", "mlp", "embed")),
    }


def param_defs(cfg) -> Pytree:
    D, V = cfg.d_model, cfg.padded_vocab()
    Le, Ld = cfg.n_enc_layers, cfg.n_layers
    enc = {
        "ln1": ParamDef((Le, D), ("layers", "embed"), "zeros"),
        **_attn(cfg, Le),
        "ln2": ParamDef((Le, D), ("layers", "embed"), "zeros"),
        **_mlp(cfg, Le),
    }
    dec = {
        "ln1": ParamDef((Ld, D), ("layers", "embed"), "zeros"),
        **_attn(cfg, Ld),
        "lnx": ParamDef((Ld, D), ("layers", "embed"), "zeros"),
        **_attn(cfg, Ld, prefix="x_"),
        "ln2": ParamDef((Ld, D), ("layers", "embed"), "zeros"),
        **_mlp(cfg, Ld),
    }
    return {
        "embed": ParamDef((V, D), ("vocab", "embed"), "embed"),
        "enc_blocks": enc,
        "enc_norm": ParamDef((D,), ("embed",), "zeros"),
        "dec_blocks": dec,
        "final_norm": ParamDef((D,), ("embed",), "zeros"),
        "unembed": ParamDef((V, D), ("vocab", "embed"), "embed"),
    }


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------
def encode(cfg, env: Env, params, src_embeds, remat: bool = True):
    """src_embeds (B, T, D) -> encoder hidden (B, T, D)."""
    x = src_embeds.astype(cm.param_dtype(cfg))

    def block(p, xc):
        h = cm.rmsnorm(xc, p["ln1"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
        o = chunked_attention(q, k, v, causal=False)
        xc = xc + jnp.einsum("bshk,hkd->bsd", o, p["wo"])
        h = cm.rmsnorm(xc, p["ln2"], cfg.norm_eps)
        return xc + cm.swiglu(h, p["w_gate"], p["w_up"], p["w_down"])

    blk = jax.checkpoint(block, policy=jax.checkpoint_policies.nothing_saveable) if remat else block

    def body(xc, p):
        return blk(p, xc), None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return cm.rmsnorm(x, params["enc_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# decoder blocks
# ---------------------------------------------------------------------------
def _dec_block_train(cfg, env: Env, p, x, enc_out, positions):
    h = cm.rmsnorm(x, p["ln1"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
    q = cm.rope(q, positions, cfg.rope_theta)
    k = cm.rope(k, positions, cfg.rope_theta)
    o = offload.prefill_attention(env, q, k, v)
    x = x + jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    # cross attention
    h = cm.rmsnorm(x, p["lnx"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["x_wq"])
    xk = jnp.einsum("btd,dhk->bthk", enc_out, p["x_wk"])
    xv = jnp.einsum("btd,dhk->bthk", enc_out, p["x_wv"])
    o = chunked_attention(q, xk, xv, causal=False)
    x = x + jnp.einsum("bshk,hkd->bsd", o, p["x_wo"])
    h = cm.rmsnorm(x, p["ln2"], cfg.norm_eps)
    x = x + cm.swiglu(h, p["w_gate"], p["w_up"], p["w_down"])
    if env.axes:
        x = jax.lax.with_sharding_constraint(
            x, env.act_spec(("batch", "seq", "embed"), x.shape)
        )
    return x, (xk, xv)


def loss_fn(cfg, env: Env, params, batch):
    enc_out = encode(cfg, env, params, batch["src_embeds"])
    x = cm.embed_lookup(params["embed"], batch["inputs"])
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    blk = jax.checkpoint(
        partial(_dec_block_train, cfg, env),
        policy=jax.checkpoint_policies.nothing_saveable,
    )

    def body(xc, p):
        xc, _ = blk(p, xc, enc_out, positions)
        return xc, None

    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = cm.unembed(x, params["unembed"], cfg.vocab)
    loss = cm.cross_entropy_loss(logits, batch["targets"], batch.get("mask"))
    return loss, {"loss": loss}


# ---------------------------------------------------------------------------
# cache / prefill / decode
# ---------------------------------------------------------------------------
def cache_defs(cfg, batch: int, max_seq: int) -> Pytree:
    D, H, Dh = _dims(cfg)
    Ld, T = cfg.n_layers, cfg.frontend_len
    kv_self = ParamDef(
        (Ld, batch, max_seq, H, Dh),
        ("layers", "kv_batch", "kv_seq", "kv_heads", "head_dim"),
        "zeros",
    )
    kv_cross = ParamDef(
        (Ld, batch, T, H, Dh),
        ("layers", "kv_batch", "kv_seq", "kv_heads", "head_dim"),
        "zeros",
    )
    return {
        "k": kv_self,
        "v": kv_self,
        "xk": kv_cross,
        "xv": kv_cross,
        "lengths": ParamDef((batch,), ("kv_batch",), "zeros"),
    }


def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16) -> Pytree:
    defs = cache_defs(cfg, batch, max_seq)
    return {
        k: jnp.zeros(d.shape, jnp.int32 if k == "lengths" else dtype)
        for k, d in defs.items()
    }


def prefill(cfg, env: Env, params, tokens, cache, embeds=None):
    """embeds = src frame embeddings (B, T, D).  Encodes, fills cross KV,
    then prefills the decoder over ``tokens``."""
    assert embeds is not None, "encdec prefill needs src_embeds"
    enc_out = encode(cfg, env, params, embeds, remat=False)
    x = cm.embed_lookup(params["embed"], tokens)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    dec = params["dec_blocks"]

    def body2(xc, xs):
        p, k_l, v_l, xk_l, xv_l = xs
        h = cm.rmsnorm(xc, p["ln1"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
        q = cm.rope(q, positions, cfg.rope_theta)
        k = cm.rope(k, positions, cfg.rope_theta)
        o = offload.prefill_attention(env, q, k, v)
        xc = xc + jnp.einsum("bshk,hkd->bsd", o, p["wo"])
        h = cm.rmsnorm(xc, p["lnx"], cfg.norm_eps)
        qx = jnp.einsum("bsd,dhk->bshk", h, p["x_wq"])
        xk = jnp.einsum("btd,dhk->bthk", enc_out, p["x_wk"])
        xv = jnp.einsum("btd,dhk->bthk", enc_out, p["x_wv"])
        o = chunked_attention(qx, xk, xv, causal=False)
        xc = xc + jnp.einsum("bshk,hkd->bsd", o, p["x_wo"])
        h = cm.rmsnorm(xc, p["ln2"], cfg.norm_eps)
        xc = xc + cm.swiglu(h, p["w_gate"], p["w_up"], p["w_down"])
        k_l = jax.lax.dynamic_update_slice(k_l, k.astype(k_l.dtype), (0, 0, 0, 0))
        v_l = jax.lax.dynamic_update_slice(v_l, v.astype(v_l.dtype), (0, 0, 0, 0))
        return xc, (k_l, v_l, xk.astype(xk_l.dtype), xv.astype(xv_l.dtype))

    x, (k_n, v_n, xk_n, xv_n) = jax.lax.scan(
        body2, x, (dec, cache["k"], cache["v"], cache["xk"], cache["xv"])
    )
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = cm.unembed(x[:, -1], params["unembed"], cfg.vocab)
    new_cache = {
        "k": k_n,
        "v": v_n,
        "xk": xk_n,
        "xv": xv_n,
        "lengths": jnp.full((B,), S, jnp.int32),
    }
    return logits, new_cache


def decode_step(cfg, env: Env, params, cache, tokens):
    lengths = cache["lengths"]
    B = tokens.shape[0]
    T = cache["xk"].shape[2]
    x = cm.embed_lookup(params["embed"], tokens)
    pos = lengths[:, None]
    bidx = jnp.arange(B)
    xT = jnp.full((B,), T, jnp.int32)

    def body(xc, xs):
        p, k_l, v_l, xk_l, xv_l = xs
        h = cm.rmsnorm(xc, p["ln1"], cfg.norm_eps)
        q = jnp.einsum("bd,dhk->bhk", h, p["wq"])
        k = jnp.einsum("bd,dhk->bhk", h, p["wk"])
        v = jnp.einsum("bd,dhk->bhk", h, p["wv"])
        q = cm.rope(q[:, None], pos, cfg.rope_theta)[:, 0]
        k = cm.rope(k[:, None], pos, cfg.rope_theta)[:, 0]
        k_l = k_l.at[bidx, lengths].set(k.astype(k_l.dtype))
        v_l = v_l.at[bidx, lengths].set(v.astype(v_l.dtype))
        o = offload.decode_attention(env, q, k_l, v_l, lengths + 1)
        xc = xc + jnp.einsum("bhk,hkd->bd", o, p["wo"])
        h = cm.rmsnorm(xc, p["lnx"], cfg.norm_eps)
        qx = jnp.einsum("bd,dhk->bhk", h, p["x_wq"])
        o = offload.decode_attention(env, qx, xk_l, xv_l, xT)
        xc = xc + jnp.einsum("bhk,hkd->bd", o, p["x_wo"])
        h = cm.rmsnorm(xc, p["ln2"], cfg.norm_eps)
        xc = xc + cm.swiglu(h, p["w_gate"], p["w_up"], p["w_down"])
        return xc, (k_l, v_l, xk_l, xv_l)

    x, (k_n, v_n, xk_n, xv_n) = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["k"], cache["v"], cache["xk"], cache["xv"])
    )
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = cm.unembed(x, params["unembed"], cfg.vocab)
    new_cache = {
        "k": k_n,
        "v": v_n,
        "xk": xk_n,
        "xv": xv_n,
        "lengths": lengths + 1,
    }
    return logits, new_cache
