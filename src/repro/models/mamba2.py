"""Mamba2 SSD block (building block for zamba2).

x -> in_proj -> [z, xBC, dt];  xBC -> causal depthwise conv -> silu ->
[x', B, C];  SSD recurrence per head (state (P, N), scalar decay per head):

    h_t = exp(dt_t * A_h) h_{t-1} + dt_t * (x'_t  (x)  B_t)
    y_t = C_t . h_t + D_h * x'_t

then gated RMSNorm(y * silu(z)) -> out_proj.  Train uses ``lax.scan`` over
time in fp32; decode is a single-step update (O(1) memory, so the hybrid
runs long_500k).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef

Pytree = Any


def dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.d_head
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    d_in_proj = 2 * d_inner + 2 * s.n_groups * s.d_state + H
    return d_inner, H, conv_dim, d_in_proj


def param_defs(cfg, L: int) -> Pytree:
    s = cfg.ssm
    D = cfg.d_model
    d_inner, H, conv_dim, d_in_proj = dims(cfg)
    return {
        "ln_s": ParamDef((L, D), ("layers", "embed"), "zeros"),
        "in_proj": ParamDef((L, D, d_in_proj), ("layers", "embed", "mlp")),
        "conv_w": ParamDef((L, s.d_conv, conv_dim), ("layers", None, "mlp"), "small"),
        "conv_b": ParamDef((L, conv_dim), ("layers", "mlp"), "zeros"),
        "dt_bias": ParamDef((L, H), ("layers", "state"), "zeros"),
        "A_log": ParamDef((L, H), ("layers", "state"), "zeros"),
        "D_skip": ParamDef((L, H), ("layers", "state"), "ones"),
        "norm_s": ParamDef((L, d_inner), ("layers", "mlp"), "zeros"),
        "out_proj": ParamDef((L, d_inner, D), ("layers", "mlp", "embed")),
    }


def _ssd_scan(xp, Bm, Cm, dt, A, state, chunk: int = 256):
    """xp (B,S,H,P); Bm/Cm (B,S,H,N); dt (B,S,H); A (H,); state (B,H,P,N) fp32.

    Time-chunked remat like rwkv6._wkv_scan: only chunk-boundary states are
    saved for backward (the full fp32 state trajectory otherwise dominates
    hybrid train memory)."""

    def step(s, inp):
        x_t, b_t, c_t, dt_t = inp  # (B,H,P), (B,H,N), (B,H,N), (B,H)
        decay = jnp.exp(dt_t * A)[..., None, None]  # (B,H,1,1)
        s = decay * s + (dt_t[..., None] * x_t)[..., None] * b_t[..., None, :]
        y = jnp.einsum("bhpn,bhn->bhp", s, c_t)
        return s, y

    B, S = xp.shape[:2]
    if S <= chunk or S % chunk:
        xs = tuple(jnp.moveaxis(t, 1, 0) for t in (xp, Bm, Cm, dt))
        state, ys = jax.lax.scan(step, state, xs)
        return jnp.moveaxis(ys, 0, 1), state  # (B,S,H,P), (B,H,P,N)

    n_c = S // chunk

    def split(t):
        return jnp.moveaxis(
            t.reshape((B, n_c, chunk) + t.shape[2:]), 1, 0
        )  # (n_c, B, chunk, ...)

    xs = tuple(split(t) for t in (xp, Bm, Cm, dt))

    @jax.checkpoint
    def chunk_step(s, inp):
        inner = tuple(jnp.moveaxis(t, 1, 0) for t in inp)
        s, ys = jax.lax.scan(step, s, inner)
        return s, jnp.moveaxis(ys, 0, 1)

    state, ys = jax.lax.scan(chunk_step, state, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape((B, S) + ys.shape[3:])
    return y, state


def forward(cfg, p, x, conv_state, ssm_state, norm_eps=1e-5):
    """One mamba2 layer over a segment.

    x (B,S,D); conv_state (B,d_conv-1,conv_dim); ssm_state (B,H,P,N) fp32.
    Returns (out (B,S,D), new_conv_state, new_ssm_state).
    """
    s = cfg.ssm
    d_inner, H, conv_dim, _ = dims(cfg)
    B, S, D = x.shape
    from repro.models import common as cm

    h = cm.rmsnorm(x, p["ln_s"], norm_eps)
    zxbcdt = jnp.einsum("bsd,de->bse", h, p["in_proj"])
    z, xBC, dt = jnp.split(zxbcdt, [d_inner, d_inner + conv_dim], axis=-1)

    # causal depthwise conv with carried state.  (A shifted-sum variant was
    # measured identical on the memory term but 2x slower to compile —
    # refuted & reverted; XLA already fuses the stacked windows.)
    full = jnp.concatenate([conv_state.astype(xBC.dtype), xBC], axis=1)
    new_conv_state = full[:, -(s.d_conv - 1) :] if s.d_conv > 1 else conv_state
    windows = jnp.stack(
        [full[:, i : i + S] for i in range(s.d_conv)], axis=-1
    )  # (B,S,conv_dim,d_conv)
    xBC = jnp.einsum("bsck,kc->bsc", windows, p["conv_w"]) + p["conv_b"]
    xBC = jax.nn.silu(xBC)

    xp, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + s.n_groups * s.d_state], axis=-1)
    xp = xp.reshape(B, S, H, s.d_head).astype(jnp.float32)
    Bm = Bm.reshape(B, S, s.n_groups, s.d_state).astype(jnp.float32)
    Cm = Cm.reshape(B, S, s.n_groups, s.d_state).astype(jnp.float32)
    rep = H // s.n_groups
    Bm = jnp.repeat(Bm, rep, axis=2)
    Cm = jnp.repeat(Cm, rep, axis=2)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    y, ssm_state = _ssd_scan(xp, Bm, Cm, dtv, A, ssm_state)
    y = y + p["D_skip"].astype(jnp.float32)[None, None, :, None] * xp
    y = y.reshape(B, S, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = cm.rmsnorm(y, p["norm_s"], norm_eps)
    out = jnp.einsum("bse,ed->bsd", y.astype(x.dtype), p["out_proj"])
    return out, new_conv_state, ssm_state
