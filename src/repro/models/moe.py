"""MoE FFN + the moonshot-v1-16b-a3b family (GQA attention + MoE layers).

Router: top-k with softmax or sigmoid scoring (DeepSeek-V3 style), switch
load-balance aux loss.  Dispatch is scatter-based (no (T,E,C) one-hot):
tokens are scatter-added into per-expert capacity buffers, expert GEMMs run
as one batched einsum (EP: `experts` sharded over `model`), and results
gather back.  Overflow beyond capacity is dropped to a garbage row
(capacity factor 1.25), the standard dropping formulation.

Shared experts are a plain dense SwiGLU of width n_shared*d_expert.
First ``moe_layer_start`` layers are dense with d_ff = cfg.d_ff.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import offload
from repro.core.placement import Env
from repro.models import common as cm
from repro.models import dense
from repro.models.common import ParamDef

Pytree = Any



# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------
def _attn_defs(cfg, L):
    D, Hq, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim()
    return {
        "ln1": ParamDef((L, D), ("layers", "embed"), "zeros"),
        "wq": ParamDef((L, D, Hq, Dh), ("layers", "embed", "heads", "head_dim")),
        "wk": ParamDef((L, D, Hkv, Dh), ("layers", "embed", "kv_heads", "head_dim")),
        "wv": ParamDef((L, D, Hkv, Dh), ("layers", "embed", "kv_heads", "head_dim")),
        "wo": ParamDef((L, Hq, Dh, D), ("layers", "heads", "head_dim", "embed")),
        "ln2": ParamDef((L, D), ("layers", "embed"), "zeros"),
    }


def moe_ffn_defs(cfg, L) -> Pytree:
    m = cfg.moe
    D, E, Fe = cfg.d_model, m.n_experts, m.d_expert
    defs = {
        "router": ParamDef((L, D, E), ("layers", "embed", None), "small"),
        "we_gate": ParamDef((L, E, D, Fe), ("layers", "experts", "embed", None)),
        "we_up": ParamDef((L, E, D, Fe), ("layers", "experts", "embed", None)),
        "we_down": ParamDef((L, E, Fe, D), ("layers", "experts", None, "embed")),
    }
    if m.n_shared:
        Fs = m.n_shared * Fe
        defs.update(
            ws_gate=ParamDef((L, D, Fs), ("layers", "embed", "mlp")),
            ws_up=ParamDef((L, D, Fs), ("layers", "embed", "mlp")),
            ws_down=ParamDef((L, Fs, D), ("layers", "mlp", "embed")),
        )
    return defs


def param_defs(cfg) -> Pytree:
    m = cfg.moe
    L_dense, L_moe = m.moe_layer_start, cfg.n_layers - m.moe_layer_start
    D, V, F = cfg.d_model, cfg.padded_vocab(), cfg.d_ff
    dense_blocks = {
        **_attn_defs(cfg, L_dense),
        "w_gate": ParamDef((L_dense, D, F), ("layers", "embed", "mlp")),
        "w_up": ParamDef((L_dense, D, F), ("layers", "embed", "mlp")),
        "w_down": ParamDef((L_dense, F, D), ("layers", "mlp", "embed")),
    }
    moe_blocks = {**_attn_defs(cfg, L_moe), **moe_ffn_defs(cfg, L_moe)}
    defs = {
        "embed": ParamDef((V, D), ("vocab", "embed"), "embed"),
        "dense_blocks": dense_blocks,
        "moe_blocks": moe_blocks,
        "final_norm": ParamDef((D,), ("embed",), "zeros"),
    }
    if not cfg.tie_embeddings:
        defs["unembed"] = ParamDef((V, D), ("vocab", "embed"), "embed")
    return defs


# ---------------------------------------------------------------------------
# MoE FFN compute
# ---------------------------------------------------------------------------
def router_scores(cfg, router_w, x):
    """(T, D) -> (weights (T,K), idx (T,K), aux_loss scalar)."""
    m = cfg.moe
    logits = jnp.einsum(
        "td,de->te", x.astype(jnp.float32), router_w.astype(jnp.float32)
    )
    if m.score_func == "sigmoid":
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(scores, m.top_k)
    topw = topw / jnp.maximum(jnp.sum(topw, -1, keepdims=True), 1e-9)
    # switch-style load-balance aux: E * sum_e f_e * P_e
    probs = jax.nn.softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(topi, m.n_experts, dtype=jnp.float32)  # (T,K,E)
    f = jnp.mean(jnp.sum(onehot, axis=1), axis=0)  # fraction routed per e
    p = jnp.mean(probs, axis=0)
    aux = m.n_experts * jnp.sum(f * p) / m.top_k
    return topw, topi, aux


def _moe_dispatch_local(cfg, p, x):
    """Dropping-MoE dispatch for one DP rank's tokens.  x (T_l, D)."""
    m = cfg.moe
    T, D = x.shape
    E, K = m.n_experts, m.top_k
    topw, topi, aux = router_scores(cfg, p["router"], x)

    capacity = max(int(math.ceil(T * K / E * m.capacity_factor)), K)
    e_flat = topi.reshape(-1)  # (M,) M = T*K
    # position of each assignment within its expert (one-hot cumsum trick)
    oh = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)  # (M, E)
    pos = (jnp.cumsum(oh, axis=0) * oh).sum(-1) - 1  # (M,)
    dropped = pos >= capacity
    pos_safe = jnp.where(dropped, capacity, pos)  # overflow -> garbage row

    tok_idx = jnp.repeat(jnp.arange(T), K)  # (M,)
    disp = jnp.zeros((E, capacity + 1, D), x.dtype)
    disp = disp.at[e_flat, pos_safe].add(x[tok_idx])
    return disp, (e_flat, pos_safe, dropped, tok_idx, topw), aux


def _moe_combine_local(cfg, out_e, meta, T, D):
    e_flat, pos_safe, dropped, tok_idx, topw = meta
    gathered = out_e[e_flat, pos_safe]  # (M, D)
    gathered = jnp.where(dropped[:, None], 0.0, gathered)
    w_flat = topw.reshape(-1).astype(gathered.dtype)
    return jnp.zeros((T, D), gathered.dtype).at[tok_idx].add(
        gathered * w_flat[:, None]
    )


def moe_ffn(cfg, env: Env, p, x):
    """x (T, D) -> (T, D), aux_loss.  p: per-layer slice of moe_ffn_defs.

    Dispatch is computed *per data-parallel rank* (vmap over a leading DP
    axis sharded on `data`): positions/capacity are rank-local, so no
    cross-rank cumsum, and the dispatch buffer is sharded over BOTH data
    (capacity) and model (experts) — the standard EP x DP decomposition.
    The token->expert exchange shows up as the expected all-to-all on the
    (dp, E) -> (E-shard) boundary.
    """
    m = cfg.moe
    T, D = x.shape
    dp = 1
    if env.axes and (not env.ep_wide or env.moe_a2a):
        # rank-local dispatch; with ep_wide (experts over data x model) the
        # dispatch must be global (dp=1) or use the a2a flip — a dp-sharded
        # dispatch against 256-way expert weights makes GSPMD all-gather
        # the experts (measured: §Perf iter. 4 regression)
        dp = env.axes.get("pod", 1) * env.axes.get("data", 1)
    if T % dp:
        dp = 1
    ep_flip = bool(env.ep_wide and env.moe_a2a and env.axes and dp > 1)
    xg = x.reshape(dp, T // dp, D)
    if env.axes:
        xg = jax.lax.with_sharding_constraint(
            xg, env.act_spec(("batch", None, "embed"), xg.shape)
        )

    disp, meta, aux = jax.vmap(partial(_moe_dispatch_local, cfg, p))(xg)
    if ep_flip:
        # EP-wide: flip the dispatch buffer from rank-sharded (dp over
        # pod/data) to expert-sharded over ALL axes — GSPMD lowers the
        # resharding transpose as an all-to-all carrying only the token
        # payload (no dispatch-buffer all-reduce) — §Perf iteration 4
        disp = jax.lax.with_sharding_constraint(
            disp, env.act_spec((None, "experts", None, "embed"), disp.shape)
        )
    elif env.axes:
        disp = jax.lax.with_sharding_constraint(
            disp, env.act_spec(("batch", "experts", None, "embed"), disp.shape)
        )

    g = jnp.einsum("recd,edf->recf", disp, p["we_gate"])
    u = jnp.einsum("recd,edf->recf", disp, p["we_up"])
    h = jax.nn.silu(g) * u
    out_e = jnp.einsum("recf,efd->recd", h, p["we_down"])

    if ep_flip:
        # flip back: expert-sharded results return to their owning rank
        out_e = jax.lax.with_sharding_constraint(
            out_e, env.act_spec(("batch", None, None, "embed"), out_e.shape)
        )
    y = jax.vmap(partial(_moe_combine_local, cfg, T=T // dp, D=D))(out_e, meta)
    y = y.reshape(T, D)

    if m.n_shared:
        y = y + cm.swiglu(x, p["ws_gate"], p["ws_up"], p["ws_down"])
    return y.astype(x.dtype), jnp.mean(aux)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------
def _attn_train(cfg, env, p, x, positions):
    h = cm.rmsnorm(x, p["ln1"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
    q = cm.rope(q, positions, cfg.rope_theta)
    k = cm.rope(k, positions, cfg.rope_theta)
    o = offload.prefill_attention(env, q, k, v)
    return x + jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def _moe_block_train(cfg, env, p, x, positions):
    x = _attn_train(cfg, env, p, x, positions)
    h = cm.rmsnorm(x, p["ln2"], cfg.norm_eps)
    B, S, D = h.shape
    y, aux = moe_ffn(cfg, env, p, h.reshape(B * S, D))
    x = x + y.reshape(B, S, D)
    if env.axes:
        x = jax.lax.with_sharding_constraint(
            x, env.act_spec(("batch", "seq", "embed"), x.shape)
        )
    return x, aux


def _dense_block_train(cfg, env, p, x, positions):
    x = _attn_train(cfg, env, p, x, positions)
    h = cm.rmsnorm(x, p["ln2"], cfg.norm_eps)
    x = x + cm.swiglu(h, p["w_gate"], p["w_up"], p["w_down"])
    return x


def hidden_states(cfg, env: Env, params, tokens, embeds=None, remat: bool = True):
    x = cm.embed_lookup(params["embed"], tokens)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    dense_blk = partial(_dense_block_train, cfg, env)
    moe_blk = partial(_moe_block_train, cfg, env)
    if remat:
        dense_blk = jax.checkpoint(dense_blk, policy=jax.checkpoint_policies.nothing_saveable)
        moe_blk = jax.checkpoint(moe_blk, policy=jax.checkpoint_policies.nothing_saveable)

    def dense_body(xc, p_slice):
        return dense_blk(p_slice, xc, positions), None

    def moe_body(carry, p_slice):
        xc, aux = carry
        xc, a = moe_blk(p_slice, xc, positions)
        return (xc, aux + a), None

    x, _ = jax.lax.scan(dense_body, x, params["dense_blocks"])
    (x, aux), _ = jax.lax.scan(moe_body, (x, jnp.float32(0.0)), params["moe_blocks"])
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, aux / max(cfg.n_layers - cfg.moe.moe_layer_start, 1)


def loss_fn(cfg, env: Env, params, batch):
    hid, aux = hidden_states(cfg, env, params, batch["inputs"], batch.get("embeds"))
    n_front = 0 if "embeds" not in batch else batch["embeds"].shape[1]
    hid = hid[:, n_front:]
    logits = cm.unembed(hid, params.get("unembed", params["embed"]), cfg.vocab)
    ce = cm.cross_entropy_loss(logits, batch["targets"], batch.get("mask"))
    loss = ce + cfg.moe.router_aux_coef * aux
    return loss, {"loss": loss, "ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# cache / prefill / decode  (attention identical to dense; FFN swapped)
# ---------------------------------------------------------------------------
cache_defs = dense.cache_defs
init_cache = dense.init_cache


def _split_cache(cfg, cache):
    Ld = cfg.moe.moe_layer_start
    return (
        {k: (v[:Ld] if k != "lengths" else v) for k, v in cache.items()},
        {k: (v[Ld:] if k != "lengths" else v) for k, v in cache.items()},
    )


def prefill(cfg, env: Env, params, tokens, cache, embeds=None):
    x = cm.embed_lookup(params["embed"], tokens)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    dcache, mcache = _split_cache(cfg, cache)

    def body(is_moe):
        def f(xc, xs):
            p, k_l, v_l = xs
            h = cm.rmsnorm(xc, p["ln1"], cfg.norm_eps)
            q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
            k = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
            v = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
            q = cm.rope(q, positions, cfg.rope_theta)
            k = cm.rope(k, positions, cfg.rope_theta)
            o = offload.prefill_attention(env, q, k, v)
            xc = xc + jnp.einsum("bshk,hkd->bsd", o, p["wo"])
            h = cm.rmsnorm(xc, p["ln2"], cfg.norm_eps)
            if is_moe:
                y, _ = moe_ffn(cfg, env, p, h.reshape(B * S, -1))
                xc = xc + y.reshape(B, S, -1)
            else:
                xc = xc + cm.swiglu(h, p["w_gate"], p["w_up"], p["w_down"])
            k_l = jax.lax.dynamic_update_slice(k_l, k.astype(k_l.dtype), (0, 0, 0, 0))
            v_l = jax.lax.dynamic_update_slice(v_l, v.astype(v_l.dtype), (0, 0, 0, 0))
            if env.axes:
                k_l, v_l = offload.constrain_cache(env, k_l, v_l)
            return xc, (k_l, v_l)

        return f

    x, (kd, vd) = jax.lax.scan(
        body(False), x, (params["dense_blocks"], dcache["k"], dcache["v"])
    )
    x, (km, vm) = jax.lax.scan(
        body(True), x, (params["moe_blocks"], mcache["k"], mcache["v"])
    )
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = cm.unembed(x[:, -1], params.get("unembed", params["embed"]), cfg.vocab)
    new_cache = {
        "k": jnp.concatenate([kd, km], 0),
        "v": jnp.concatenate([vd, vm], 0),
        "lengths": jnp.full((B,), S, jnp.int32),
    }
    return logits, new_cache


def decode_step(cfg, env: Env, params, cache, tokens):
    lengths = cache["lengths"]
    B = tokens.shape[0]
    x = cm.embed_lookup(params["embed"], tokens)
    pos = lengths[:, None]
    bidx = jnp.arange(B)
    dcache, mcache = _split_cache(cfg, cache)

    def body(is_moe):
        def f(xc, xs):
            p, k_l, v_l = xs
            h = cm.rmsnorm(xc, p["ln1"], cfg.norm_eps)
            q = jnp.einsum("bd,dhk->bhk", h, p["wq"])
            k = jnp.einsum("bd,dhk->bhk", h, p["wk"])
            v = jnp.einsum("bd,dhk->bhk", h, p["wv"])
            q = cm.rope(q[:, None], pos, cfg.rope_theta)[:, 0]
            k = cm.rope(k[:, None], pos, cfg.rope_theta)[:, 0]
            k_l = k_l.at[bidx, lengths].set(k.astype(k_l.dtype))
            v_l = v_l.at[bidx, lengths].set(v.astype(v_l.dtype))
            o = offload.decode_attention(env, q, k_l, v_l, lengths + 1)
            xc = xc + jnp.einsum("bhk,hkd->bd", o, p["wo"])
            h = cm.rmsnorm(xc, p["ln2"], cfg.norm_eps)
            if is_moe:
                y, _ = moe_ffn(cfg, env, p, h)
                xc = xc + y
            else:
                xc = xc + cm.swiglu(h, p["w_gate"], p["w_up"], p["w_down"])
            return xc, (k_l, v_l)

        return f

    x, (kd, vd) = jax.lax.scan(
        body(False), x, (params["dense_blocks"], dcache["k"], dcache["v"])
    )
    x, (km, vm) = jax.lax.scan(
        body(True), x, (params["moe_blocks"], mcache["k"], mcache["v"])
    )
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = cm.unembed(x, params.get("unembed", params["embed"]), cfg.vocab)
    new_cache = {
        "k": jnp.concatenate([kd, km], 0),
        "v": jnp.concatenate([vd, vm], 0),
        "lengths": lengths + 1,
    }
    return logits, new_cache
