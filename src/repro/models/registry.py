"""Unified Model API: ``build_model(cfg, env)`` -> :class:`Model`.

Every family exposes the same five callables so the trainer, the serving
engine, the dry-run and the benchmarks are family-agnostic.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

from repro.configs.base import DEEPSEEK, DENSE, ENCDEC, MOE, RWKV6, ZAMBA2, ModelConfig
from repro.core.placement import Env
from repro.models import common as cm

Pytree = Any


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    env: Env
    param_defs: Pytree
    loss_fn: Callable[[Pytree, dict], tuple[jax.Array, dict]]
    prefill: Callable[..., tuple[jax.Array, Pytree]]
    decode_step: Callable[[Pytree, Pytree, jax.Array], tuple[jax.Array, Pytree]]
    cache_defs: Callable[[int, int], Pytree]
    init_cache: Callable[[int, int], Pytree]
    # chunked-prefill continuation (serving/scheduler.py hybrid steps);
    # None for families without one.  Signature:
    # prefill_step(params, cache, tokens, slot, q_offset, n_valid)
    #   -> (logits, cache) — tokens (1, C) attended at absolute position
    # q_offset against `slot`'s existing cache, K/V written at the offset.
    prefill_step: Callable[..., tuple[jax.Array, Pytree]] | None = None
    # paged-cache path (serving/paged/); None for families without one.
    # Signatures: (n_slots, n_blocks, block_size, max_blocks) -> cache,
    # and paged_decode_step(params, cache, tokens) -> (logits, cache).
    paged_decode_step: Callable[[Pytree, Pytree, jax.Array], tuple[jax.Array, Pytree]] | None = None
    paged_cache_defs: Callable[[int, int, int, int], Pytree] | None = None
    init_paged_cache: Callable[[int, int, int, int], Pytree] | None = None
    # sampled steps (async engine): sampling fused into the jit step so
    # only [batch] token ids cross the host boundary per step.
    # decode_sample_step(params, cache, tokens, rng, eos_ids, *, sampler)
    #   -> (tokens', eos_hit, cache); sampler is static under jit.
    # prefill_sample_step mirrors prefill_step with a trailing rng and
    # returns (token (1,), cache).  None for families without them.
    decode_sample_step: Callable[..., tuple[jax.Array, jax.Array, Pytree]] | None = None
    paged_decode_sample_step: Callable[..., tuple[jax.Array, jax.Array, Pytree]] | None = None
    prefill_sample_step: Callable[..., tuple[jax.Array, Pytree]] | None = None
    # speculative draft-verify (serving/engine.py spec_depth > 0):
    # verify_step(params, cache, tokens (B, T)) -> (logits (B, T, V), cache)
    # scores T positions per slot against the live cache in one pass,
    # writing their K/V but leaving `lengths` for the caller to commit.
    # paged_verify_step is the block-pool twin.  None for families
    # without multi-position scoring.
    verify_step: Callable[[Pytree, Pytree, jax.Array], tuple[jax.Array, Pytree]] | None = None
    paged_verify_step: Callable[[Pytree, Pytree, jax.Array], tuple[jax.Array, Pytree]] | None = None

    # ---- derived helpers -------------------------------------------------
    def init(self, rng: jax.Array) -> Pytree:
        return cm.init_params(self.param_defs, rng, cm.param_dtype(self.cfg))

    def param_shapes(self) -> Pytree:
        return cm.shape_tree(self.param_defs, cm.param_dtype(self.cfg))

    def param_specs(self) -> Pytree:
        return cm.specs_for(
            self.param_defs, self.env.param_rules(), self.env.axes, params=True
        )

    def cache_specs(self, batch: int, max_seq: int) -> Pytree:
        from repro.core.placement import kv_rules

        policy = self.env.kv_policy if self.env.offload == "hpu" else "none"
        return cm.specs_for(
            self.cache_defs(batch, max_seq), kv_rules(policy), self.env.axes
        )

    def cache_shapes(self, batch: int, max_seq: int) -> Pytree:
        """ShapeDtypeStructs mirroring init_cache (no allocation)."""
        return jax.eval_shape(lambda: self.init_cache(batch, max_seq))

    def paged_cache_specs(
        self, n_slots: int, n_blocks: int, block_size: int, max_blocks: int,
        **kw,
    ) -> Pytree:
        """HPU-layout shardings for the paged pool (block axis split across
        lanes per the ``kv_blocks`` placement rule).  Extra kwargs
        (``kv_dtype``, ``host_blocks``) pass through to the family's
        ``paged_cache_defs``."""
        from repro.core.placement import kv_rules

        if self.paged_cache_defs is None:
            raise ValueError(f"{self.cfg.family} has no paged cache")
        policy = self.env.kv_policy if self.env.offload == "hpu" else "none"
        return cm.specs_for(
            self.paged_cache_defs(n_slots, n_blocks, block_size, max_blocks, **kw),
            kv_rules(policy),
            self.env.axes,
        )

    def paged_cache_shapes(
        self, n_slots: int, n_blocks: int, block_size: int, max_blocks: int,
        **kw,
    ) -> Pytree:
        if self.init_paged_cache is None:
            raise ValueError(f"{self.cfg.family} has no paged cache")
        return jax.eval_shape(
            lambda: self.init_paged_cache(
                n_slots, n_blocks, block_size, max_blocks, **kw
            )
        )

    def n_params(self) -> int:
        return cm.count_params(self.param_defs)


def build_model(cfg: ModelConfig, env: Env | None = None) -> Model:
    env = env or Env()
    if cfg.family == DENSE:
        from repro.models import dense as fam
    elif cfg.family == MOE:
        from repro.models import moe as fam
    elif cfg.family == DEEPSEEK:
        from repro.models import deepseek as fam
    elif cfg.family == RWKV6:
        from repro.models import rwkv6 as fam
    elif cfg.family == ZAMBA2:
        from repro.models import zamba2 as fam
    elif cfg.family == ENCDEC:
        from repro.models import encdec as fam
    else:
        raise ValueError(f"unknown family {cfg.family}")

    import functools

    return Model(
        cfg=cfg,
        env=env,
        param_defs=fam.param_defs(cfg),
        loss_fn=functools.partial(fam.loss_fn, cfg, env),
        prefill=functools.partial(fam.prefill, cfg, env),
        decode_step=functools.partial(fam.decode_step, cfg, env),
        cache_defs=functools.partial(fam.cache_defs, cfg),
        init_cache=functools.partial(fam.init_cache, cfg),
        # families opt into chunked prefill by defining prefill_step
        prefill_step=(
            functools.partial(fam.prefill_step, cfg, env)
            if hasattr(fam, "prefill_step") else None
        ),
        # families opt into paging by defining the three paged_* callables
        paged_decode_step=(
            functools.partial(fam.paged_decode_step, cfg, env)
            if hasattr(fam, "paged_decode_step") else None
        ),
        paged_cache_defs=(
            functools.partial(fam.paged_cache_defs, cfg)
            if hasattr(fam, "paged_cache_defs") else None
        ),
        init_paged_cache=(
            functools.partial(fam.init_paged_cache, cfg)
            if hasattr(fam, "init_paged_cache") else None
        ),
        # families opt into on-device sampling (async engine) by defining
        # the *_sample_step variants
        decode_sample_step=(
            functools.partial(fam.decode_sample_step, cfg, env)
            if hasattr(fam, "decode_sample_step") else None
        ),
        paged_decode_sample_step=(
            functools.partial(fam.paged_decode_sample_step, cfg, env)
            if hasattr(fam, "paged_decode_sample_step") else None
        ),
        prefill_sample_step=(
            functools.partial(fam.prefill_sample_step, cfg, env)
            if hasattr(fam, "prefill_sample_step") else None
        ),
        # families opt into speculative verification by defining verify_step
        verify_step=(
            functools.partial(fam.verify_step, cfg, env)
            if hasattr(fam, "verify_step") else None
        ),
        paged_verify_step=(
            functools.partial(fam.paged_verify_step, cfg, env)
            if hasattr(fam, "paged_verify_step") else None
        ),
    )
