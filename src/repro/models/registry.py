"""Unified Model API: ``build_model(cfg, env)`` -> :class:`Model`.

Every family exposes the same five callables so the trainer, the serving
engine, the dry-run and the benchmarks are family-agnostic.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import DEEPSEEK, DENSE, ENCDEC, MOE, RWKV6, ZAMBA2, ModelConfig
from repro.core.placement import Env
from repro.models import common as cm

Pytree = Any


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    env: Env
    param_defs: Pytree
    loss_fn: Callable[[Pytree, dict], tuple[jax.Array, dict]]
    prefill: Callable[..., tuple[jax.Array, Pytree]]
    decode_step: Callable[[Pytree, Pytree, jax.Array], tuple[jax.Array, Pytree]]
    cache_defs: Callable[[int, int], Pytree]
    init_cache: Callable[[int, int], Pytree]

    # ---- derived helpers -------------------------------------------------
    def init(self, rng: jax.Array) -> Pytree:
        return cm.init_params(self.param_defs, rng, cm.param_dtype(self.cfg))

    def param_shapes(self) -> Pytree:
        return cm.shape_tree(self.param_defs, cm.param_dtype(self.cfg))

    def param_specs(self) -> Pytree:
        return cm.specs_for(
            self.param_defs, self.env.param_rules(), self.env.axes, params=True
        )

    def cache_specs(self, batch: int, max_seq: int) -> Pytree:
        from repro.core.placement import kv_rules

        policy = self.env.kv_policy if self.env.offload == "hpu" else "none"
        return cm.specs_for(
            self.cache_defs(batch, max_seq), kv_rules(policy), self.env.axes
        )

    def cache_shapes(self, batch: int, max_seq: int) -> Pytree:
        """ShapeDtypeStructs mirroring init_cache (no allocation)."""
        return jax.eval_shape(lambda: self.init_cache(batch, max_seq))

    def n_params(self) -> int:
        return cm.count_params(self.param_defs)


def build_model(cfg: ModelConfig, env: Env | None = None) -> Model:
    env = env or Env()
    if cfg.family == DENSE:
        from repro.models import dense as fam
    elif cfg.family == MOE:
        from repro.models import moe as fam
    elif cfg.family == DEEPSEEK:
        from repro.models import deepseek as fam
    elif cfg.family == RWKV6:
        from repro.models import rwkv6 as fam
    elif cfg.family == ZAMBA2:
        from repro.models import zamba2 as fam
    elif cfg.family == ENCDEC:
        from repro.models import encdec as fam
    else:
        raise ValueError(f"unknown family {cfg.family}")

    import functools

    return Model(
        cfg=cfg,
        env=env,
        param_defs=fam.param_defs(cfg),
        loss_fn=functools.partial(fam.loss_fn, cfg, env),
        prefill=functools.partial(fam.prefill, cfg, env),
        decode_step=functools.partial(fam.decode_step, cfg, env),
        cache_defs=functools.partial(fam.cache_defs, cfg),
        init_cache=functools.partial(fam.init_cache, cfg),
    )
