"""RWKV6 "Finch" — attention-free RNN with data-dependent decay
[arXiv:2404.05892].

Time-mix: token-shift ddlerp (5-way LoRA-interpolated mixing), per-channel
data-dependent decay w_t = exp(-exp(w0 + tanh(x_w @ w1) @ w2)), WKV state
recurrence per head (state S in R^{N x N}, N = head_dim):

    y_t = r_t · (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

Train runs the recurrence with ``lax.scan`` over time in fp32 (the chunked
GLA-style form is a perf option; see EXPERIMENTS.md §Perf).  Decode is a
single-step state update — O(1) per token, which is why this arch runs the
``long_500k`` cell.

Paper-technique note (DESIGN.md §4): there is no KV cache; the (L,B,H,N,N)
state takes the cache's place in the HPU layout (generalized offload).
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.placement import Env
from repro.models import common as cm
from repro.models.common import ParamDef

Pytree = Any

N_MIX = 5  # w, k, v, r, g


def _dims(cfg):
    N = cfg.rwkv.head_dim
    H = cfg.d_model // N
    return H, N


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------
def param_defs(cfg) -> Pytree:
    L, D, V, F = cfg.n_layers, cfg.d_model, cfg.padded_vocab(), cfg.d_ff
    H, N = _dims(cfg)
    r = cfg.rwkv
    blocks = {
        "ln1_s": ParamDef((L, D), ("layers", "embed"), "ones"),
        "ln1_b": ParamDef((L, D), ("layers", "embed"), "zeros"),
        "ln2_s": ParamDef((L, D), ("layers", "embed"), "ones"),
        "ln2_b": ParamDef((L, D), ("layers", "embed"), "zeros"),
        # time-mix ddlerp
        "mu_x": ParamDef((L, D), ("layers", "embed"), "zeros"),
        "mu_5": ParamDef((L, N_MIX, D), ("layers", None, "embed"), "zeros"),
        "tm_a": ParamDef((L, D, N_MIX * r.mix_lora), ("layers", "embed", None), "small"),
        "tm_b": ParamDef((L, N_MIX, r.mix_lora, D), ("layers", None, None, "embed"), "small"),
        # data-dependent decay
        "w0": ParamDef((L, D), ("layers", "embed"), "zeros"),
        "w1": ParamDef((L, D, r.decay_lora), ("layers", "embed", None), "small"),
        "w2": ParamDef((L, r.decay_lora, D), ("layers", None, "embed"), "small"),
        # projections
        "wr": ParamDef((L, D, D), ("layers", "embed", "heads")),
        "wk": ParamDef((L, D, D), ("layers", "embed", "heads")),
        "wv": ParamDef((L, D, D), ("layers", "embed", "heads")),
        "wg": ParamDef((L, D, D), ("layers", "embed", "heads")),
        "wo": ParamDef((L, D, D), ("layers", "heads", "embed")),
        "u": ParamDef((L, H, N), ("layers", "heads", None), "small"),
        "ln_x_s": ParamDef((L, D), ("layers", "embed"), "ones"),
        "ln_x_b": ParamDef((L, D), ("layers", "embed"), "zeros"),
        # channel-mix
        "mu_ck": ParamDef((L, D), ("layers", "embed"), "zeros"),
        "mu_cr": ParamDef((L, D), ("layers", "embed"), "zeros"),
        "cm_k": ParamDef((L, D, F), ("layers", "embed", "mlp")),
        "cm_v": ParamDef((L, F, D), ("layers", "mlp", "embed")),
        "cm_r": ParamDef((L, D, D), ("layers", "embed", "heads")),
    }
    return {
        "embed": ParamDef((V, D), ("vocab", "embed"), "embed"),
        "ln0_s": ParamDef((D,), ("embed",), "ones"),
        "ln0_b": ParamDef((D,), ("embed",), "zeros"),
        "blocks": blocks,
        "final_norm_s": ParamDef((D,), ("embed",), "ones"),
        "final_norm_b": ParamDef((D,), ("embed",), "zeros"),
        "unembed": ParamDef((V, D), ("vocab", "embed"), "embed"),
    }


# ---------------------------------------------------------------------------
# time-mix pieces
# ---------------------------------------------------------------------------
def _ddlerp(p, x, xx):
    """5-way data-dependent interpolation.  x, xx: (..., D) -> 5 x (..., D)."""
    sx = xx - x
    base = x + sx * p["mu_x"].astype(x.dtype)
    z = jnp.tanh(jnp.einsum("...d,dr->...r", base, p["tm_a"]))
    z = z.reshape(z.shape[:-1] + (N_MIX, p["tm_b"].shape[1]))
    off = jnp.einsum("...mr,mrd->...md", z, p["tm_b"])  # (..., 5, D)
    mixed = x[..., None, :] + sx[..., None, :] * (p["mu_5"].astype(x.dtype) + off)
    return [mixed[..., i, :] for i in range(N_MIX)]


def _decay(p, x_w):
    """Data-dependent per-channel decay in (0,1), fp32."""
    lo = jnp.einsum("...d,dr->...r", x_w.astype(jnp.float32), p["w1"].astype(jnp.float32))
    ww = p["w0"].astype(jnp.float32) + jnp.einsum(
        "...r,rd->...d", jnp.tanh(lo), p["w2"].astype(jnp.float32)
    )
    return jnp.exp(-jnp.exp(ww - 0.5))  # -0.5 centers init decay ~ exp(-0.6)


def _wkv_scan(r, k, v, w, u, state, chunk: int = 256):
    """WKV recurrence.  r,k,v,w: (B,S,H,N) fp32; u (H,N); state (B,H,N,N).

    Returns y (B,S,H,N), final state.  State layout: S[h, i(k-index), j(v-index)].

    Time-chunked remat: a plain scan makes autodiff save the FULL per-step
    (B,H,N,N) state trajectory in fp32 (S x 1 MB/layer — dominated the
    rwkv6 train_4k memory term).  Scanning over chunks with a checkpointed
    inner scan keeps only chunk-boundary states and recomputes inside the
    chunk during backward (classic remat-over-time).
    """
    B, S, H, N = r.shape

    def step(s, inp):
        rt, kt, vt, wt = inp  # (B,H,N)
        a = kt[..., :, None] * vt[..., None, :]  # (B,H,N,N) outer
        y = jnp.einsum("bhi,bhij->bhj", rt, s + u[None, :, :, None] * a)
        s = wt[..., :, None] * s + a
        return s, y

    if S <= chunk or S % chunk:
        xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))  # (S,B,H,N)
        state, ys = jax.lax.scan(step, state, xs)
        return jnp.moveaxis(ys, 0, 1), state

    n_c = S // chunk
    xs = tuple(
        jnp.moveaxis(t.reshape(B, n_c, chunk, H, N), 1, 0) for t in (r, k, v, w)
    )  # (n_c, B, chunk, H, N)

    @jax.checkpoint
    def chunk_step(s, inp):
        inner = tuple(jnp.moveaxis(t, 1, 0) for t in inp)  # (chunk, B, H, N)
        s, ys = jax.lax.scan(step, s, inner)
        return s, jnp.moveaxis(ys, 0, 1)  # (B, chunk, H, N)

    state, ys = jax.lax.scan(chunk_step, state, xs)  # ys (n_c, B, chunk, H, N)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, N)
    return y, state


def _time_mix(cfg, p, x, shift_in, state):
    """x (B,S,D); shift_in (B,D) last token of prev segment; state (B,H,N,N).

    Returns (out (B,S,D), new_shift (B,D), new_state)."""
    H, N = _dims(cfg)
    B, S, D = x.shape
    xx = jnp.concatenate([shift_in[:, None], x[:, :-1]], axis=1)
    x_w, x_k, x_v, x_r, x_g = _ddlerp(p, x, xx)
    r = jnp.einsum("bsd,de->bse", x_r, p["wr"]).reshape(B, S, H, N)
    k = jnp.einsum("bsd,de->bse", x_k, p["wk"]).reshape(B, S, H, N)
    v = jnp.einsum("bsd,de->bse", x_v, p["wv"]).reshape(B, S, H, N)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", x_g, p["wg"]))
    w = _decay(p, x_w).reshape(B, S, H, N)

    y, state = _wkv_scan(
        r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        w, p["u"].astype(jnp.float32), state,
    )
    y = y.reshape(B, S, D)
    # group-norm per head
    y = y.reshape(B, S, H, N)
    mu = jnp.mean(y, -1, keepdims=True)
    var = jnp.var(y, -1, keepdims=True)
    y = ((y - mu) * jax.lax.rsqrt(var + 64e-5)).reshape(B, S, D)
    y = y * p["ln_x_s"].astype(jnp.float32) + p["ln_x_b"].astype(jnp.float32)
    out = jnp.einsum("bsd,de->bse", y.astype(x.dtype) * g, p["wo"])
    return out, x[:, -1], state


def _channel_mix(cfg, p, x, shift_in):
    xx = jnp.concatenate([shift_in[:, None], x[:, :-1]], axis=1)
    x_k = x + (xx - x) * p["mu_ck"].astype(x.dtype)
    x_r = x + (xx - x) * p["mu_cr"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", x_k, p["cm_k"])))
    v = jnp.einsum("bsf,fd->bsd", k, p["cm_v"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x_r, p["cm_r"]))
    return r * v, x[:, -1]


def _block(cfg, env: Env, p, x, tm_shift, cm_shift, state):
    h = cm.layernorm(x, p["ln1_s"], p["ln1_b"], cfg.norm_eps)
    o, tm_shift, state = _time_mix(cfg, p, h, tm_shift, state)
    x = x + o
    h = cm.layernorm(x, p["ln2_s"], p["ln2_b"], cfg.norm_eps)
    o, cm_shift = _channel_mix(cfg, p, h, cm_shift)
    x = x + o
    if env.axes:
        x = jax.lax.with_sharding_constraint(
            x, env.act_spec(("batch", "seq", "embed"), x.shape)
        )
    return x, tm_shift, cm_shift, state


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------
def _run_blocks(cfg, env, params, x, cache=None, remat=True):
    """Scan blocks; threads shift/state caches.  x (B,S,D)."""
    H, N = _dims(cfg)
    B = x.shape[0]
    L = cfg.n_layers
    if cache is None:
        tm0 = jnp.zeros((L, B, cfg.d_model), x.dtype)
        cm0 = jnp.zeros((L, B, cfg.d_model), x.dtype)
        st0 = jnp.zeros((L, B, H, N, N), jnp.float32)
    else:
        tm0, cm0, st0 = cache["tm_shift"], cache["cm_shift"], cache["state"]

    blk = partial(_block, cfg, env)
    if remat:
        blk = jax.checkpoint(blk, policy=jax.checkpoint_policies.nothing_saveable)

    def body(xc, xs):
        p, tm, cmx, st = xs
        xc, tm, cmx, st = blk(p, xc, tm, cmx, st)
        return xc, (tm, cmx, st)

    x, (tm, cmx, st) = jax.lax.scan(body, x, (params["blocks"], tm0, cm0, st0))
    new_cache = {"tm_shift": tm, "cm_shift": cmx, "state": st}
    return x, new_cache


def hidden_states(cfg, env: Env, params, tokens, remat: bool = True):
    x = cm.embed_lookup(params["embed"], tokens)
    x = cm.layernorm(x, params["ln0_s"], params["ln0_b"], cfg.norm_eps)
    x, _ = _run_blocks(cfg, env, params, x, remat=remat)
    return cm.layernorm(x, params["final_norm_s"], params["final_norm_b"], cfg.norm_eps)


def loss_fn(cfg, env: Env, params, batch):
    hid = hidden_states(cfg, env, params, batch["inputs"])
    logits = cm.unembed(hid, params["unembed"], cfg.vocab)
    loss = cm.cross_entropy_loss(logits, batch["targets"], batch.get("mask"))
    return loss, {"loss": loss}


# ---------------------------------------------------------------------------
# cache / prefill / decode
# ---------------------------------------------------------------------------
def cache_defs(cfg, batch: int, max_seq: int) -> Pytree:
    """max_seq is irrelevant for an RNN — state is O(1) in sequence length."""
    L, D = cfg.n_layers, cfg.d_model
    H, N = _dims(cfg)
    return {
        "tm_shift": ParamDef((L, batch, D), ("layers", "kv_batch", "embed"), "zeros"),
        "cm_shift": ParamDef((L, batch, D), ("layers", "kv_batch", "embed"), "zeros"),
        "state": ParamDef((L, batch, H, N, N), ("layers", "kv_batch", "state", None, None), "zeros"),
        "lengths": ParamDef((batch,), ("kv_batch",), "zeros"),
    }


def init_cache(cfg, batch: int, max_seq: int = 0, dtype=jnp.bfloat16) -> Pytree:
    defs = cache_defs(cfg, batch, max_seq)
    dt = {"tm_shift": dtype, "cm_shift": dtype, "state": jnp.float32, "lengths": jnp.int32}
    return {k: jnp.zeros(d.shape, dt[k]) for k, d in defs.items()}


def prefill(cfg, env: Env, params, tokens, cache, embeds=None):
    x = cm.embed_lookup(params["embed"], tokens)
    x = cm.layernorm(x, params["ln0_s"], params["ln0_b"], cfg.norm_eps)
    B, S = tokens.shape
    cache_in = {
        "tm_shift": cache["tm_shift"].astype(x.dtype),
        "cm_shift": cache["cm_shift"].astype(x.dtype),
        "state": cache["state"],
    }
    x, new_cache = _run_blocks(cfg, env, params, x, cache_in, remat=False)
    x = cm.layernorm(x, params["final_norm_s"], params["final_norm_b"], cfg.norm_eps)
    logits = cm.unembed(x[:, -1], params["unembed"], cfg.vocab)
    new_cache["lengths"] = cache["lengths"] + S
    return logits, new_cache


def decode_step(cfg, env: Env, params, cache, tokens):
    logits, new_cache = prefill(cfg, env, params, tokens[:, None], cache)
    return logits, new_cache
