"""Zamba2 hybrid: mamba2 backbone + one *shared* attention+MLP block
[arXiv:2411.15242].

The shared block (weight-tied across its invocation slots, with per-slot
LoRA deltas on q/k/v) runs every ``shared_block_period`` mamba layers; it
sees ``concat([x, x_embed])`` (2*d_model) and its output is projected back
to d_model by a per-slot linear.  Its KV caches are ordinary attention
caches -> offloaded per the paper; the mamba conv/ssm states ride along in
the same cache pytree (generalized offload, DESIGN.md §4).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core import offload
from repro.core.placement import Env
from repro.models import common as cm
from repro.models import mamba2
from repro.models.common import ParamDef

Pytree = Any


def _slots(cfg) -> list[int]:
    """Mamba-layer indices *before* which the shared block runs."""
    p = cfg.hybrid.shared_block_period
    return [i for i in range(cfg.n_layers) if i % p == p - 1]


def _attn_dims(cfg):
    D2 = 2 * cfg.d_model
    H = cfg.n_heads
    Dh = D2 // H
    return D2, H, Dh


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------
def param_defs(cfg) -> Pytree:
    D, V, F = cfg.d_model, cfg.padded_vocab(), cfg.d_ff
    D2, H, Dh = _attn_dims(cfg)
    n_slots = len(_slots(cfg))
    r = cfg.hybrid.lora_rank
    shared = {
        "ln1": ParamDef((D2,), ("embed",), "zeros"),
        "wq": ParamDef((D2, H, Dh), ("embed", "heads", "head_dim")),
        "wk": ParamDef((D2, H, Dh), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDef((D2, H, Dh), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDef((H, Dh, D2), ("heads", "head_dim", "embed")),
        "ln2": ParamDef((D2,), ("embed",), "zeros"),
        "w_gate": ParamDef((D2, F), ("embed", "mlp")),
        "w_up": ParamDef((D2, F), ("embed", "mlp")),
        "w_down": ParamDef((F, D2), ("mlp", "embed")),
        # per-slot LoRA on q/k/v + per-slot down projection to D
        "lora_a": ParamDef((n_slots, 3, D2, r), (None, None, "embed", None), "small"),
        "lora_b": ParamDef((n_slots, 3, r, H * Dh), (None, None, None, "heads"), "zeros"),
        "down": ParamDef((n_slots, D2, D), (None, "embed", None)),
    }
    return {
        "embed": ParamDef((V, D), ("vocab", "embed"), "embed"),
        "mamba": mamba2.param_defs(cfg, cfg.n_layers),
        "shared": shared,
        "final_norm": ParamDef((D,), ("embed",), "zeros"),
        "unembed": ParamDef((V, D), ("vocab", "embed"), "embed"),
    }


# ---------------------------------------------------------------------------
# shared attention block
# ---------------------------------------------------------------------------
def _shared_qkv(cfg, p, slot, h):
    """h (..., D2) -> q,k,v (..., H, Dh) with per-slot LoRA deltas."""
    D2, H, Dh = _attn_dims(cfg)
    outs = []
    for i, w in enumerate((p["wq"], p["wk"], p["wv"])):
        base = jnp.einsum("...d,dhk->...hk", h, w)
        lo = jnp.einsum("...d,dr->...r", h, p["lora_a"][slot, i])
        delta = jnp.einsum("...r,re->...e", lo, p["lora_b"][slot, i])
        outs.append(base + delta.reshape(delta.shape[:-1] + (H, Dh)))
    return outs


def _shared_block_train(cfg, env: Env, p, slot, x, x0, positions):
    """Train/prefill shared block.  Returns (delta_to_x (B,S,D), k, v)."""
    h_in = jnp.concatenate([x, x0], axis=-1)
    h = cm.rmsnorm(h_in, p["ln1"], cfg.norm_eps)
    q, k, v = _shared_qkv(cfg, p, slot, h)
    q = cm.rope(q, positions, cfg.rope_theta)
    k = cm.rope(k, positions, cfg.rope_theta)
    o = offload.prefill_attention(env, q, k, v)
    h_in = h_in + jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    g = cm.rmsnorm(h_in, p["ln2"], cfg.norm_eps)
    h_in = h_in + cm.swiglu(g, p["w_gate"], p["w_up"], p["w_down"])
    return jnp.einsum("bse,ed->bsd", h_in, p["down"][slot]), k, v


def _shared_block_decode(cfg, env: Env, p, slot, x, x0, k_cache, v_cache, lengths):
    B = x.shape[0]
    pos = lengths[:, None]
    bidx = jnp.arange(B)
    h_in = jnp.concatenate([x, x0], axis=-1)
    h = cm.rmsnorm(h_in, p["ln1"], cfg.norm_eps)
    q, k, v = _shared_qkv(cfg, p, slot, h)
    q = cm.rope(q[:, None], pos, cfg.rope_theta)[:, 0]
    k = cm.rope(k[:, None], pos, cfg.rope_theta)[:, 0]
    k_cache = k_cache.at[bidx, lengths].set(k.astype(k_cache.dtype))
    v_cache = v_cache.at[bidx, lengths].set(v.astype(v_cache.dtype))
    o = offload.decode_attention(env, q, k_cache, v_cache, lengths + 1)
    h_in = h_in + jnp.einsum("bhk,hkd->bd", o, p["wo"])
    g = cm.rmsnorm(h_in, p["ln2"], cfg.norm_eps)
    h_in = h_in + cm.swiglu(g, p["w_gate"], p["w_up"], p["w_down"])
    return jnp.einsum("be,ed->bd", h_in, p["down"][slot]), k_cache, v_cache


# ---------------------------------------------------------------------------
# backbone traversal (segments of mamba scan + shared-block interjections)
# ---------------------------------------------------------------------------
def _segments(cfg):
    """[(start, end, slot_after or None)]: scan mamba[start:end], then run
    shared block #slot (if not None) BEFORE the next segment."""
    slots = _slots(cfg)
    segs = []
    prev = 0
    for si, li in enumerate(slots):
        segs.append((prev, li + 1, si))
        prev = li + 1
    if prev < cfg.n_layers:
        segs.append((prev, cfg.n_layers, None))
    return segs


def _run_backbone(cfg, env: Env, params, x, cache, positions, decode: bool, remat=False):
    """x: (B,S,D) train/prefill or (B,D) decode.  Returns (x, new_cache)."""
    x0 = x
    mam = params["mamba"]
    sh = params["shared"]
    conv_all, ssm_all = cache["conv"], cache["ssm"]
    k_all, v_all = cache["k"], cache["v"]
    lengths = cache["lengths"]
    new_conv, new_ssm, new_k, new_v = [], [], [], []

    mamba_fwd = mamba2.forward
    if remat:
        mamba_fwd = jax.checkpoint(
            mamba2.forward, policy=jax.checkpoint_policies.nothing_saveable,
            static_argnums=(0,),
        )

    def seg_scan(xc, lo, hi):
        p_seg = jax.tree.map(lambda a: a[lo:hi], mam)

        def body(c, xs):
            xc_, = (c,)
            p, cv, st = xs
            if decode:
                y, cv, st = mamba_fwd(cfg, p, xc_[:, None], cv, st, cfg.norm_eps)
                y = y[:, 0]
            else:
                y, cv, st = mamba_fwd(cfg, p, xc_, cv, st, cfg.norm_eps)
            return xc_ + y, (cv, st)

        xc, (cv, st) = jax.lax.scan(
            body, xc, (p_seg, conv_all[lo:hi], ssm_all[lo:hi])
        )
        new_conv.append(cv)
        new_ssm.append(st)
        return xc

    for lo, hi, slot in _segments(cfg):
        x = seg_scan(x, lo, hi)
        if slot is not None:
            if decode:
                delta, kc, vc = _shared_block_decode(
                    cfg, env, sh, slot, x, x0, k_all[slot], v_all[slot], lengths
                )
                new_k.append(kc)
                new_v.append(vc)
            else:
                delta, k, v = _shared_block_train(cfg, env, sh, slot, x, x0, positions)
                if k_all is not None:  # prefill: write cache
                    kc = jax.lax.dynamic_update_slice(
                        k_all[slot], k.astype(k_all.dtype), (0, 0, 0, 0)
                    )
                    vc = jax.lax.dynamic_update_slice(
                        v_all[slot], v.astype(v_all.dtype), (0, 0, 0, 0)
                    )
                    new_k.append(kc)
                    new_v.append(vc)
            x = x + delta

    new_cache = {
        "conv": jnp.concatenate(new_conv, 0),
        "ssm": jnp.concatenate(new_ssm, 0),
        "k": jnp.stack(new_k, 0) if new_k else k_all,
        "v": jnp.stack(new_v, 0) if new_v else v_all,
        "lengths": lengths,
    }
    return x, new_cache


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------
def _empty_cache(cfg, B, max_seq, dtype, with_kv: bool):
    s = cfg.ssm
    d_inner, H, conv_dim, _ = mamba2.dims(cfg)
    D2, Ha, Dh = _attn_dims(cfg)
    n_slots = len(_slots(cfg))
    return {
        "conv": jnp.zeros((cfg.n_layers, B, s.d_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((cfg.n_layers, B, H, s.d_head, s.d_state), jnp.float32),
        "k": jnp.zeros((n_slots, B, max_seq, cfg.n_kv_heads, Dh), dtype) if with_kv else None,
        "v": jnp.zeros((n_slots, B, max_seq, cfg.n_kv_heads, Dh), dtype) if with_kv else None,
        "lengths": jnp.zeros((B,), jnp.int32),
    }


def cache_defs(cfg, batch: int, max_seq: int) -> Pytree:
    s = cfg.ssm
    d_inner, H, conv_dim, _ = mamba2.dims(cfg)
    D2, Ha, Dh = _attn_dims(cfg)
    n_slots = len(_slots(cfg))
    return {
        "conv": ParamDef((cfg.n_layers, batch, s.d_conv - 1, conv_dim), ("layers", "kv_batch", None, "state"), "zeros"),
        "ssm": ParamDef((cfg.n_layers, batch, H, s.d_head, s.d_state), ("layers", "kv_batch", "state", None, None), "zeros"),
        "k": ParamDef((n_slots, batch, max_seq, cfg.n_kv_heads, Dh), ("layers", "kv_batch", "kv_seq", "kv_heads", "head_dim"), "zeros"),
        "v": ParamDef((n_slots, batch, max_seq, cfg.n_kv_heads, Dh), ("layers", "kv_batch", "kv_seq", "kv_heads", "head_dim"), "zeros"),
        "lengths": ParamDef((batch,), ("kv_batch",), "zeros"),
    }


def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16) -> Pytree:
    return _empty_cache(cfg, batch, max_seq, dtype, with_kv=True)


def hidden_states(cfg, env: Env, params, tokens, remat: bool = True):
    x = cm.embed_lookup(params["embed"], tokens)
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    cache = _empty_cache(cfg, B, 0, x.dtype, with_kv=False)
    x, _ = _run_backbone(cfg, env, params, x, cache, positions, decode=False, remat=remat)
    return cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)


def loss_fn(cfg, env: Env, params, batch):
    hid = hidden_states(cfg, env, params, batch["inputs"])
    logits = cm.unembed(hid, params["unembed"], cfg.vocab)
    loss = cm.cross_entropy_loss(logits, batch["targets"], batch.get("mask"))
    return loss, {"loss": loss}


def prefill(cfg, env: Env, params, tokens, cache, embeds=None):
    x = cm.embed_lookup(params["embed"], tokens)
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x, new_cache = _run_backbone(cfg, env, params, x, cache, positions, decode=False)
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = cm.unembed(x[:, -1], params["unembed"], cfg.vocab)
    new_cache["lengths"] = cache["lengths"] + S
    return logits, new_cache


def decode_step(cfg, env: Env, params, cache, tokens):
    x = cm.embed_lookup(params["embed"], tokens)  # (B, D)
    x, new_cache = _run_backbone(cfg, env, params, x, cache, None, decode=True)
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = cm.unembed(x, params["unembed"], cfg.vocab)
    new_cache["lengths"] = cache["lengths"] + 1
    return logits, new_cache
