"""Serving tier: continuous batching, paged KV, scheduling, clustering.

Layers, bottom-up (each module's own docstring has the details):

* :mod:`repro.serving.kv_cache` — dense per-slot KV slicing;
* :mod:`repro.serving.paged` — block-pool KV: allocator, per-slot block
  tables, jitted device ops;
* :mod:`repro.serving.sampler` — greedy/temperature/top-k, host + device;
* :mod:`repro.serving.scheduler` — token-budget hybrid batching;
* :mod:`repro.serving.engine` — the per-replica continuous-batching
  engine (dense/paged x decode-only/hybrid x sync/async), including KV
  block export/import for cross-replica migration;
* :mod:`repro.serving.cluster` — routed replicas behind one global
  queue, with disaggregated prefill/decode roles and live KV migration;
* :mod:`repro.serving.telemetry` — request-span tracing, step
  timelines, metrics registry, Perfetto export.

See ``docs/ARCHITECTURE.md`` for the cross-layer dataflow and
``docs/serving.md`` for the serve CLI built on this package.
"""
