"""Cluster serving tier: routed engine replicas behind one global queue.

See :mod:`repro.serving.cluster.cluster` for the stepping model,
:mod:`repro.serving.cluster.router` for the routing policies, and
:mod:`repro.serving.cluster.stats` for the aggregate metrics.
"""
from repro.serving.cluster.cluster import ROLES, Cluster, parse_roles
from repro.serving.cluster.router import ROUTE_POLICIES, Router, RouterStats
from repro.serving.cluster.stats import ClusterStats, ReplicaStats

__all__ = [
    "Cluster",
    "Router",
    "RouterStats",
    "ROLES",
    "ROUTE_POLICIES",
    "ClusterStats",
    "ReplicaStats",
    "parse_roles",
]
