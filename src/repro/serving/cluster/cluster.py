"""N engine replicas behind one admission/routing front-end.

The paper scales KV capacity by adding HPU cards; the serving-tier
analogue is data-parallel engine replicas — each :class:`Engine` owns
its own params reference, cache, scheduler, and block pool (on CPU tests
they share one device; on a mesh each replica gets its own slice) — with
a **shared global request queue** in front.  Requests wait globally and
are placed by a :class:`~repro.serving.cluster.router.Router` the moment
some replica can admit them, so placement decisions always see current
load and current prefix residency, not submission-time state.

Stepping is an interleaved loop: one cluster *round* dispatches the
queue, then steps every replica once.  Replicas never block each other —
a replica with nothing to do returns from ``step`` immediately — and the
async dispatch-ahead pipeline inside each engine keeps device work
overlapped across the round exactly as it does standalone.

Dispatch is FCFS with head-of-line blocking: when no replica can admit
the queue head, the whole queue waits (mirrors each engine's own FCFS
admission, keeps preempted-request recovery exact, and makes cluster
output order deterministic).  Greedy outputs are token-identical
per request to a single engine serving the same prompts — routing moves
work, never changes it.
"""
from __future__ import annotations

from collections import deque

from repro.serving.cluster.router import Router
from repro.serving.cluster.stats import ClusterStats, ReplicaStats
from repro.serving.engine import Engine, Request
from repro.serving.telemetry import NULL_TRACER

Pytree = object


class Cluster:
    def __init__(
        self,
        model,
        params: Pytree,
        n_replicas: int,
        route: str = "round_robin",
        tracer=None,
        **engine_kw,
    ):
        if n_replicas < 1:
            raise ValueError(f"need >= 1 replica, got {n_replicas}")
        self.tracer = NULL_TRACER if tracer is None else tracer
        self.engines = [
            Engine(model, params, tracer=self.tracer, replica=i, **engine_kw)
            for i in range(n_replicas)
        ]
        self.router = Router(self.engines, route, tracer=self.tracer)
        self.max_seq = self.engines[0].max_seq
        self.queue: deque[Request] = deque()
        self.rounds = 0
        self.placement: dict[int, int] = {}    # uid -> replica, exactly once
        self._submit_round: dict[int, int] = {}
        self.queue_wait_sum = 0
        self.queue_wait_count = 0

    # ------------------------------------------------------------- requests
    def submit(self, req: Request) -> None:
        """Enqueue on the shared global queue (uids must be unique — the
        routed-exactly-once invariant is keyed on them).  The engine's
        own prompt-length check is applied eagerly so an oversized prompt
        fails at submission, not rounds later at dispatch."""
        if len(req.prompt) >= self.max_seq - 1:
            raise ValueError(
                f"prompt of {len(req.prompt)} tokens does not fit max_seq="
                f"{self.max_seq} (needs len(prompt) <= max_seq - 2)"
            )
        if req.uid in self.placement or req.uid in self._submit_round:
            raise ValueError(f"duplicate request uid {req.uid}")
        self.queue.append(req)
        self._submit_round[req.uid] = self.rounds

    def _dispatch_queue(self) -> None:
        """Route queued requests FCFS until the head cannot be admitted
        anywhere (head-of-line wait: it is re-routed next round, when
        completions have freed capacity or moved the affinity target)."""
        while self.queue:
            req = self.queue[0]
            idx = self.router.route(req)
            if idx is None:
                break
            self.queue.popleft()
            assert req.uid not in self.placement, "request routed twice"
            self.placement[req.uid] = idx
            self.queue_wait_sum += self.rounds - self._submit_round.pop(req.uid)
            self.queue_wait_count += 1
            self.engines[idx].submit(req)

    # ----------------------------------------------------------------- step
    def step(self) -> bool:
        """One cluster round: admit from the global queue, then step
        every replica once.  Returns whether any work remains."""
        if self.tracer.enabled:
            self.tracer.round = self.rounds
        self._dispatch_queue()
        self.rounds += 1
        busy = False
        for eng in self.engines:
            busy = eng.step() or busy
        return busy or bool(self.queue)

    def run(self, max_rounds: int = 10_000) -> ClusterStats:
        for _ in range(max_rounds):
            if not self.step():
                break
        for eng in self.engines:
            if eng.async_mode:
                eng._drain()    # settle out_tokens if max_rounds truncated
        return self.stats()

    # ---------------------------------------------------------------- stats
    def stats(self) -> ClusterStats:
        rs = self.router.stats
        return ClusterStats(
            rounds=self.rounds,
            replicas=[
                ReplicaStats(replica=i, routed=rs.routed[i],
                             n_slots=len(eng.slots), engine=eng.stats)
                for i, eng in enumerate(self.engines)
            ],
            spills=rs.spills,
            prefix_hit_tokens=rs.prefix_hit_tokens,
            probed_tokens=rs.probed_tokens,
            queue_wait_sum=self.queue_wait_sum,
            queue_wait_count=self.queue_wait_count,
        )
