"""N engine replicas behind one admission/routing front-end.

The paper scales KV capacity by adding HPU cards; the serving-tier
analogue is data-parallel engine replicas — each :class:`Engine` owns
its own params reference, cache, scheduler, and block pool (on CPU tests
they share one device; on a mesh each replica gets its own slice via
``launch.mesh.replica_meshes`` and a per-replica model from
``model_factory``) — with a **shared global request queue** in front.
Requests wait globally and are placed by a
:class:`~repro.serving.cluster.router.Router` the moment some replica
can admit them, so placement decisions always see current load and
current prefix residency, not submission-time state.

Stepping is an interleaved loop: one cluster *round* dispatches the
queue, then steps every replica once.  Replicas never block each other —
a replica with nothing to do returns from ``step`` immediately — and the
async dispatch-ahead pipeline inside each engine keeps device work
overlapped across the round exactly as it does standalone.

Dispatch is FCFS with head-of-line blocking: when no replica can admit
the queue head, the whole queue waits (mirrors each engine's own FCFS
admission, keeps preempted-request recovery exact, and makes cluster
output order deterministic).  Greedy outputs are token-identical
per request to a single engine serving the same prompts — routing moves
work, never changes it.

Disaggregated serving (``roles=``)
----------------------------------
The paper's thesis is splitting memory-bound attention from
compute-bound GEMMs across device classes; the cluster expresses it as
replica **roles**.  ``roles`` (see :func:`parse_roles`) marks each
replica ``prefill`` / ``decode`` / ``mixed``:

* new prompts are only admitted to prefill/mixed replicas;
* after each round, every resident (prefill-complete) request on a
  ``prefill``-role replica is **migrated** to the least-loaded decode
  target that can take it — ``Engine.export_request`` gathers its KV
  blocks in storage dtype, ``Engine.import_request`` lands them (deduped
  against the destination's prefix cache) and decode resumes with the
  same next-input token over the same KV, so greedy output is
  token-identical to never having migrated;
* a request whose migration finds no destination simply keeps decoding
  on its prefill replica and is retried next round (graceful
  degradation, never a stall).

The same machinery levels bursty tails on any role layout: a preempted
request waiting at a replica's local queue front refolds on the
least-loaded admitting replica instead of its home when home cannot
take it next step (router-driven refold placement).

Round-clock TTFT: each engine's TTFT excludes the *global* queue wait
(the request has no home replica while it waits), so the cluster also
records submit-round -> first-token-round per request
(``ClusterStats.ttft_rounds_samples``) — the end-to-end latency metric
the disaggregation benchmark gates on.
"""
from __future__ import annotations

import re
from collections import deque

from repro.serving.cluster.router import Router
from repro.serving.cluster.stats import ClusterStats, ReplicaStats
from repro.serving.engine import Engine, Request
from repro.serving.telemetry import NULL_TRACER

Pytree = object

ROLES = ("prefill", "decode", "mixed")


def parse_roles(spec, n_replicas: int) -> list[str]:
    """Resolve a role specification into one role per replica.

    Accepts ``None`` (all ``mixed`` — the non-disaggregated default), an
    explicit list/tuple, a comma list (``"prefill,decode"``), or the
    ``"<k>P+<m>D"`` shorthand (optionally ``+<j>M``): ``"2P+2D"`` is two
    prefill replicas followed by two decode replicas.  Validates that at
    least one replica can admit prompts and that prefill/decode replicas
    are not stranded without a counterpart.
    """
    if spec is None:
        return ["mixed"] * n_replicas
    if isinstance(spec, str):
        s = spec.strip().lower()
        m = re.fullmatch(r"(\d+)p\+(\d+)d(?:\+(\d+)m)?", s)
        if m:
            roles = (["prefill"] * int(m.group(1))
                     + ["decode"] * int(m.group(2))
                     + ["mixed"] * int(m.group(3) or 0))
        else:
            roles = [r.strip() for r in s.split(",")]
    else:
        roles = [str(r) for r in spec]
    if len(roles) != n_replicas:
        raise ValueError(
            f"role map {spec!r} names {len(roles)} replicas, cluster has "
            f"{n_replicas}"
        )
    for r in roles:
        if r not in ROLES:
            raise ValueError(f"unknown role {r!r} (known: {', '.join(ROLES)})")
    if not any(r in ("prefill", "mixed") for r in roles):
        raise ValueError("no admission target: need a prefill or mixed replica")
    if "prefill" in roles and not any(r in ("decode", "mixed") for r in roles):
        raise ValueError(
            "prefill replicas need a decode or mixed replica to migrate to"
        )
    if "decode" in roles and "prefill" not in roles:
        raise ValueError(
            "decode replicas sit idle without a prefill replica migrating "
            "work to them (use 'mixed' instead)"
        )
    return roles


class Cluster:
    def __init__(
        self,
        model,
        params: Pytree,
        n_replicas: int,
        route: str = "round_robin",
        roles=None,
        tracer=None,
        profiler=None,
        model_factory=None,
        role_kw: dict[str, dict] | None = None,
        **engine_kw,
    ):
        if n_replicas < 1:
            raise ValueError(f"need >= 1 replica, got {n_replicas}")
        self.tracer = NULL_TRACER if tracer is None else tracer
        self.profiler = profiler
        self.roles = parse_roles(roles, n_replicas)
        role_kw = role_kw or {}
        self.engines = []
        for i, role in enumerate(self.roles):
            # role_kw lets a role override engine knobs (e.g. decode
            # replicas run more slots: they hold the long decode phase
            # while prefill replicas only stage short-lived prefills)
            kw = {**engine_kw, **role_kw.get(role, {})}
            mdl = model if model_factory is None else model_factory(i)
            self.engines.append(
                Engine(mdl, params, tracer=self.tracer,
                       profiler=self.profiler, replica=i, role=role, **kw)
            )
        self.router = Router(self.engines, route, tracer=self.tracer,
                             roles=self.roles)
        self._prefill_idx = [i for i, r in enumerate(self.roles)
                             if r == "prefill"]
        self.disaggregated = bool(self._prefill_idx)
        self.max_seq = self.engines[0].max_seq
        self.queue: deque[Request] = deque()
        self.rounds = 0
        self.placement: dict[int, int] = {}    # uid -> current replica
        self._submit_round: dict[int, int] = {}
        self.queue_wait_sum = 0
        self.queue_wait_count = 0
        self.migrations = 0
        self.refold_moves = 0
        # round-clock TTFT: uid -> (request, submit round) until its
        # first token is produced on whichever replica holds it
        self._ttft_pending: dict[int, tuple[Request, int]] = {}
        self.ttft_rounds_samples: list[int] = []

    # ------------------------------------------------------------- requests
    def submit(self, req: Request) -> None:
        """Enqueue on the shared global queue (uids must be unique — the
        routed-exactly-once invariant is keyed on them).  The engine's
        own prompt-length check is applied eagerly so an oversized prompt
        fails at submission, not rounds later at dispatch."""
        if len(req.prompt) >= self.max_seq - 1:
            raise ValueError(
                f"prompt of {len(req.prompt)} tokens does not fit max_seq="
                f"{self.max_seq} (needs len(prompt) <= max_seq - 2)"
            )
        if req.uid in self.placement or req.uid in self._submit_round:
            raise ValueError(f"duplicate request uid {req.uid}")
        self.queue.append(req)
        self._submit_round[req.uid] = self.rounds
        self._ttft_pending[req.uid] = (req, self.rounds)

    def _dispatch_queue(self) -> None:
        """Route queued requests FCFS until the head cannot be admitted
        anywhere (head-of-line wait: it is re-routed next round, when
        completions have freed capacity or moved the affinity target)."""
        while self.queue:
            req = self.queue[0]
            idx = self.router.route(req)
            if idx is None:
                break
            self.queue.popleft()
            assert req.uid not in self.placement, "request routed twice"
            self.placement[req.uid] = idx
            self.queue_wait_sum += self.rounds - self._submit_round.pop(req.uid)
            self.queue_wait_count += 1
            self.engines[idx].submit(req)

    # ------------------------------------------------------------ migration
    def _migrate_prefills(self) -> int:
        """Disaggregated handoff: move every resident (prefill-complete)
        request off ``prefill``-role replicas to the least-loaded decode
        target that can take it now (``Engine.can_import`` probes before
        the export is paid).  A request with no viable destination keeps
        decoding at home and is retried next round."""
        moved = 0
        for src_idx in self._prefill_idx:
            src = self.engines[src_idx]
            for slot, req in enumerate(list(src.slots)):
                if req is None or req.done:
                    continue
                ticket = src.preview_export(slot)
                if ticket is None:
                    continue
                dst_idx = next(
                    (i for i in self.router.rank_decode(exclude=src_idx)
                     if self.engines[i].can_import(ticket)),
                    None,
                )
                if dst_idx is None:
                    continue
                exported = src.export_request(slot)
                if exported is None:
                    continue        # finished while observing in-flight tokens
                req, ticket, payload = exported
                dst = self.engines[dst_idx]
                dslot = dst.import_request(req, ticket, payload)
                if dslot is None:
                    # capacity shifted between probe and import (cannot
                    # happen single-threaded; defensive): land it back
                    # home — its blocks were just freed there
                    back = src.import_request(req, ticket, payload)
                    assert back is not None, "migration fallback failed"
                    continue
                self.placement[req.uid] = dst_idx
                self.migrations += 1
                moved += 1
                self.tracer.on_migrate(
                    req, src_idx, ticket.src_step, slot,
                    dst_idx, dst.stats.engine_steps, dslot, ticket.n_blocks,
                )
        return moved

    def _rebalance_refolds(self) -> int:
        """Router-driven refold placement: a preempted request waiting at
        a replica's local queue front refolds on the least-loaded
        admitting replica instead of its home, when home cannot admit it
        next step but somewhere else can right now."""
        moved = 0
        for src_idx, src in enumerate(self.engines):
            q = src.sched.queue
            if not q or not q[0].out_tokens or q[0].done:
                continue
            if src.can_admit_next():
                continue            # home takes it next step: leave it
            head = q[0]
            dst_idx = next(
                (i for i in self.router.rank_refold(exclude=src_idx)
                 if self.engines[i].can_admit(head)),
                None,
            )
            if dst_idx is None:
                continue
            req = src.take_refold()
            assert req is head
            dst = self.engines[dst_idx]
            # translate decode-latency accounting onto the new home's
            # step clock (mirrors Engine.import_request)
            if req.first_token_step >= 0:
                req.first_token_step = dst.stats.engine_steps - (
                    src.stats.engine_steps - req.first_token_step
                )
            dst.adopt_refold(req)
            self.placement[req.uid] = dst_idx
            self.refold_moves += 1
            moved += 1
            self.tracer.on_refold_move(req, src_idx, dst_idx)
        return moved

    def _harvest_first_tokens(self) -> None:
        """Record submit-round -> first-token-round samples (the cluster
        TTFT clock; covers the global queue wait each engine's own
        step-clock TTFT cannot see)."""
        done = [uid for uid, (req, _) in self._ttft_pending.items()
                if req.first_token_step >= 0]
        for uid in done:
            req, r0 = self._ttft_pending.pop(uid)
            self.ttft_rounds_samples.append(self.rounds - r0)

    # ----------------------------------------------------------------- step
    def step(self) -> bool:
        """One cluster round: admit from the global queue, step every
        replica once, then migrate finished prefills off prefill-role
        replicas and re-place stranded refolds.  Returns whether any work
        remains."""
        if self.tracer.enabled:
            self.tracer.round = self.rounds
        self._dispatch_queue()
        self.rounds += 1
        busy = False
        for eng in self.engines:
            busy = eng.step() or busy
        if self.disaggregated:
            busy = bool(self._migrate_prefills()) or busy
        if len(self.engines) > 1:
            busy = bool(self._rebalance_refolds()) or busy
        self._harvest_first_tokens()
        return busy or bool(self.queue)

    def run(self, max_rounds: int = 10_000) -> ClusterStats:
        for _ in range(max_rounds):
            if not self.step():
                break
        for eng in self.engines:
            if eng.async_mode:
                eng._drain()    # settle out_tokens if max_rounds truncated
        self._harvest_first_tokens()
        return self.stats()

    # ---------------------------------------------------------------- stats
    def stats(self) -> ClusterStats:
        rs = self.router.stats
        return ClusterStats(
            rounds=self.rounds,
            replicas=[
                ReplicaStats(replica=i, routed=rs.routed[i],
                             n_slots=len(eng.slots), engine=eng.stats,
                             role=eng.role)
                for i, eng in enumerate(self.engines)
            ],
            spills=rs.spills,
            prefix_hit_tokens=rs.prefix_hit_tokens,
            probed_tokens=rs.probed_tokens,
            queue_wait_sum=self.queue_wait_sum,
            queue_wait_count=self.queue_wait_count,
            migrations=self.migrations,
            refold_moves=self.refold_moves,
            ttft_rounds_samples=list(self.ttft_rounds_samples),
        )
