"""Replica selection: pluggable routing policies with spill-over.

The router is the cluster's admission front-end brain: given one request
and the live replica set, produce a preference ranking and place the
request on the first ranked replica that can admit it *now*
(``Engine.can_admit``).  Admission off the first choice is a **spill**;
when no replica can admit, the request stays in the cluster's global
queue (FCFS) and is re-routed next round with fresh load/affinity state.

Policies (``ROUTE_POLICIES``):

* ``round_robin`` — cycle through replicas; the baseline, load-blind.
* ``least_loaded`` — ascending in-flight tokens (prompt + generated of
  every resident or locally-queued request, via ``Engine.load``), free
  paged blocks then free slots as tie-breakers.  Keeps heterogeneous
  request lengths from piling onto one replica.
* ``prefix_affinity`` — rank by the longest *resident* prompt prefix on
  each replica (``Engine.probe_prefix`` →
  ``PagedCacheManager.probe_prefix``, a side-effect-free walk of the
  block hash), falling back to the least-loaded ordering among equal
  hits.  Shared-prompt traffic lands where its KV blocks already live,
  so the paged prefix cache actually hits across requests instead of
  being shredded by round-robin placement.

Every route decision — regardless of policy — also *records* the chosen
replica's resident-prefix hit in ``RouterStats``, so benchmarks can
compare the hit-rate a policy achieved without instrumenting engines.

Disaggregated roles (``roles=``): each replica carries a
``{"prefill","decode","mixed"}`` role.  New prompts only ever rank over
the **admission targets** (prefill or mixed); :meth:`Router.rank_decode`
ranks the **decode targets** (decode or mixed) by load for the cluster's
prefill->decode KV migration, and :meth:`Router.rank_refold` ranks the
admission targets by load for router-driven refold placement.  An
all-``mixed`` cluster (the default) behaves exactly as before.
"""
from __future__ import annotations

import dataclasses

from repro.serving.telemetry import NULL_TRACER

ROUTE_POLICIES = ("round_robin", "least_loaded", "prefix_affinity")


@dataclasses.dataclass
class RouterStats:
    routed: list[int]           # requests placed per replica
    spills: int = 0             # placements off the policy's first choice
    prefix_hit_tokens: int = 0  # resident prefix on the chosen replica
    probed_tokens: int = 0      # total prompt tokens routed

    @property
    def total_routed(self) -> int:
        return sum(self.routed)

    @property
    def prefix_hit_rate(self) -> float:
        return self.prefix_hit_tokens / max(self.probed_tokens, 1)


class Router:
    def __init__(self, engines, policy: str = "round_robin", tracer=None,
                 roles: list[str] | None = None):
        if policy not in ROUTE_POLICIES:
            raise ValueError(
                f"unknown route policy {policy!r} (known: {', '.join(ROUTE_POLICIES)})"
            )
        if not engines:
            raise ValueError("router needs at least one replica")
        self.engines = list(engines)
        self.policy = policy
        self.tracer = NULL_TRACER if tracer is None else tracer
        self._rr = 0
        self.stats = RouterStats(routed=[0] * len(self.engines))
        self.roles = list(roles) if roles else ["mixed"] * len(self.engines)
        if len(self.roles) != len(self.engines):
            raise ValueError(
                f"{len(self.roles)} roles for {len(self.engines)} replicas"
            )
        self._admit_idx = [i for i, r in enumerate(self.roles)
                           if r in ("prefill", "mixed")]
        self._decode_idx = [i for i, r in enumerate(self.roles)
                            if r in ("decode", "mixed")]
        if not self._admit_idx:
            raise ValueError(
                "no admission target: at least one replica must have role "
                "'prefill' or 'mixed'"
            )

    # ------------------------------------------------------------- ranking
    def _load_key(self, idx: int):
        """Ascending sort key: lightest replica first.  Ties break toward
        more free blocks (paged KV headroom), then more free slots, then
        the lowest index (deterministic)."""
        ld = self.engines[idx].load()
        return (
            ld.inflight_tokens,
            -(ld.free_blocks if ld.free_blocks is not None else 0),
            -ld.free_slots,
            idx,
        )

    def rank(self, req, hits: list[int] | None = None) -> list[int]:
        """Admission-target preference order for ``req`` under the active
        policy (decode-role replicas never prefill new prompts).
        ``prefix_affinity`` probes every candidate unless the caller
        passes precomputed ``hits`` (indexed by replica)."""
        cand = self._admit_idx
        k = len(cand)
        if self.policy == "round_robin":
            return [cand[(self._rr + i) % k] for i in range(k)]
        if self.policy == "least_loaded":
            return sorted(cand, key=self._load_key)
        if hits is None:
            hits = self.probe_hits(req)
        return sorted(cand, key=lambda i: (-hits[i],) + self._load_key(i))

    def probe_hits(self, req) -> list[int]:
        """Resident-prefix hit per replica (admission targets only; a
        decode-role replica is never probed — probes are side-effect-free
        but also pointless there)."""
        return [
            self.engines[i].probe_prefix(req.prompt) if i in set(self._admit_idx)
            else 0
            for i in range(len(self.engines))
        ]

    def _ranked_by_load(self, idxs, exclude: int | None = None) -> list[int]:
        return sorted((i for i in idxs if i != exclude), key=self._load_key)

    def rank_decode(self, exclude: int | None = None) -> list[int]:
        """Decode targets (decode/mixed roles) for a prefill->decode KV
        migration, least-loaded first."""
        return self._ranked_by_load(self._decode_idx, exclude)

    def rank_refold(self, exclude: int | None = None) -> list[int]:
        """Admission targets for re-placing a preempted request's refold,
        least-loaded first (regardless of the admission policy: a refold
        is load leveling, not affinity placement)."""
        return self._ranked_by_load(self._admit_idx, exclude)

    # ------------------------------------------------------------- routing
    def route(self, req) -> int | None:
        """Place ``req``: the policy's first admitting replica, or None
        when every replica is saturated (the caller keeps it queued and
        retries with fresh state).  Each successfully routed request is
        counted exactly once, and the chosen replica's resident-prefix
        hit is recorded under every policy (probed once per replica at
        most — affinity ranking and stats share the same walk)."""
        hits = None
        if self.policy == "prefix_affinity":
            hits = self.probe_hits(req)
        order = self.rank(req, hits)
        for pos, idx in enumerate(order):
            if not self.engines[idx].can_admit(req):
                continue
            if pos > 0:
                self.stats.spills += 1
            self.stats.routed[idx] += 1
            hit = (hits[idx] if hits is not None
                   else self.engines[idx].probe_prefix(req.prompt))
            self.stats.prefix_hit_tokens += hit
            self.stats.probed_tokens += len(req.prompt)
            self.tracer.on_route(req.uid, idx, self.policy, pos, hit,
                                 len(req.prompt))
            if self.policy == "round_robin":
                self._rr = (self._admit_idx.index(idx) + 1) % len(self._admit_idx)
            return idx
        return None
