"""Cluster-level statistics: per-replica rows + aggregate scale-out view.

The paper's scale-out claim is about *aggregate* serving capacity — HPU
cards added to a node raise total KV residency and therefore total
decode throughput.  The cluster analogue reported here:

* ``tokens_per_round`` — generated tokens per cluster round (one round
  steps every replica once), the machine-independent scaling metric
  ``benchmarks/cluster_bench.py`` gates on;
* per-replica ``utilization`` — the fraction of each replica's
  slot-rounds that produced a token (idle replicas drag this down);
* ``load_imbalance`` — max/mean of per-replica generated tokens: 1.0 is
  a perfectly level cluster, and a bad router shows up here first;
* ``mean_queue_wait_rounds`` — rounds a request spent in the *global*
  queue before any replica could admit it (per-replica TTFT is measured
  by each engine separately);
* ``mean_ttft_rounds`` — submit round to first-token round, the
  *end-to-end* TTFT clock: unlike each engine's step-clock TTFT it
  includes the global queue wait, so it is the metric disaggregated
  (prefill/decode role) layouts are judged on;
* ``migrations`` / ``refold_moves`` — cross-replica KV handoffs (the
  disaggregated prefill->decode path) and router-driven refold
  re-placements.
"""
from __future__ import annotations

import dataclasses

from repro.serving.engine import EngineStats
from repro.serving.telemetry import percentile


@dataclasses.dataclass
class ReplicaStats:
    """One replica's contribution, as the cluster saw it."""

    replica: int
    routed: int                 # requests the router placed here
    n_slots: int
    engine: EngineStats         # the replica engine's own counters
    role: str = "mixed"         # disaggregated serving role

    def utilization(self, rounds: int) -> float:
        """Generated tokens per slot-round offered to this replica."""
        return self.engine.generated / max(rounds * self.n_slots, 1)

    @property
    def routed_share(self) -> float:
        """Routed requests per token generated (0.0 before any output)."""
        return self.routed / max(self.engine.generated, 1)


@dataclasses.dataclass
class ClusterStats:
    rounds: int
    replicas: list[ReplicaStats]
    spills: int                 # requests admitted off their first choice
    prefix_hit_tokens: int      # resident-prefix tokens at routing time
    probed_tokens: int          # total prompt tokens routed
    queue_wait_sum: int         # rounds spent in the global queue
    queue_wait_count: int
    migrations: int = 0         # prefill->decode KV handoffs
    refold_moves: int = 0       # refolds re-placed off their home replica
    # submit round -> first-token round per request (end-to-end TTFT)
    ttft_rounds_samples: list[int] = dataclasses.field(default_factory=list)

    @property
    def generated(self) -> int:
        return sum(r.engine.generated for r in self.replicas)

    @property
    def mean_ttft_rounds(self) -> float:
        """Mean end-to-end TTFT in cluster rounds (includes the global
        queue wait; see module docstring)."""
        return (sum(self.ttft_rounds_samples)
                / max(len(self.ttft_rounds_samples), 1))

    def ttft_rounds_percentile(self, p: float) -> float:
        return percentile(self.ttft_rounds_samples, p)

    @property
    def preemptions(self) -> int:
        return sum(r.engine.preemptions for r in self.replicas)

    @property
    def kv_spills(self) -> int:
        """KV blocks spilled to the host tier across replicas (distinct
        from ``spills``, which counts router spill-over placements)."""
        return sum(r.engine.spills for r in self.replicas)

    @property
    def kv_rehydrations(self) -> int:
        return sum(r.engine.rehydrations for r in self.replicas)

    @property
    def tokens_per_round(self) -> float:
        return self.generated / max(self.rounds, 1)

    @property
    def mean_queue_wait_rounds(self) -> float:
        return self.queue_wait_sum / max(self.queue_wait_count, 1)

    @property
    def mean_ttft_steps(self) -> float:
        """Request-weighted mean TTFT across replicas, in engine steps."""
        total = sum(r.engine.ttft_steps_sum for r in self.replicas)
        count = sum(r.engine.ttft_count for r in self.replicas)
        return total / max(count, 1)

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of routed prompt tokens already resident on the
        chosen replica (the prefix-affinity win metric)."""
        return self.prefix_hit_tokens / max(self.probed_tokens, 1)

    @property
    def load_imbalance(self) -> float:
        """max/mean of per-replica generated tokens (1.0 = level)."""
        gen = [r.engine.generated for r in self.replicas]
        if not gen:
            return 1.0
        mean = sum(gen) / len(gen)
        return max(gen) / mean if mean > 0 else 1.0

    def ttft_percentile(self, p: float) -> float:
        """Exact TTFT percentile over all replicas' raw samples."""
        return percentile(
            [s for r in self.replicas for s in r.engine.ttft_samples], p
        )

    @property
    def ttft_p50_steps(self) -> float:
        return self.ttft_percentile(50)

    @property
    def ttft_p99_steps(self) -> float:
        return self.ttft_percentile(99)

    def per_token_percentile(self, p: float) -> float:
        """Exact decode per-token-latency percentile across replicas."""
        return percentile(
            [s for r in self.replicas for s in r.engine.per_token_samples], p
        )

    def summary(self) -> str:
        per = " ".join(
            f"r{r.replica}[{r.role[0].upper()}]:routed={r.routed},"
            f"gen={r.engine.generated},"
            f"util={r.utilization(self.rounds):.2f}"
            for r in self.replicas
        )
        extra = ""
        if self.migrations or self.refold_moves:
            extra = (f" migrations={self.migrations}"
                     f" refold_moves={self.refold_moves}")
        return (
            f"rounds={self.rounds} generated={self.generated} "
            f"tokens/round={self.tokens_per_round:.2f} "
            f"ttft={self.mean_ttft_steps:.1f} "
            f"ttft_rounds={self.mean_ttft_rounds:.1f} "
            f"queue_wait={self.mean_queue_wait_rounds:.1f} "
            f"imbalance={self.load_imbalance:.2f} spills={self.spills} "
            f"prefix_hit_rate={self.prefix_hit_rate:.2f}{extra} | {per}"
        )
