"""Continuous-batching serving engine with HPU-offloaded decode.

Slot-based continuous batching (Orca-style): a fixed decode batch of
``n_slots`` sequences; finished sequences free their slot and queued
requests are prefilled into it while decode keeps running for the rest —
this is what keeps the decode batch (and thus the offloaded-attention
bandwidth utilization the paper optimizes) high.

Two cache modes (``cache_kind``):

* ``"dense"`` — the seed baseline: every slot reserves a full
  ``max_seq`` stripe of KV, admission is gated on free *slots*.
* ``"paged"`` — physical KV is a :class:`~repro.serving.paged.BlockPool`
  of fixed-size blocks; admission is gated on free *blocks* (actual HPU
  memory), shared prompt prefixes share physical blocks (copy-on-write
  on first divergent append), and running out of blocks preempts the
  youngest sequence back to the queue — it re-prefills later from its
  prompt plus the tokens already generated, so greedy output is exact.

Two schedules (``schedule``; :mod:`repro.serving.scheduler`):

* ``"decode-only"`` — whole-prompt prefill at admission (one jit program
  per distinct prompt length), every model step is decode-only.
* ``"hybrid"`` — a token-budget :class:`Scheduler` packs each iteration
  as one decode token per active slot *plus* one bucket-padded chunk of
  the head-of-queue prompt, executed as a single fused model step: the
  chunk's GEMMs ride the decode batch's weight stream (the paper's
  GPU/HPU co-processing, expressed as one program on one mesh), and all
  jit shapes come from the scheduler's fixed bucket set.  Greedy outputs
  are token-identical to ``decode-only``.  Paged sequences admit
  partially — each chunk acquires only the blocks it needs.

The decode step is wrapped by ``core.pipeline.pipelined_step`` when
``sub_batches > 1`` (paper Fig. 3), and attention runs through
``core.offload`` in the layout chosen by ``core.balance.plan``.

Step accounting: ``EngineStats.engine_steps`` counts fixed-shape model
dispatches; a decode-only whole prefill of ``L`` tokens counts
``ceil(L / prefill_chunk)`` steps (the hybrid-batch units it occupies),
so TTFT/throughput in steps are comparable across schedules.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import pipelined_step
from repro.models.registry import Model
from repro.serving import kv_cache
from repro.serving.paged import BlockPool, PagedCacheManager
from repro.serving.paged import device as paged_dev
from repro.serving.sampler import SamplerConfig, sample
from repro.serving.scheduler import PrefillChunk, Scheduler

Pytree = Any


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int
    eos_id: int = -1                # -1: never stops early
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # latency accounting, in engine steps (-1 = not reached yet)
    submit_step: int = 0
    admit_step: int = -1
    first_token_step: int = -1
    finish_step: int = -1


@dataclasses.dataclass
class EngineStats:
    prefills: int = 0               # completed request prefills
    prefill_chunks: int = 0         # hybrid: chunks executed
    decode_steps: int = 0           # model steps that carried a decode batch
    engine_steps: int = 0           # normalized step clock (see module doc)
    generated: int = 0
    peak_active: int = 0
    preemptions: int = 0
    ttft_steps_sum: int = 0
    ttft_count: int = 0

    @property
    def mean_ttft_steps(self) -> float:
        """Mean submit->first-token latency, in engine steps."""
        return self.ttft_steps_sum / max(self.ttft_count, 1)

    @property
    def tokens_per_step(self) -> float:
        return self.generated / max(self.engine_steps, 1)


class Engine:
    def __init__(
        self,
        model: Model,
        params: Pytree,
        n_slots: int,
        max_seq: int,
        sampler: SamplerConfig = SamplerConfig(),
        sub_batches: int = 1,
        rng: jax.Array | None = None,
        cache_kind: str = "dense",
        block_size: int = 16,
        n_blocks: int | None = None,
        schedule: str = "decode-only",
        prefill_chunk: int = 32,
        token_budget: int | None = None,
    ):
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self.sampler = sampler
        self.cache_kind = cache_kind
        self.schedule = schedule
        self.prefill_chunk = prefill_chunk
        self.slots: list[Request | None] = [None] * n_slots
        self.stats = EngineStats()
        self.rng = rng if rng is not None else jax.random.key(0)

        self._prefill = jax.jit(model.prefill)
        if cache_kind == "paged":
            if model.paged_decode_step is None:
                raise ValueError(f"{model.cfg.family} has no paged decode path")
            if sub_batches != 1:
                raise NotImplementedError(
                    "paged cache does not compose with sub-batch pipelining yet"
                )
            self.block_size = block_size
            self.max_blocks = -(-max_seq // block_size)
            # default: same physical budget as the dense cache, + null block
            self.n_blocks = (
                n_slots * self.max_blocks + 1 if n_blocks is None else n_blocks
            )
            if self.n_blocks - 1 < self.max_blocks:
                raise ValueError(
                    f"pool of {self.n_blocks - 1} usable blocks cannot hold one "
                    f"max_seq={max_seq} sequence ({self.max_blocks} blocks)"
                )
            self.pool = BlockPool(self.n_blocks, block_size)
            self.manager = PagedCacheManager(self.pool, n_slots, self.max_blocks)
            self.cache = model.init_paged_cache(
                n_slots, self.n_blocks, block_size, self.max_blocks
            )
            self._decode = jax.jit(model.paged_decode_step)
        elif cache_kind == "dense":
            self.cache = model.init_cache(n_slots, max_seq)
            step = pipelined_step(model.decode_step, sub_batches)
            self._decode = jax.jit(step)
        else:
            raise ValueError(f"unknown cache_kind {cache_kind!r}")

        self.sched = Scheduler(
            n_slots=n_slots, max_seq=max_seq, mode=schedule,
            prefill_chunk=prefill_chunk, token_budget=token_budget,
            block_size=block_size if cache_kind == "paged" else None,
        )
        if schedule == "hybrid":
            self._init_hybrid(sub_batches)

    def _init_hybrid(self, sub_batches: int) -> None:
        model = self.model
        if model.prefill_step is None:
            raise ValueError(
                f"{model.cfg.family} has no prefill_step: hybrid scheduling "
                "needs the chunked-prefill model entry point"
            )
        if model.cfg.kv_quant:
            raise NotImplementedError("hybrid schedule does not support kv_quant yet")
        if sub_batches != 1:
            raise NotImplementedError(
                "hybrid schedule does not compose with sub-batch pipelining yet"
            )
        # chunk tokens of the prompt being prefilled (set by _begin_prefill)
        self._inflight_tokens: np.ndarray | None = None
        self._prefix_blocks = 0
        self._solo = jax.jit(model.prefill_step)
        if self.cache_kind == "paged":
            # persistent staging cache (one fixed shape): chunks accumulate
            # here, completed blocks flush into the pool
            self.staging = model.init_cache(1, self.max_blocks * self.block_size)

            def _fused(params, cache, staging, dec_tokens, pre_tokens, off, nv):
                pre_logits, staging = model.prefill_step(
                    params, staging, pre_tokens, 0, off, nv
                )
                dec_logits, cache = model.paged_decode_step(params, cache, dec_tokens)
                return dec_logits, pre_logits, cache, staging
        else:

            def _fused(params, cache, dec_tokens, pre_tokens, slot, off, nv):
                pre_logits, cache = model.prefill_step(
                    params, cache, pre_tokens, slot, off, nv
                )
                dec_logits, cache = model.decode_step(params, cache, dec_tokens)
                # decode advanced every slot's length; the mid-prefill slot
                # stays at its chunk end (its garbage append is overwritten
                # by the next chunk / first decode token)
                lengths = cache["lengths"].at[slot].set(off + nv)
                return dec_logits, pre_logits, {**cache, "lengths": lengths}

        self._fused = jax.jit(_fused)

    # ------------------------------------------------------------- requests
    def submit(self, req: Request):
        if len(req.prompt) >= self.max_seq - 1:
            raise ValueError(
                f"prompt of {len(req.prompt)} tokens does not fit max_seq="
                f"{self.max_seq}: admission needs len(prompt) <= max_seq - 2 "
                "so the cache holds the prompt plus at least one generated "
                "token without overflowing mid-decode"
            )
        req.submit_step = self.stats.engine_steps
        self.sched.submit(req)

    def _free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def _next_rng(self) -> jax.Array:
        self.rng, sub = jax.random.split(self.rng)
        return sub

    @staticmethod
    def _refold(req: Request) -> np.ndarray:
        """Prompt plus already-generated tokens: prefilling this exactly
        reproduces a preempted request's decode state (greedy-exact)."""
        return np.concatenate(
            [np.asarray(req.prompt, np.int32),
             np.asarray(req.out_tokens, np.int32)]
        )

    # ------------------------------------------- admission (whole-prefill)
    def _prefill_cost(self, n_tokens: int) -> int:
        """Whole-prefill step cost, in fixed hybrid-batch units."""
        return max(1, -(-n_tokens // self.prefill_chunk))

    def _admit(self):
        if self.cache_kind == "paged":
            self._admit_paged()
            return
        for slot in self._free_slots():
            if not len(self.sched):
                break
            req = self.sched.pop()
            self.stats.engine_steps += self._prefill_cost(len(req.prompt))
            if req.admit_step < 0:
                req.admit_step = self.stats.engine_steps
            prompt = jnp.asarray(req.prompt, jnp.int32)[None]
            sub_cache = self.model.init_cache(1, self.max_seq)
            logits, sub_cache = self._prefill(self.params, prompt, sub_cache)
            self.cache = kv_cache.insert(self.cache, sub_cache, slot)
            self.slots[slot] = req
            self._sample_prefill(req, logits)

    def _admit_paged(self):
        """Admit while slots AND blocks allow; head-of-line blocks wait.

        A preempted request re-enters here with its generated tokens
        folded into the prefill, reproducing its exact decode state.
        """
        for slot in self._free_slots():
            if not len(self.sched):
                break
            req = self.sched.peek()
            full = self._refold(req)
            # the last sampled token is input, not cache content: the KV
            # written at admission covers full[:-1]'s context plus itself,
            # i.e. exactly len(full) positions after prefill
            res = self.manager.try_admit(slot, full)
            if res is None:
                break                       # out of blocks: wait/FCFS
            self.sched.pop()
            self.stats.engine_steps += self._prefill_cost(len(full))
            if req.admit_step < 0:
                req.admit_step = self.stats.engine_steps
            blocks, n_cached = res
            pad = -(-len(full) // self.block_size) * self.block_size
            sub_cache = self.model.init_cache(1, pad)
            logits, sub_cache = self._prefill(
                self.params, jnp.asarray(full, jnp.int32)[None], sub_cache
            )
            # fill only the blocks the prefix cache didn't already hold
            for j in range(n_cached, len(blocks)):
                self.cache = paged_dev.write_prompt_block(
                    self.cache, sub_cache, blocks[j], j * self.block_size
                )
            self.cache = paged_dev.sync_slot(
                self.cache, slot, self.manager.tables[slot], len(full)
            )
            self.slots[slot] = req
            self._sample_prefill(req, logits)

    def _sample_prefill(self, req: Request, logits):
        tok = int(sample(logits, self._next_rng(), self.sampler)[0])
        req.out_tokens.append(tok)
        if req.first_token_step < 0:
            req.first_token_step = self.stats.engine_steps
            self.stats.ttft_steps_sum += req.first_token_step - req.submit_step
            self.stats.ttft_count += 1
        self.stats.prefills += 1
        self.stats.generated += 1

    # --------------------------------------------- admission (chunked/hybrid)
    def _begin_prefill(self, req: Request, slot: int) -> tuple[int, int]:
        """Pin ``req``'s (possibly re-folded) prompt for chunked prefill;
        returns (first chunk position, total tokens)."""
        full = self._refold(req)
        self._inflight_tokens = full
        if self.cache_kind != "paged":
            self._prefix_blocks = 0
            return 0, len(full)
        bs = self.block_size
        matched = self.manager.begin_chunked(slot, full)
        self._prefix_blocks = len(matched)
        for j, phys in enumerate(matched):
            self.staging = paged_dev.read_block(self.staging, self.cache, phys, j * bs)
        # a fully prefix-cached prompt still recomputes its last chunk for
        # the first-token logits (pool writes for matched blocks skip)
        start = min(len(matched) * bs, (len(full) - 1) // bs * bs)
        return start, len(full)

    def _complete_chunk(self, work: PrefillChunk, pre_logits):
        if self.cache_kind == "paged":
            bs = self.block_size
            end = work.start + work.n_valid
            for j in range(work.start // bs, (end - 1) // bs + 1):
                if j < self._prefix_blocks:
                    continue            # prefix-cache hit: already valid
                self.cache = paged_dev.write_prompt_block(
                    self.cache, self.staging, self.manager.blocks[work.slot][j],
                    j * bs,
                )
        self.sched.advance(work)
        if work.last:
            req = work.req
            self.slots[work.slot] = req
            if self.cache_kind == "paged":
                self.cache = paged_dev.sync_slot(
                    self.cache, work.slot, self.manager.tables[work.slot],
                    work.start + work.n_valid,
                )
            self._inflight_tokens = None
            self._sample_prefill(req, pre_logits)

    # ----------------------------------------------------- block management
    def _kv_len(self, slot: int) -> int:
        """KV positions held for ``slot`` (last sampled token not yet
        appended — it is this step's input)."""
        req = self.slots[slot]
        return len(req.prompt) + len(req.out_tokens) - 1

    def _preempt(self, slot: int):
        """Evict ``slot`` to the queue front; blocks return to the pool.
        Its tokens are preserved and recomputed at re-admission."""
        req = self.slots[slot]
        self.slots[slot] = None
        self.manager.free_slot(slot)
        self.cache = paged_dev.sync_slot(
            self.cache, slot, self.manager.tables[slot], 0
        )
        self.sched.push_front(req)
        self.stats.preemptions += 1
        self.pool.stats.preemptions += 1

    def _prepare_append(self, active: list[int]) -> list[int]:
        """Guarantee every active slot can write its next token: allocate
        boundary blocks, copy-on-write shared tails, preempt the youngest
        sequence when the pool runs dry.  Returns the surviving slots."""
        alive = set(active)
        for slot in sorted(active, key=lambda s: self.manager.admit_seq[s]):
            while slot in alive:
                directive, payload = self.manager.ensure_append(
                    slot, self._kv_len(slot)
                )
                if directive == "oom":
                    victim = self.manager.youngest(alive)
                    self._preempt(victim)
                    alive.discard(victim)
                    continue                # retry (unless we evicted slot)
                if directive == "cow":
                    src, dst = payload
                    self.cache = paged_dev.copy_block(self.cache, src, dst)
                if directive in ("cow", "new"):
                    self.cache = paged_dev.sync_slot(
                        self.cache, slot, self.manager.tables[slot]
                    )
                break
        return [s for s in active if s in alive]

    # ----------------------------------------------------------------- step
    def _decode_tokens(self) -> jax.Array:
        tokens = np.zeros((len(self.slots),), np.int32)
        for i, req in enumerate(self.slots):
            if req is not None and req.out_tokens:
                tokens[i] = req.out_tokens[-1]
        return jnp.asarray(tokens)

    def _finish_decode(self, active: list[int], logits):
        next_toks = sample(logits, self._next_rng(), self.sampler)
        next_host = np.asarray(next_toks)
        for i in active:
            req = self.slots[i]
            tok = int(next_host[i])
            req.out_tokens.append(tok)
            self.stats.generated += 1
            length = len(req.prompt) + len(req.out_tokens)
            if (
                tok == req.eos_id
                or len(req.out_tokens) >= req.max_new_tokens
                or length >= self.max_seq - 1
            ):
                req.done = True
                req.finish_step = self.stats.engine_steps
                self.slots[i] = None
                if self.cache_kind == "paged":
                    self.manager.free_slot(i)
                    self.cache = paged_dev.sync_slot(
                        self.cache, i, self.manager.tables[i], 0
                    )
                else:
                    self.cache = kv_cache.reset_slot(self.cache, i)

    def step(self) -> bool:
        """One engine iteration.  Returns whether any work remains."""
        if self.schedule == "hybrid":
            return self._step_hybrid()
        return self._step_decode_only()

    def _step_decode_only(self) -> bool:
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if self.cache_kind == "paged" and active:
            active = self._prepare_append(active)
        if not active:
            return self.sched.has_work()
        self.stats.peak_active = max(self.stats.peak_active, len(active))

        logits, self.cache = self._decode(
            self.params, self.cache, self._decode_tokens()
        )
        self.stats.decode_steps += 1
        self.stats.engine_steps += 1
        self._finish_decode(active, logits)
        return any(s is not None for s in self.slots) or self.sched.has_work()

    def _step_hybrid(self) -> bool:
        sched = self.sched
        if sched.inflight is None and len(sched):
            free = self._free_slots()
            if free:
                req = sched.pop()
                slot = free[0]
                start, total = self._begin_prefill(req, slot)
                sched.begin(req, slot, start, total)
                if req.admit_step < 0:
                    req.admit_step = self.stats.engine_steps + 1

        active = [i for i, s in enumerate(self.slots) if s is not None]
        if self.cache_kind == "paged" and active:
            active = self._prepare_append(active)
        decision = sched.schedule(active)
        active = decision.decode_slots       # the scheduler owns the batch
        work = decision.prefill
        if work is not None and self.cache_kind == "paged":
            ok = self.manager.extend_chunked(
                work.slot, len(self._inflight_tokens),
                work.start + work.n_valid, work.last,
            )
            if not ok:
                work = None             # pool dry: decode-only iteration
        if not active and work is None:
            return sched.has_work()

        self.stats.engine_steps += 1
        self.stats.peak_active = max(self.stats.peak_active, len(active))
        if work is not None:
            chunk = np.zeros((1, work.bucket), np.int32)
            chunk[0, :work.n_valid] = self._inflight_tokens[
                work.start:work.start + work.n_valid
            ]
            chunk = jnp.asarray(chunk)
            off, nv = np.int32(work.start), np.int32(work.n_valid)

        dec_logits = pre_logits = None
        if active and work is not None:
            if self.cache_kind == "paged":
                dec_logits, pre_logits, self.cache, self.staging = self._fused(
                    self.params, self.cache, self.staging,
                    self._decode_tokens(), chunk, off, nv,
                )
            else:
                dec_logits, pre_logits, self.cache = self._fused(
                    self.params, self.cache, self._decode_tokens(), chunk,
                    np.int32(work.slot), off, nv,
                )
            self.stats.decode_steps += 1
        elif active:
            dec_logits, self.cache = self._decode(
                self.params, self.cache, self._decode_tokens()
            )
            self.stats.decode_steps += 1
        else:
            if self.cache_kind == "paged":
                pre_logits, self.staging = self._solo(
                    self.params, self.staging, chunk, np.int32(0), off, nv
                )
            else:
                pre_logits, self.cache = self._solo(
                    self.params, self.cache, chunk, np.int32(work.slot), off, nv
                )

        if active:
            self._finish_decode(active, dec_logits)
        if work is not None:
            self.stats.prefill_chunks += 1
            self._complete_chunk(work, pre_logits)
        return any(s is not None for s in self.slots) or sched.has_work()

    def run(self, max_steps: int = 10_000) -> EngineStats:
        for _ in range(max_steps):
            if not self.step():
                break
        return self.stats

    # -------------------------------------------------------- introspection
    def kv_bytes(self) -> int:
        """Physical KV footprint of the resident cache (both modes)."""
        return kv_cache.kv_bytes(self.cache)
