"""Continuous-batching serving engine with HPU-offloaded decode.

Slot-based continuous batching (Orca-style): a fixed decode batch of
``n_slots`` sequences; finished sequences free their slot and queued
requests are prefilled into it while decode keeps running for the rest —
this is what keeps the decode batch (and thus the offloaded-attention
bandwidth utilization the paper optimizes) high.

Two cache modes (``cache_kind``):

* ``"dense"`` — the seed baseline: every slot reserves a full
  ``max_seq`` stripe of KV, admission is gated on free *slots*.
* ``"paged"`` — physical KV is a :class:`~repro.serving.paged.BlockPool`
  of fixed-size blocks; admission is gated on free *blocks* (actual HPU
  memory), shared prompt prefixes share physical blocks (copy-on-write
  on first divergent append), and running out of blocks preempts the
  youngest sequence back to the queue — it re-prefills later from its
  prompt plus the tokens already generated, so greedy output is exact.

Two schedules (``schedule``; :mod:`repro.serving.scheduler`):

* ``"decode-only"`` — whole-prompt prefill at admission (one jit program
  per distinct prompt length), every model step is decode-only.
* ``"hybrid"`` — a token-budget :class:`Scheduler` packs each iteration
  as one decode token per active slot *plus* one bucket-padded chunk of
  the head-of-queue prompt, executed as a single fused model step: the
  chunk's GEMMs ride the decode batch's weight stream (the paper's
  GPU/HPU co-processing, expressed as one program on one mesh), and all
  jit shapes come from the scheduler's fixed bucket set.  Greedy outputs
  are token-identical to ``decode-only``.  Paged sequences admit
  partially — each chunk acquires only the blocks it needs.

Two execution modes (``async_mode``):

* ``async_mode=True`` (default) — the dispatch-ahead pipeline.  Every
  jit step samples **on device** and returns sampled token ids plus a
  per-slot EOS flag instead of logits, so the per-step host transfer is
  ``[batch]`` ints, and the token ids feed the next step device-to-device
  (``tok_state``).  The engine dispatches iteration *t+1* from *t*'s
  *planned* host state before *t*'s tokens are observed — JAX's async
  dispatch keeps the device busy through all host-side Python — then
  fetches *t*'s small token array in the background.  Length/max-new
  retirements are host-deterministic and gate dispatch exactly like the
  sync engine; EOS retirements are observed one step late, and the one
  speculative token dispatched past an EOS is masked (never emitted,
  its cache writes are reset with the slot).  Greedy outputs are
  token-identical to sync mode; temperature sampling is valid but
  consumes the rng stream in a different order.
* ``async_mode=False`` — the conservative synchronous fallback
  (``--async off``): block on each step's logits, sample on host.

Correctness of dispatch-ahead rests on device data-flow ordering: every
device op threads ``self.cache`` (and ``self.staging``/``tok_state``),
so host bookkeeping done at dispatch time (block flushes, table syncs,
resets) lands *after* the in-flight step's writes.  The one host action
that needs observed token values — preemption's exact-recovery refold —
observes only the victim slot's in-flight tokens first
(:meth:`Engine._observe_victim`), keeping the rest of the pipeline in
flight; the full drain is paid only when eviction is otherwise
imminent (an unobserved completion elsewhere may still avert it).

The decode step is wrapped by ``core.pipeline.pipelined_step`` when
``sub_batches > 1`` (paper Fig. 3), and attention runs through
``core.offload`` in the layout chosen by ``core.balance.plan``.

Step accounting: ``EngineStats.engine_steps`` counts fixed-shape model
dispatches; a decode-only whole prefill of ``L`` tokens counts
``ceil(L / prefill_chunk)`` steps (the hybrid-batch units it occupies),
so TTFT/throughput in steps are comparable across schedules.

Speculative multi-token decoding (``spec_depth=k`` with a draft model):
each decode dispatch becomes draft-then-verify — the small draft model
proposes ``k`` tokens autoregressively on device, the target model scores
all ``k+1`` positions in one fused ``verify_step`` (the chunked-prefill
``q_offset`` scoring path generalized to per-slot offsets), and
rejection sampling accepts a prefix of the drafts plus one
bonus/correction token.  The accepted prefix feeds back device-to-device
through the same ``tok_state`` plumbing; KV "rollback" is simply not
advancing ``lengths`` past the accepted prefix (garbage K/V beyond the
committed length is causally invisible and overwritten by later writes).
Greedy output is token-identical to non-speculative decoding;
temperature sampling matches the target distribution exactly (standard
rejection/residual sampling).  Speculation always runs on the
dispatch-ahead machinery — ``async_mode=False`` with ``spec_depth > 0``
collapses to a pipeline of depth zero (dispatch, then observe
immediately), which keeps one code path and stays greedy
token-identical.  A speculative dispatch carries ``k+1`` in-flight
token *charges* per slot (the router's load accounting sees the true
KV commitment upper bound) but only one guaranteed commit
(``in_flight_steps``), which is what dispatch prediction uses.

Cross-replica migration (disaggregated serving): a paged request whose
prefill just completed can leave this engine and continue decoding on
another — :meth:`Engine.preview_export` sizes the move without side
effects, :meth:`Engine.export_request` detaches the slot and returns a
``MigrationTicket`` (block payloads gathered in storage dtype, scale
pools included, shared-prefix blocks copied out so remaining owners
keep theirs), and :meth:`Engine.can_import` /
:meth:`Engine.import_request` admit it on the destination, deduping
against blocks already resident under the same chain hash.  The
cluster drives this; a refused import simply decodes in place.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import pipelined_step
from repro.models.registry import Model
from repro.serving import kv_cache
from repro.serving.paged import BlockPool, PagedCacheManager
from repro.serving.paged import device as paged_dev
from repro.serving.sampler import (
    SamplerConfig,
    sample,
    sample_on_device,
    spec_draft_sample,
    spec_verify_tokens,
)
from repro.serving.scheduler import PrefillChunk, Scheduler
from repro.serving.telemetry import (
    NULL_PROFILER,
    NULL_TRACER,
    DispatchCostModel,
    StepRecord,
    percentile,
)

Pytree = Any


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int
    eos_id: int = -1                # -1: never stops early
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # latency accounting, in engine steps (-1 = not reached yet)
    submit_step: int = 0
    admit_step: int = -1
    first_token_step: int = -1
    finish_step: int = -1
    # async engine bookkeeping.  One dispatched step carries one token
    # charge normally; a speculative step carries spec_depth+1 charges
    # (the commit upper bound, what KV/load accounting must cover) but
    # guarantees only one commit — in_flight_steps counts the guaranteed
    # floor, which is what dispatch prediction may rely on.
    in_flight: int = 0              # token charges dispatched, not observed
    in_flight_steps: int = 0        # dispatched steps (>= 1 commit each)
    admit_base: int = 0             # len(out_tokens) at last (re-)admission


@dataclasses.dataclass
class EngineStats:
    prefills: int = 0               # completed request prefills
    prefill_chunks: int = 0         # hybrid: chunks executed
    boundary_packs: int = 0         # hybrid: head chunks packed at a boundary
    decode_steps: int = 0           # model steps that carried a decode batch
    engine_steps: int = 0           # normalized step clock (see module doc)
    generated: int = 0
    peak_active: int = 0
    preemptions: int = 0
    victim_drains: int = 0          # async: partial (victim-only) drains
    spills: int = 0                 # KV blocks copied device -> host tier
    rehydrations: int = 0           # KV blocks copied host tier -> device
    migrations_out: int = 0         # resident requests exported to a peer
    migrations_in: int = 0          # resident requests imported from a peer
    spec_steps: int = 0             # speculative draft-verify dispatches
    draft_steps: int = 0            # draft-model steps (decode + prefill chunks)
    drafted_tokens: int = 0         # draft proposals consumed by verification
                                    # (windows masked past a finish don't count)
    accepted_tokens: int = 0        # draft proposals accepted
    ttft_steps_sum: int = 0
    ttft_count: int = 0
    # raw per-request samples (ttft: submit->first-token in engine steps;
    # per_token: decode steps per generated token after the first) so
    # percentiles are exact, not reconstructed from sums
    ttft_samples: list[int] = dataclasses.field(default_factory=list)
    per_token_samples: list[float] = dataclasses.field(default_factory=list)
    # per-observed-window acceptance fractions (accepted / spec_depth)
    spec_accept_samples: list[float] = dataclasses.field(default_factory=list)

    @property
    def acceptance_rate(self) -> float:
        """Fraction of drafted tokens the verifier accepted."""
        return self.accepted_tokens / max(self.drafted_tokens, 1)

    @property
    def mean_ttft_steps(self) -> float:
        """Mean submit->first-token latency, in engine steps."""
        return self.ttft_steps_sum / max(self.ttft_count, 1)

    @property
    def tokens_per_step(self) -> float:
        return self.generated / max(self.engine_steps, 1)

    def ttft_percentile(self, p: float) -> float:
        """Exact nearest-rank TTFT percentile over per-request samples."""
        return percentile(self.ttft_samples, p)

    @property
    def ttft_p50_steps(self) -> float:
        return self.ttft_percentile(50)

    @property
    def ttft_p99_steps(self) -> float:
        return self.ttft_percentile(99)

    def per_token_percentile(self, p: float) -> float:
        return percentile(self.per_token_samples, p)


@dataclasses.dataclass
class EngineLoad:
    """One replica's load snapshot, read by the cluster router.

    ``inflight_tokens`` counts KV positions committed to this replica —
    prompt plus generated (observed and dispatched) tokens of every
    resident request, plus the prompt tokens of anything waiting in the
    local queue (a preempted request is still this replica's work).
    """

    free_slots: int
    queued: int
    inflight_tokens: int
    free_blocks: int | None         # paged only; None for the dense cache


@dataclasses.dataclass
class MigrationTicket:
    """Host-side description of an exported resident request's KV.

    ``keys`` is the paged hash-key chain aligned with the payload's block
    columns (None entries are diverged tails / decode headroom); the
    dense cache has no keys (``None``) and its payload is a batch-1
    sub-cache.  ``length`` is the KV positions held (prompt + observed
    output - 1: the last sampled token is the next step's *input*).
    """

    length: int
    kv_dtype: str
    keys: list | None = None         # paged: per-block hash chain
    n_blocks: int = 0                # paged: payload block count
    block_size: int = 0              # paged: source pool block granularity
    src_step: int = 0                # source engine-step clock at export


@dataclasses.dataclass
class _PendingStep:
    """One dispatched-but-unobserved model step (async pipeline).

    ``reqs`` pins the requests that were in the decode batch at dispatch
    — a slot may be retired and re-admitted to a different request
    before this record is observed, so slot indices alone are not
    enough.  ``tokens``/``eos`` are in-flight device arrays; fetching
    them blocks only until *this* step finishes while later steps keep
    the device busy.
    """

    step: int                            # engine_steps value at dispatch
    reqs: dict[int, Request]             # slot -> request in decode batch
    tokens: jax.Array | None             # (B,) sampled ids (device)
    eos: jax.Array | None                # (B,) bool EOS hits (device)
    work: PrefillChunk | None = None     # chunk fused into this step
    pre_tok: jax.Array | None = None     # (1,) first token when work.last
    work2: PrefillChunk | None = None    # boundary-packed second chunk
    pre_tok2: jax.Array | None = None    # (1,) first token when work2.last
    # speculative dispatch: tokens is (B, k+1) emitted rows, eos is None
    # (EOS is found host-side while walking the accepted prefix), and
    # each decode-batch request carried `charge` in-flight token charges
    n_accept: jax.Array | None = None    # (B,) accepted-draft counts (device)
    charge: int = 1                      # in-flight charges per batch slot


class Engine:
    def __init__(
        self,
        model: Model,
        params: Pytree,
        n_slots: int,
        max_seq: int,
        sampler: SamplerConfig = SamplerConfig(),
        sub_batches: int = 1,
        rng: jax.Array | None = None,
        cache_kind: str = "dense",
        block_size: int = 16,
        n_blocks: int | None = None,
        kv_dtype: str = "bf16",
        host_blocks: int = 0,
        schedule: str = "decode-only",
        prefill_chunk: int = 32,
        token_budget: int | None = None,
        async_mode: bool = True,
        spec_depth: int = 0,
        draft_model: Model | None = None,
        draft_params: Pytree | None = None,
        tracer=None,
        profiler=None,
        replica: int = 0,
        role: str = "mixed",
    ):
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self.sampler = sampler
        self.cache_kind = cache_kind
        self.schedule = schedule
        self.prefill_chunk = prefill_chunk
        self.async_mode = async_mode
        # speculative decoding always runs on the dispatch-ahead machinery;
        # --async off collapses to a pipeline of depth zero (dispatch, then
        # observe immediately) so there is exactly one speculative code
        # path and it stays greedy token-identical to the sync engine
        if spec_depth < 0:
            raise ValueError(f"spec_depth must be >= 0, got {spec_depth}")
        self.spec_depth = spec_depth
        self.draft_model = draft_model
        self.draft_params = draft_params
        self._sync_pipeline = False
        if spec_depth:
            if draft_model is None or draft_params is None:
                raise ValueError(
                    "spec_depth > 0 needs a draft_model and draft_params"
                )
            if sub_batches != 1:
                raise NotImplementedError(
                    "speculative decoding does not compose with sub-batch "
                    "pipelining yet"
                )
            if model.cfg.kv_quant:
                raise NotImplementedError(
                    "speculative decoding does not support kv_quant yet"
                )
            if (model.paged_verify_step if cache_kind == "paged"
                    else model.verify_step) is None:
                raise ValueError(
                    f"{model.cfg.family} has no verify_step: speculative "
                    "decoding needs the multi-position scoring entry point"
                )
            if draft_model.prefill_step is None:
                raise ValueError(
                    f"draft family {draft_model.cfg.family} has no "
                    "prefill_step: the draft cache is filled chunk-wise"
                )
            if draft_model.cfg.vocab != model.cfg.vocab:
                raise ValueError(
                    f"draft vocab {draft_model.cfg.vocab} != target vocab "
                    f"{model.cfg.vocab}: rejection sampling needs one "
                    "token space"
                )
            if cache_kind == "paged" and (kv_dtype != "bf16" or host_blocks):
                raise NotImplementedError(
                    "speculative verification reads the bf16 device pool "
                    "only (no quantized kv_dtype / host tier yet)"
                )
            self._sync_pipeline = not async_mode
            self.async_mode = async_mode = True
        # disaggregated serving: the role is *advisory* routing metadata
        # (the cluster admits prompts to prefill/mixed replicas and
        # migrates finished prefills off "prefill" replicas) — the engine
        # itself always handles both phases, so a migration that finds no
        # destination degrades gracefully to decoding in place
        if role not in ("prefill", "decode", "mixed"):
            raise ValueError(f"unknown role {role!r}")
        self.role = role
        self.slots: list[Request | None] = [None] * n_slots
        self.stats = EngineStats()
        self.rng = rng if rng is not None else jax.random.key(0)
        # telemetry: NULL_TRACER / NULL_PROFILER hooks are no-ops, and
        # `_telemetry` gates the per-dispatch StepRecord construction so a
        # disabled run does no extra host work at all; the tracer records
        # at dispatch/observe boundaries — never inside jit-traced code —
        # and only the profiler's explicitly sampled dispatches fence
        self.tracer = NULL_TRACER if tracer is None else tracer
        self.profiler = NULL_PROFILER if profiler is None else profiler
        self._telemetry = self.tracer.enabled or self.profiler.enabled
        self.replica = replica
        self._cost_model = (
            DispatchCostModel(model.cfg) if self._telemetry else None
        )

        self._prefill = jax.jit(model.prefill)
        if cache_kind != "paged" and (kv_dtype != "bf16" or host_blocks):
            raise ValueError(
                "kv_dtype / host_blocks are paged-cache features "
                f"(cache_kind={cache_kind!r})"
            )
        self.kv_dtype = kv_dtype
        self.host_blocks = host_blocks
        if cache_kind == "paged":
            if model.paged_decode_step is None:
                raise ValueError(f"{model.cfg.family} has no paged decode path")
            if sub_batches != 1:
                raise NotImplementedError(
                    "paged cache does not compose with sub-batch pipelining yet"
                )
            self.block_size = block_size
            self.max_blocks = -(-max_seq // block_size)
            # default: same physical budget as the dense cache, + null block
            self.n_blocks = (
                n_slots * self.max_blocks + 1 if n_blocks is None else n_blocks
            )
            if self.n_blocks - 1 < self.max_blocks:
                raise ValueError(
                    f"pool of {self.n_blocks - 1} usable blocks cannot hold one "
                    f"max_seq={max_seq} sequence ({self.max_blocks} blocks)"
                )
            self.pool = BlockPool(self.n_blocks, block_size, host_blocks=host_blocks)
            self.manager = PagedCacheManager(self.pool, n_slots, self.max_blocks)
            self.cache = model.init_paged_cache(
                n_slots, self.n_blocks, block_size, self.max_blocks,
                kv_dtype=kv_dtype, host_blocks=host_blocks,
            )
            self._decode = jax.jit(model.paged_decode_step)
            if async_mode:
                if model.paged_decode_sample_step is not None:
                    self._decode_sampled = jax.jit(
                        model.paged_decode_sample_step, static_argnames=("sampler",)
                    )
                else:
                    self._decode_sampled = self._wrap_sampled(model.paged_decode_step)
        elif cache_kind == "dense":
            self.cache = model.init_cache(n_slots, max_seq)
            step = pipelined_step(model.decode_step, sub_batches)
            self._decode = jax.jit(step)
            if async_mode:
                if sub_batches == 1 and model.decode_sample_step is not None:
                    self._decode_sampled = jax.jit(
                        model.decode_sample_step, static_argnames=("sampler",)
                    )
                else:
                    self._decode_sampled = self._wrap_sampled(step)
        else:
            raise ValueError(f"unknown cache_kind {cache_kind!r}")

        # async pipeline state (allocated in both modes so shared helpers
        # like _prepare_append can test `self._pending` unconditionally)
        self._pending: deque[_PendingStep] = deque()
        self._first_pending: list[tuple[Request, jax.Array]] = []
        if async_mode:
            self._tok_state = jnp.zeros((n_slots,), jnp.int32)
            self._eos_dev = jnp.full((n_slots,), -1, jnp.int32)
            self._rng_zero = jax.random.key(0)
            self._jit_sample = jax.jit(sample_on_device, static_argnames=("cfg",))

        self.sched = Scheduler(
            n_slots=n_slots, max_seq=max_seq, mode=schedule,
            prefill_chunk=prefill_chunk, token_budget=token_budget,
            block_size=block_size if cache_kind == "paged" else None,
            spec_width=spec_depth + 1,
        )
        if schedule == "hybrid":
            self._init_hybrid(sub_batches)
        if spec_depth:
            # the draft cache is always dense: the draft model is small,
            # so one (n_slots, max_seq) stripe costs little, and its
            # lengths mirror the target's committed lengths slot-for-slot
            self.d_cache = draft_model.init_cache(n_slots, max_seq)
            self._draft_prefill = jax.jit(draft_model.prefill_step)
            self._init_spec()

    @staticmethod
    def _wrap_sampled(base_step):
        """Fuse on-device sampling onto a logits step (used when the
        family has no *_sample_step, or the step is sub-batch pipelined)."""

        def _sampled(params, cache, tokens, rng, eos_ids, *, sampler):
            logits, new_cache = base_step(params, cache, tokens)
            tok = sample_on_device(logits, rng, sampler)
            return tok, tok == eos_ids, new_cache

        return jax.jit(_sampled, static_argnames=("sampler",))

    def _init_hybrid(self, sub_batches: int) -> None:
        model = self.model
        if model.prefill_step is None:
            raise ValueError(
                f"{model.cfg.family} has no prefill_step: hybrid scheduling "
                "needs the chunked-prefill model entry point"
            )
        if model.cfg.kv_quant:
            raise NotImplementedError("hybrid schedule does not support kv_quant yet")
        if sub_batches != 1:
            raise NotImplementedError(
                "hybrid schedule does not compose with sub-batch pipelining yet"
            )
        # per-slot chunked-prefill state (set by _begin_prefill): the
        # pinned token stream, prefix-cache-hit block count, and (paged)
        # the staging lane — boundary packing keeps TWO prompts mid-flight
        # for one dispatch, so none of this can be a single global
        self._pf_tokens: dict[int, np.ndarray] = {}
        self._pf_prefix: dict[int, int] = {}
        self._pf_lane: dict[int, int] = {}
        sampler = self.sampler
        if self.cache_kind == "paged":
            # persistent staging cache (one fixed shape): chunks accumulate
            # here, completed blocks flush into the pool.  Two lanes
            # (batch 2) so a boundary-packed second prompt can stage its
            # chunks while the finishing prompt still owns its lane.
            self.staging = model.init_cache(2, self.max_blocks * self.block_size)

        if not self.async_mode:
            self._solo = jax.jit(model.prefill_step)
            if self.cache_kind == "paged":

                def _fused(params, cache, staging, dec_tokens, pre_tokens, lane, off, nv):
                    pre_logits, staging = model.prefill_step(
                        params, staging, pre_tokens, lane, off, nv
                    )
                    dec_logits, cache = model.paged_decode_step(params, cache, dec_tokens)
                    return dec_logits, pre_logits, cache, staging

                # boundary packing (Sarathi-SC), paged: prompt A's final
                # chunk and prompt B's head chunk stage in separate lanes
                # and ride one dispatch with the decode batch
                def _fused2(params, cache, staging, dec_tokens,
                            tokA, laneA, offA, nvA, tokB, laneB, offB, nvB):
                    la, staging = model.prefill_step(params, staging, tokA, laneA, offA, nvA)
                    lb, staging = model.prefill_step(params, staging, tokB, laneB, offB, nvB)
                    dec_logits, cache = model.paged_decode_step(params, cache, dec_tokens)
                    return dec_logits, la, lb, cache, staging

                def _solo2(params, staging, tokA, laneA, offA, nvA,
                           tokB, laneB, offB, nvB):
                    la, staging = model.prefill_step(params, staging, tokA, laneA, offA, nvA)
                    lb, staging = model.prefill_step(params, staging, tokB, laneB, offB, nvB)
                    return la, lb, staging

                self._fused2 = jax.jit(_fused2)
                self._solo2 = jax.jit(_solo2)
            else:

                def _fused(params, cache, dec_tokens, pre_tokens, slot, off, nv):
                    pre_logits, cache = model.prefill_step(
                        params, cache, pre_tokens, slot, off, nv
                    )
                    dec_logits, cache = model.decode_step(params, cache, dec_tokens)
                    # decode advanced every slot's length; the mid-prefill slot
                    # stays at its chunk end (its garbage append is overwritten
                    # by the next chunk / first decode token)
                    lengths = cache["lengths"].at[slot].set(off + nv)
                    return dec_logits, pre_logits, {**cache, "lengths": lengths}

                # boundary packing (Sarathi-SC): prompt A's final chunk and
                # prompt B's head chunk in ONE dispatch — both prefills ride
                # the same weight stream as the decode batch
                def _fused2(params, cache, dec_tokens, tokA, slotA, offA, nvA,
                            tokB, slotB, offB, nvB):
                    la, cache = model.prefill_step(params, cache, tokA, slotA, offA, nvA)
                    lb, cache = model.prefill_step(params, cache, tokB, slotB, offB, nvB)
                    dec_logits, cache = model.decode_step(params, cache, dec_tokens)
                    lengths = (cache["lengths"].at[slotA].set(offA + nvA)
                               .at[slotB].set(offB + nvB))
                    return dec_logits, la, lb, {**cache, "lengths": lengths}

                def _solo2(params, cache, tokA, slotA, offA, nvA,
                           tokB, slotB, offB, nvB):
                    la, cache = model.prefill_step(params, cache, tokA, slotA, offA, nvA)
                    lb, cache = model.prefill_step(params, cache, tokB, slotB, offB, nvB)
                    return la, lb, cache

                self._fused2 = jax.jit(_fused2)
                self._solo2 = jax.jit(_solo2)

            self._fused = jax.jit(_fused)
            return

        # ---- async closures: sampling fused, token state fed back on device.
        # The fused step returns sampled ids + EOS flags for the decode
        # batch and, on a prompt's final chunk, splices the chunk's first
        # generated token into tok_state at `slot` so the next decode step
        # consumes it without any host round-trip.
        if model.prefill_sample_step is not None:
            prefill_sample = model.prefill_sample_step
        else:
            def prefill_sample(params, cache, tokens, slot, off, nv, rng, *,
                               sampler):
                logits, cache = model.prefill_step(params, cache, tokens, slot, off, nv)
                return sample_on_device(logits, rng, sampler), cache

        if self.cache_kind == "paged":

            def _fused_async(params, cache, staging, tok_state, pre_tokens,
                             slot, lane, off, nv, rng, eos_ids, last):
                r_dec, r_pre = jax.random.split(rng)
                pre_logits, staging = model.prefill_step(
                    params, staging, pre_tokens, lane, off, nv
                )
                dec_logits, cache = model.paged_decode_step(params, cache, tok_state)
                toks = sample_on_device(dec_logits, r_dec, sampler)
                pre_tok = sample_on_device(pre_logits, r_pre, sampler)
                state = jnp.where(last, toks.at[slot].set(pre_tok[0]), toks)
                return state, toks, toks == eos_ids, pre_tok, cache, staging

            def _solo_async(params, staging, tok_state, pre_tokens,
                            slot, lane, off, nv, rng, last):
                pre_tok, staging = prefill_sample(
                    params, staging, pre_tokens, lane, off, nv, rng, sampler=sampler
                )
                state = jnp.where(last, tok_state.at[slot].set(pre_tok[0]), tok_state)
                return state, pre_tok, staging

            # boundary packing, paged async twins: two staging lanes, A
            # always completes (final by construction), B splices its
            # first token only when its head chunk is also its last
            def _fused2_async(params, cache, staging, tok_state,
                              tokA, slotA, laneA, offA, nvA,
                              tokB, slotB, laneB, offB, nvB,
                              rng, eos_ids, lastB):
                r_dec, r_a, r_b = jax.random.split(rng, 3)
                la, staging = model.prefill_step(params, staging, tokA, laneA, offA, nvA)
                lb, staging = model.prefill_step(params, staging, tokB, laneB, offB, nvB)
                dec_logits, cache = model.paged_decode_step(params, cache, tok_state)
                toks = sample_on_device(dec_logits, r_dec, sampler)
                ta = sample_on_device(la, r_a, sampler)
                tb = sample_on_device(lb, r_b, sampler)
                state = toks.at[slotA].set(ta[0])
                state = jnp.where(lastB, state.at[slotB].set(tb[0]), state)
                return state, toks, toks == eos_ids, ta, tb, cache, staging

            def _solo2_async(params, staging, tok_state,
                             tokA, slotA, laneA, offA, nvA,
                             tokB, slotB, laneB, offB, nvB, rng, lastB):
                r_a, r_b = jax.random.split(rng)
                la, staging = model.prefill_step(params, staging, tokA, laneA, offA, nvA)
                lb, staging = model.prefill_step(params, staging, tokB, laneB, offB, nvB)
                ta = sample_on_device(la, r_a, sampler)
                tb = sample_on_device(lb, r_b, sampler)
                state = tok_state.at[slotA].set(ta[0])
                state = jnp.where(lastB, state.at[slotB].set(tb[0]), state)
                return state, ta, tb, staging

            self._fused2 = jax.jit(_fused2_async)
            self._solo2 = jax.jit(_solo2_async)
        else:

            def _fused_async(params, cache, tok_state, pre_tokens,
                             slot, off, nv, rng, eos_ids, last):
                r_dec, r_pre = jax.random.split(rng)
                pre_logits, cache = model.prefill_step(
                    params, cache, pre_tokens, slot, off, nv
                )
                dec_logits, cache = model.decode_step(params, cache, tok_state)
                lengths = cache["lengths"].at[slot].set(off + nv)
                cache = {**cache, "lengths": lengths}
                toks = sample_on_device(dec_logits, r_dec, sampler)
                pre_tok = sample_on_device(pre_logits, r_pre, sampler)
                state = jnp.where(last, toks.at[slot].set(pre_tok[0]), toks)
                return state, toks, toks == eos_ids, pre_tok, cache

            def _solo_async(params, cache, tok_state, pre_tokens,
                            slot, off, nv, rng, last):
                pre_tok, cache = prefill_sample(
                    params, cache, pre_tokens, slot, off, nv, rng, sampler=sampler
                )
                state = jnp.where(last, tok_state.at[slot].set(pre_tok[0]), tok_state)
                return state, pre_tok, cache

            # boundary packing (Sarathi-SC), async twins: A always
            # completes (its chunk is final by construction), B's first
            # token splices only when its head chunk is also its last
            def _fused2_async(params, cache, tok_state, tokA, slotA, offA, nvA,
                              tokB, slotB, offB, nvB, rng, eos_ids, lastB):
                r_dec, r_a, r_b = jax.random.split(rng, 3)
                la, cache = model.prefill_step(params, cache, tokA, slotA, offA, nvA)
                lb, cache = model.prefill_step(params, cache, tokB, slotB, offB, nvB)
                dec_logits, cache = model.decode_step(params, cache, tok_state)
                lengths = (cache["lengths"].at[slotA].set(offA + nvA)
                           .at[slotB].set(offB + nvB))
                cache = {**cache, "lengths": lengths}
                toks = sample_on_device(dec_logits, r_dec, sampler)
                ta = sample_on_device(la, r_a, sampler)
                tb = sample_on_device(lb, r_b, sampler)
                state = toks.at[slotA].set(ta[0])
                state = jnp.where(lastB, state.at[slotB].set(tb[0]), state)
                return state, toks, toks == eos_ids, ta, tb, cache

            def _solo2_async(params, cache, tok_state, tokA, slotA, offA, nvA,
                             tokB, slotB, offB, nvB, rng, lastB):
                r_a, r_b = jax.random.split(rng)
                la, cache = model.prefill_step(params, cache, tokA, slotA, offA, nvA)
                lb, cache = model.prefill_step(params, cache, tokB, slotB, offB, nvB)
                ta = sample_on_device(la, r_a, sampler)
                tb = sample_on_device(lb, r_b, sampler)
                state = tok_state.at[slotA].set(ta[0])
                state = jnp.where(lastB, state.at[slotB].set(tb[0]), state)
                return state, ta, tb, cache

            self._fused2 = jax.jit(_fused2_async)
            self._solo2 = jax.jit(_solo2_async)

        self._fused = jax.jit(_fused_async)
        self._solo = jax.jit(_solo_async)

    # ------------------------------------------------- speculative decoding
    def _init_spec(self) -> None:
        """Build the jitted speculative programs (``spec_depth > 0``).

        ``spec_core`` is ONE device program per dispatch: k autoregressive
        draft decode+sample steps, one extra draft decode (so a fully
        accepted window leaves the draft cache holding every accepted
        position's K/V, including the last draft's), the target's
        (k+1)-position verify, rejection sampling, and both length
        commits.  The emitted token at ``n_accept`` becomes the next
        dispatch's ``tok_state`` entry without a host round-trip; the
        full ``(B, k+1)`` emitted array and the acceptance counts travel
        to the host lazily with the pipeline, like the non-speculative
        token/EOS arrays.
        """
        model, draft = self.model, self.draft_model
        k = self.spec_depth
        sampler = self.sampler
        d_decode = draft.decode_step
        verify = (model.paged_verify_step if self.cache_kind == "paged"
                  else model.verify_step)

        def spec_core(params, d_params, cache, d_cache, tok_state, rng):
            rngs = jax.random.split(rng, k + 1)
            tok = tok_state
            drafts, probs = [], []
            for j in range(k):
                d_logits, d_cache = d_decode(d_params, d_cache, tok)
                tok, p = spec_draft_sample(d_logits, rngs[j], sampler)
                drafts.append(tok)
                if p is not None:
                    probs.append(p)
            # write d_k's own K/V too: on full acceptance the next window
            # starts right after d_k, and its context must be complete
            _, d_cache = d_decode(d_params, d_cache, tok)
            tokens = jnp.stack([tok_state] + drafts, axis=1)      # (B, k+1)
            v_logits, cache = verify(params, cache, tokens)
            emitted, n_accept = spec_verify_tokens(
                v_logits,
                jnp.stack(drafts, axis=1),
                jnp.stack(probs, axis=1) if probs else None,
                rngs[k], sampler,
            )
            # KV rollback is just the commit: lengths advance only over
            # the accepted prefix + bonus token; rejected positions'
            # writes sit past the length and are causally invisible.  The
            # k+1 draft decodes advanced d_cache by k+1 — net it back to
            # the same n_accept+1 commit the target took.
            cache = {**cache, "lengths": cache["lengths"] + n_accept + 1}
            d_cache = {**d_cache,
                       "lengths": d_cache["lengths"] + n_accept - k}
            state = emitted[jnp.arange(emitted.shape[0]), n_accept]
            return state, emitted, n_accept, cache, d_cache

        self._spec_step = jax.jit(spec_core)
        if self.schedule != "hybrid":
            return
        if self.cache_kind == "paged":

            def _spec_fused(params, d_params, cache, staging, d_cache,
                            tok_state, pre_tokens, slot, lane, off, nv,
                            rng, last):
                r_pre, r_spec = jax.random.split(rng)
                pre_logits, staging = model.prefill_step(
                    params, staging, pre_tokens, lane, off, nv
                )
                state, emitted, n_accept, cache, d_cache = spec_core(
                    params, d_params, cache, d_cache, tok_state, r_spec
                )
                pre_tok = sample_on_device(pre_logits, r_pre, sampler)
                state = jnp.where(last, state.at[slot].set(pre_tok[0]), state)
                return (state, emitted, n_accept, pre_tok,
                        cache, staging, d_cache)
        else:

            def _spec_fused(params, d_params, cache, d_cache, tok_state,
                            pre_tokens, slot, off, nv, rng, last):
                r_pre, r_spec = jax.random.split(rng)
                pre_logits, cache = model.prefill_step(
                    params, cache, pre_tokens, slot, off, nv
                )
                state, emitted, n_accept, cache, d_cache = spec_core(
                    params, d_params, cache, d_cache, tok_state, r_spec
                )
                # the verify advanced every slot's length; the mid-prefill
                # slot stays at its chunk end (its garbage writes beyond
                # that are overwritten by the next chunk / first decode)
                lengths = cache["lengths"].at[slot].set(off + nv)
                cache = {**cache, "lengths": lengths}
                pre_tok = sample_on_device(pre_logits, r_pre, sampler)
                state = jnp.where(last, state.at[slot].set(pre_tok[0]), state)
                return state, emitted, n_accept, pre_tok, cache, d_cache

        self._spec_fused = jax.jit(_spec_fused)

    def _draft_prefill_slot(self, slot: int, tokens: np.ndarray) -> None:
        """Prefill ``tokens`` into the draft cache at ``slot`` so draft
        and target lengths agree at the next dispatch boundary.  Chunked
        at ``prefill_chunk`` (one compiled shape per bucket); runs at
        dispatch time — device data-flow orders it after every in-flight
        step's d_cache writes and before the slot's next speculative
        dispatch reads it."""
        if not self.spec_depth:
            return
        bucket = self.prefill_chunk
        wslot = np.int32(slot)
        for start in range(0, len(tokens), bucket):
            nv = min(bucket, len(tokens) - start)
            buf = np.zeros((1, bucket), np.int32)
            buf[0, :nv] = tokens[start:start + nv]
            _, self.d_cache = self._draft_prefill(
                self.draft_params, self.d_cache, jnp.asarray(buf),
                wslot, np.int32(start), np.int32(nv),
            )
            self.stats.draft_steps += 1

    # ------------------------------------------------------------- requests
    def submit(self, req: Request):
        if len(req.prompt) >= self.max_seq - 1:
            raise ValueError(
                f"prompt of {len(req.prompt)} tokens does not fit max_seq="
                f"{self.max_seq}: admission needs len(prompt) <= max_seq - 2 "
                "so the cache holds the prompt plus at least one generated "
                "token without overflowing mid-decode"
            )
        req.submit_step = self.stats.engine_steps
        self.sched.submit(req)
        self.tracer.on_submit(self.replica, req, req.submit_step)

    # ------------------------------------------------- cluster router hooks
    def load(self) -> EngineLoad:
        """Load snapshot for ``least_loaded`` routing (read-only).  A
        chunked prefill in flight (``sched.inflight``) is committed work
        on a reserved slot even though the request is in neither
        ``slots`` nor the queue yet — count both."""
        inflight = sum(
            len(r.prompt) + len(r.out_tokens) + r.in_flight
            for r in self.slots if r is not None
        )
        inflight += sum(len(r.prompt) + len(r.out_tokens)
                        for r in self.sched.queue)
        fl = self.sched.inflight
        if fl is not None:
            inflight += fl.total
        return EngineLoad(
            free_slots=self.slots.count(None) - (0 if fl is None else 1),
            queued=len(self.sched),
            inflight_tokens=inflight,
            free_blocks=(self.pool.free_count if self.cache_kind == "paged"
                         else None),
        )

    def can_admit(self, req: Request) -> bool:
        """Would ``req`` be this replica's *next* prefill?  The cluster
        router's spill-over probe: read-only and conservative (counts
        resident prefix hits but never blocks a preemption could free).
        A chunked prefill already in flight counts as running — its slot
        is subtracted and the newcomer starts right behind it, a bounded
        wait — but any locally *queued* request means an unbounded park,
        so the answer is no."""
        fl = self.sched.inflight
        free = self.slots.count(None) - (0 if fl is None else 1)
        if len(self.sched) or free < 1:
            return False
        if self.cache_kind != "paged":
            return True
        # a preempted request re-admits with its generated tokens folded
        # into the prefill, so the block bill covers prompt + output
        tokens = self._refold(req) if req.out_tokens else np.asarray(
            req.prompt, np.int32
        )
        return self.manager.admit_shortfall(tokens) <= self.pool.free_count

    def probe_prefix(self, prompt: np.ndarray) -> int:
        """Longest resident prompt prefix, in tokens (0 for the dense
        cache — it has no prefix reuse).  Side-effect free; the router's
        ``prefix_affinity`` score."""
        if self.cache_kind != "paged":
            return 0
        return self.manager.probe_prefix(np.asarray(prompt, np.int32))

    # ---------------------------------------------------- KV block migration
    def export_request(self, slot: int):
        """Detach the resident request on ``slot``, with its KV, for
        migration to a peer replica (the disaggregated prefill->decode
        handoff; also load leveling).

        Async mode observes the victim's in-flight tokens first
        (:meth:`_observe_victim`) so the exported history is exact — which
        may reveal the request already finished; then, or when the slot
        holds a cold host-tier prefix (only fully device-resident
        sequences migrate), the export is declined and ``None`` returned.

        Otherwise returns ``(req, ticket, payload)``: the request (its
        slot here is freed), a :class:`MigrationTicket`, and the
        storage-dtype KV payload (:func:`paged.device.copy_blocks_out` /
        :func:`kv_cache.export_slot`).  Shared-prefix blocks are
        **copy-on-export**: the peer copies the payload while this
        replica's remaining owners keep the physical block and its hash
        entry; a dying private registered prefix still free-time-spills
        to the host tier, so migrating a sequence away never cold-starts
        this replica's prefix cache.
        """
        req = self.slots[slot]
        if req is None or req.done:
            return None
        if self.async_mode:
            self._observe_victim(slot)
            req = self.slots[slot]
            if req is None or req.done:
                return None             # finished while observing
        if self.cache_kind == "paged" and self.manager.cold_blocks[slot]:
            return None
        length = len(req.prompt) + len(req.out_tokens) - 1
        if self.cache_kind == "paged":
            ids = list(self.manager.blocks[slot])
            payload = paged_dev.copy_blocks_out(self.cache, ids)
            _, keys = self.manager.export_slot(slot)
            # dying private prefixes may free-time-spill host-ward: apply
            # before the freed device blocks can be reallocated/rewritten
            self._apply_pool_directives()
            self.cache = paged_dev.sync_slot(
                self.cache, slot, self.manager.tables[slot], 0
            )
            ticket = MigrationTicket(
                length=length, kv_dtype=self.kv_dtype, keys=keys,
                n_blocks=len(ids), block_size=self.block_size,
                src_step=self.stats.engine_steps,
            )
        else:
            payload = kv_cache.export_slot(self.cache, slot)
            self.cache = kv_cache.reset_slot(self.cache, slot)
            ticket = MigrationTicket(
                length=length, kv_dtype=self.kv_dtype,
                src_step=self.stats.engine_steps,
            )
        self.slots[slot] = None
        self.stats.migrations_out += 1
        return req, ticket, payload

    def preview_export(self, slot: int) -> MigrationTicket | None:
        """Read-only ticket for what :meth:`export_request` would produce
        — the cluster probes destinations (:meth:`can_import`) *before*
        paying the export.  Exact: the manager's block/key lists already
        reflect every dispatched append, and observing the victim's
        in-flight tokens at export time only converts them to observed
        output (same KV length) or finishes the request (export declines).
        None when the slot is empty, done, or holds a cold host-tier
        prefix."""
        req = self.slots[slot]
        if req is None or req.done:
            return None
        length = len(req.prompt) + len(req.out_tokens) + req.in_flight - 1
        if self.cache_kind != "paged":
            return MigrationTicket(
                length=length, kv_dtype=self.kv_dtype,
                src_step=self.stats.engine_steps,
            )
        if self.manager.cold_blocks[slot]:
            return None
        return MigrationTicket(
            length=length, kv_dtype=self.kv_dtype,
            keys=list(self.manager.keys[slot]),
            n_blocks=len(self.manager.blocks[slot]),
            block_size=self.block_size,
            src_step=self.stats.engine_steps,
        )

    def can_import(self, ticket: MigrationTicket) -> bool:
        """Read-only: could :meth:`import_request` land ``ticket`` right
        now without touching anyone?  Conservative — the import itself
        can additionally free blocks via spill-before-evict when a host
        tier exists, but it never preempts, so the cluster probes here
        before paying the export."""
        if ticket.kv_dtype != self.kv_dtype or ticket.length >= self.max_seq - 1:
            return False
        if (ticket.keys is None) != (self.cache_kind != "paged"):
            return False
        if not self._free_slots():
            return False
        if self.cache_kind != "paged":
            return True
        if ticket.block_size != self.block_size:
            return False
        return (
            self.manager.import_shortfall(ticket.keys, ticket.length)
            <= self.pool.free_count
        )

    def import_request(self, req: Request, ticket: MigrationTicket,
                       payload) -> int | None:
        """Land a migrating request: allocate/dedup blocks
        (:meth:`BlockPool.import_blocks`), scatter the payload columns the
        local prefix cache does not already hold, and resume decode with
        the same next-input token over the same KV — greedy output is
        token-identical to never having migrated.  Under block pressure
        with a host tier, resident cold prefixes spill host-ward
        (spill-before-evict) rather than preempting anyone.  Returns the
        landing slot, or ``None`` — nothing mutated — when capacity cannot
        be found."""
        if ticket.kv_dtype != self.kv_dtype:
            return None
        free = self._free_slots()
        if not free:
            return None
        slot = free[0]
        if self.cache_kind == "paged":
            fresh = self.manager.import_shortfall(ticket.keys, ticket.length)
            if fresh > self.pool.free_count:
                if not self.pool.host_blocks:
                    return None
                alive = [i for i, s in enumerate(self.slots) if s is not None]
                while fresh > self.pool.free_count and self._try_spill(alive):
                    pass
                if fresh > self.pool.free_count:
                    return None
            res = self.manager.import_slot(slot, ticket.keys, ticket.length)
            if res is None:
                return None
            ids, needs = res
            # copy only the payload columns the local prefix cache did not
            # already hold (a trailing headroom block has no payload column)
            sel = [j for j in range(ticket.n_blocks) if needs[j]]
            if sel:
                self.cache = paged_dev.copy_blocks_in(
                    self.cache, self._localize(payload), sel,
                    [ids[j] for j in sel],
                )
            self.cache = paged_dev.sync_slot(
                self.cache, slot, self.manager.tables[slot], ticket.length
            )
        else:
            self.cache = kv_cache.insert(self.cache, self._localize(payload), slot)
        self.slots[slot] = req
        # translate decode-latency accounting onto this engine's step
        # clock (finish_step will be stamped here; the elapsed decode
        # steps already spent on the source carry over)
        if req.first_token_step >= 0:
            req.first_token_step = (
                self.stats.engine_steps - (ticket.src_step - req.first_token_step)
            )
        if self.async_mode:
            # resume the device-side token feedback: the last sampled
            # token is the next decode input, exactly as on the source
            self._tok_state = paged_dev.feed_token(
                self._tok_state, slot, int(req.out_tokens[-1])
            )
            self._eos_dev = paged_dev.set_stop_id(self._eos_dev, slot, req.eos_id)
            # the draft cache did not travel: rebuild it from the history
            # (everything but the next-input token, matching the target's
            # imported KV length exactly)
            self._draft_prefill_slot(slot, self._refold(req)[:-1])
        self.stats.migrations_in += 1
        return slot

    def _localize(self, payload: Pytree) -> Pytree:
        """Move a migration payload onto this engine's device (no-op when
        source and destination share one, e.g. single-host CPU runs;
        multi-device *sharded* pools would need a resharding transfer and
        are out of scope for migration)."""
        anchor = self.cache["lengths"]
        devs = anchor.devices() if hasattr(anchor, "devices") else set()
        if len(devs) == 1:
            (dev,) = devs
            return jax.tree.map(lambda a: jax.device_put(a, dev), payload)
        return payload

    # ---------------------------------------------- cluster refold leveling
    def can_admit_next(self) -> bool:
        """Will this engine's *own* queue head be admittable at the next
        step?  (:meth:`can_admit` answers for a *foreign* request and says
        no whenever anything is queued locally — this is the home-replica
        mirror the cluster consults before moving a preempted request's
        refold to a less-loaded replica.)"""
        if not len(self.sched):
            return False
        fl = self.sched.inflight
        if self.slots.count(None) - (0 if fl is None else 1) < 1:
            return False
        if self.cache_kind != "paged":
            return True
        head = self.sched.queue[0]
        tokens = self._refold(head) if head.out_tokens else np.asarray(
            head.prompt, np.int32
        )
        return self.manager.admit_shortfall(tokens) <= self.pool.free_count

    def take_refold(self) -> Request | None:
        """Pop this engine's queue head if it is a preempted (refolding)
        request the cluster wants to re-place elsewhere; None otherwise."""
        q = self.sched.queue
        if q and q[0].out_tokens and not q[0].done:
            return self.sched.pop()
        return None

    def adopt_refold(self, req: Request) -> None:
        """Accept a refolding request moved from another replica.  It
        keeps queue-front priority (it has already waited out a
        preemption) and re-enters on this engine's step clock."""
        req.submit_step = self.stats.engine_steps
        self.sched.push_front(req)

    def _free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def _next_rng(self) -> jax.Array:
        self.rng, sub = jax.random.split(self.rng)
        return sub

    def _step_rng(self) -> jax.Array:
        """Per-dispatch rng for the async path.  Greedy never consumes
        randomness, so skip the per-step host-side key split entirely."""
        if self.sampler.temperature <= 0.0:
            return self._rng_zero
        return self._next_rng()

    @staticmethod
    def _refold(req: Request) -> np.ndarray:
        """Prompt plus already-generated tokens: prefilling this exactly
        reproduces a preempted request's decode state (greedy-exact)."""
        assert req.in_flight == 0 and req.in_flight_steps == 0, (
            "refold needs every dispatched token observed"
        )
        return np.concatenate(
            [np.asarray(req.prompt, np.int32),
             np.asarray(req.out_tokens, np.int32)]
        )

    # --------------------------------------------- async pipeline primitives
    def _predicted_done(self, req: Request) -> bool:
        """Will the sync engine have marked ``req`` done once every
        dispatched token is observed?  Mirrors ``_finish_decode``'s check
        exactly: the first token after a (re-)admission comes from a
        prefill sample and is never length-checked, so a request is only
        predicted done once a *decode* token can trip the condition.

        Speculation: ``in_flight_steps`` is the guaranteed-commit floor
        (each dispatched window commits at least its bonus token), so a
        predicted-done here is certain — the engine never pauses a live
        slot whose device rows later dispatches would keep mutating.
        Extra tokens a window commits beyond the floor only finish the
        request *earlier*; the surplus dispatches are masked at observe
        exactly like the one-step EOS lag.
        """
        c = len(req.out_tokens) + req.in_flight_steps
        if c < req.admit_base + 2:
            return False
        return (c >= req.max_new_tokens
                or len(req.prompt) + c >= self.max_seq - 1)

    def _predicted_active(self) -> list[int]:
        if not self.async_mode:
            return [i for i, s in enumerate(self.slots) if s is not None]
        return [i for i, s in enumerate(self.slots)
                if s is not None and not self._predicted_done(s)]

    def _dispatch(self, rec: _PendingStep) -> None:
        """Queue a dispatched step; observe the previous one *after* the
        new one is in flight (the dispatch-ahead overlap).  A sync-mode
        speculative engine runs the same pipeline at depth zero: observe
        immediately after dispatch."""
        self._pending.append(rec)
        if self._sync_pipeline:
            self._drain()
            return
        if len(self._pending) > 1:
            self._observe(self._pending.popleft())

    def _flush_first(self) -> None:
        for req, tok in self._first_pending:
            req.in_flight -= 1
            req.in_flight_steps -= 1
            req.out_tokens.append(int(np.asarray(tok)[0]))
        self._first_pending.clear()

    def _observe(self, rec: _PendingStep) -> None:
        """Fetch one step's token/EOS arrays and apply completions.

        This is the only place the async engine blocks on the device, and
        by construction a newer step is already queued behind the one
        being fetched.  EOS retirements discovered here are one step
        late: the speculative token a later in-flight step sampled for a
        now-done request is masked (``req.done`` short-circuit below)."""
        self._flush_first()
        if rec.work is not None and rec.work.last:
            req = rec.work.req
            req.in_flight -= 1
            req.in_flight_steps -= 1
            req.out_tokens.append(int(np.asarray(rec.pre_tok)[0]))
        if rec.work2 is not None and rec.work2.last:
            req = rec.work2.req
            req.in_flight -= 1
            req.in_flight_steps -= 1
            req.out_tokens.append(int(np.asarray(rec.pre_tok2)[0]))
        if rec.tokens is None:
            return
        toks = np.asarray(rec.tokens)
        if rec.n_accept is not None:
            self._observe_spec(rec, toks)
            return
        eos = np.asarray(rec.eos)
        for i, req in rec.reqs.items():
            req.in_flight -= 1
            req.in_flight_steps -= 1
            if req.done:
                continue            # speculative token past EOS: masked
            tok = int(toks[i])
            req.out_tokens.append(tok)
            self.stats.generated += 1
            length = len(req.prompt) + len(req.out_tokens)
            if (
                bool(eos[i])
                or len(req.out_tokens) >= req.max_new_tokens
                or length >= self.max_seq - 1
            ):
                self._finish(i, req, rec.step)

    def _observe_spec(self, rec: _PendingStep, toks: np.ndarray) -> None:
        """Apply one observed speculative window: per batch row, commit
        the accepted drafts plus the bonus/correction token (``toks[i]``
        holds ``n_accept[i] + 1`` valid leading positions), refund the
        unused in-flight charges, and stop at the first finish condition
        — an EOS *inside* the accepted window truncates the rest."""
        n_acc = np.asarray(rec.n_accept)
        accepted = 0
        for i, req in rec.reqs.items():
            req.in_flight -= rec.charge
            req.in_flight_steps -= 1
            if req.done:
                continue            # window dispatched past EOS: masked
            n_emit = int(n_acc[i]) + 1
            accepted += n_emit - 1
            self.stats.drafted_tokens += self.spec_depth
            self.stats.accepted_tokens += n_emit - 1
            self.stats.spec_accept_samples.append(
                (n_emit - 1) / self.spec_depth
            )
            self._apply_spec_row(i, req, toks[i], n_emit, rec.step)
        if self.tracer.enabled:
            self.tracer.on_spec_verify(self.replica, rec.step, accepted,
                                       len(rec.reqs))

    def _apply_spec_row(self, slot: int, req: Request, row: np.ndarray,
                        n_emit: int, step: int) -> None:
        """Commit one slot's emitted tokens in stream order, applying the
        sync engine's finish conditions after each — identical to
        observing ``n_emit`` consecutive non-speculative steps."""
        for t in range(n_emit):
            tok = int(row[t])
            req.out_tokens.append(tok)
            self.stats.generated += 1
            length = len(req.prompt) + len(req.out_tokens)
            if (
                tok == req.eos_id
                or len(req.out_tokens) >= req.max_new_tokens
                or length >= self.max_seq - 1
            ):
                self._finish(slot, req, step)
                break

    def _drain(self) -> None:
        """Observe every in-flight step (pipeline empties; ``out_tokens``
        and ``in_flight`` become exact)."""
        while self._pending:
            self._observe(self._pending.popleft())
        self._flush_first()

    def _observe_victim(self, slot: int) -> None:
        """Observe only ``slot``'s in-flight tokens, in dispatch order,
        leaving every other slot's tokens (and the pending records
        themselves) in flight — the preemption refold needs *one* slot's
        exact history, so the rest of the pipeline stays overlapped
        instead of paying a full drain.  The victim's entries are
        consumed out of each record (``reqs``/``work`` cleared) so a
        later :meth:`_observe` of the same record skips them.  No-op when
        nothing of the victim's is in flight (sync mode always)."""
        req = self.slots[slot]
        if req is None or req.in_flight == 0:
            return
        self.stats.victim_drains += 1
        kept = []
        for r, tok in self._first_pending:
            if r is req:
                r.in_flight -= 1
                r.in_flight_steps -= 1
                r.out_tokens.append(int(np.asarray(tok)[0]))
            else:
                kept.append((r, tok))
        self._first_pending[:] = kept
        for rec in self._pending:
            if rec.work is not None and rec.work.last and rec.work.req is req:
                req.in_flight -= 1
                req.in_flight_steps -= 1
                req.out_tokens.append(int(np.asarray(rec.pre_tok)[0]))
                rec.work = None          # consumed; _observe must not re-apply
            if rec.work2 is not None and rec.work2.last and rec.work2.req is req:
                req.in_flight -= 1
                req.in_flight_steps -= 1
                req.out_tokens.append(int(np.asarray(rec.pre_tok2)[0]))
                rec.work2 = None
            if rec.tokens is not None and rec.reqs.get(slot) is req:
                del rec.reqs[slot]
                req.in_flight -= rec.charge
                req.in_flight_steps -= 1
                if req.done:
                    continue
                if rec.n_accept is not None:
                    n_emit = int(np.asarray(rec.n_accept)[slot]) + 1
                    self.stats.drafted_tokens += self.spec_depth
                    self.stats.accepted_tokens += n_emit - 1
                    self.stats.spec_accept_samples.append(
                        (n_emit - 1) / self.spec_depth
                    )
                    self._apply_spec_row(
                        slot, req, np.asarray(rec.tokens[slot]), n_emit,
                        rec.step,
                    )
                    continue
                req.out_tokens.append(int(np.asarray(rec.tokens[slot])))
                self.stats.generated += 1
                length = len(req.prompt) + len(req.out_tokens)
                if (
                    bool(np.asarray(rec.eos[slot]))
                    or len(req.out_tokens) >= req.max_new_tokens
                    or length >= self.max_seq - 1
                ):
                    self._finish(slot, req, rec.step)
        assert req.in_flight == 0 and req.in_flight_steps == 0, (
            "victim drain left tokens in flight"
        )

    def _finish(self, slot: int, req: Request, step: int) -> None:
        """Retire a completed request: stats samples, trace, slot release.
        ``step`` is the engine-step clock value the finishing token was
        *dispatched* at (the async observe paths pass the pending
        record's stamp, keeping the clock identical to sync mode)."""
        req.done = True
        req.finish_step = step
        n_decode_tokens = len(req.out_tokens) - 1
        if n_decode_tokens > 0 and req.first_token_step >= 0:
            self.stats.per_token_samples.append(
                (req.finish_step - req.first_token_step) / n_decode_tokens
            )
        self.tracer.on_finish(self.replica, req, step, slot)
        self._release_slot(slot, req)

    def _release_slot(self, slot: int, req: Request) -> None:
        if self.slots[slot] is not req:
            return                  # slot already recycled past this record
        self.slots[slot] = None
        if self.cache_kind == "paged":
            self.manager.free_slot(slot)
            # dying registered blocks may spill host-ward: copy before
            # the freed device blocks can be reallocated and rewritten
            self._apply_pool_directives()
            self.cache = paged_dev.sync_slot(
                self.cache, slot, self.manager.tables[slot], 0
            )
        else:
            self.cache = kv_cache.reset_slot(self.cache, slot)

    # ------------------------------------------- admission (whole-prefill)
    def _prefill_cost(self, n_tokens: int) -> int:
        """Whole-prefill step cost, in fixed hybrid-batch units."""
        return max(1, -(-n_tokens // self.prefill_chunk))

    def _admit(self):
        if self.cache_kind == "paged":
            self._admit_paged()
            return
        for slot in self._free_slots():
            if not len(self.sched):
                break
            req = self.sched.pop()
            step0 = self.stats.engine_steps
            self.stats.engine_steps += self._prefill_cost(len(req.prompt))
            if req.admit_step < 0:
                req.admit_step = self.stats.engine_steps
            self.tracer.on_admit(self.replica, req, step0, slot,
                                 n_tokens=len(req.prompt))
            self.tracer.on_chunk(self.replica, req, slot, step0,
                                 self.stats.engine_steps, 0,
                                 len(req.prompt), None, True)
            if self.tracer.enabled:
                self._trace_prefill_dispatch(len(req.prompt),
                                             self.stats.engine_steps - step0)
            prompt = jnp.asarray(req.prompt, jnp.int32)[None]
            sub_cache = self.model.init_cache(1, self.max_seq)
            logits, sub_cache = self._prefill(self.params, prompt, sub_cache)
            self.cache = kv_cache.insert(self.cache, sub_cache, slot)
            self.slots[slot] = req
            self._draft_prefill_slot(slot, np.asarray(req.prompt, np.int32))
            self._sample_prefill(req, slot, logits)

    def _admit_paged(self):
        """Admit while slots AND blocks allow; head-of-line blocks wait.

        A preempted request re-enters here with its generated tokens
        folded into the prefill, reproducing its exact decode state.
        """
        for slot in self._free_slots():
            if not len(self.sched):
                break
            req = self.sched.peek()
            full = self._refold(req)
            # the last sampled token is input, not cache content: the KV
            # written at admission covers full[:-1]'s context plus itself,
            # i.e. exactly len(full) positions after prefill
            res = self.manager.try_admit(slot, full)
            if res is None:
                break                       # out of blocks: wait/FCFS
            self.sched.pop()
            step0 = self.stats.engine_steps
            self.stats.engine_steps += self._prefill_cost(len(full))
            if req.admit_step < 0:
                req.admit_step = self.stats.engine_steps
            self.tracer.on_admit(self.replica, req, step0, slot,
                                 n_tokens=len(full),
                                 refold=bool(req.out_tokens))
            self.tracer.on_chunk(self.replica, req, slot, step0,
                                 self.stats.engine_steps, 0, len(full),
                                 None, True)
            if self.tracer.enabled:
                self._trace_prefill_dispatch(len(full),
                                             self.stats.engine_steps - step0)
            blocks, n_cached = res
            # host-tier prefix hits re-hydrate: apply the copies before
            # the prefill's own block writes go out
            self._apply_pool_directives()
            pad = -(-len(full) // self.block_size) * self.block_size
            sub_cache = self.model.init_cache(1, pad)
            logits, sub_cache = self._prefill(
                self.params, jnp.asarray(full, jnp.int32)[None], sub_cache
            )
            # fill only the blocks the prefix cache didn't already hold
            for j in range(n_cached, len(blocks)):
                self.cache = paged_dev.write_prompt_block(
                    self.cache, sub_cache, blocks[j], j * self.block_size
                )
            self.cache = paged_dev.sync_slot(
                self.cache, slot, self.manager.tables[slot], len(full)
            )
            self.slots[slot] = req
            self._draft_prefill_slot(slot, full)
            self._sample_prefill(req, slot, logits)

    def _sample_prefill(self, req: Request, slot: int, logits):
        req.admit_base = len(req.out_tokens)
        if self.async_mode:
            # sample on device, feed the token into tok_state for the next
            # decode step, and fetch the id lazily with the step stream —
            # the host never blocks on the prefill here
            tok = self._jit_sample(logits, self._step_rng(), cfg=self.sampler)
            self._tok_state = paged_dev.feed_token(self._tok_state, slot, tok[0])
            self._eos_dev = paged_dev.set_stop_id(self._eos_dev, slot, req.eos_id)
            req.in_flight += 1
            req.in_flight_steps += 1
            self._first_pending.append((req, tok))
        else:
            req.out_tokens.append(int(sample(logits, self._next_rng(), self.sampler)[0]))
        self._record_first_token(req, slot)

    def _record_first_token(self, req: Request, slot: int) -> None:
        """Shared prefill-completion accounting (sync and async paths)."""
        first = req.first_token_step < 0
        if first:
            req.first_token_step = self.stats.engine_steps
            ttft = req.first_token_step - req.submit_step
            self.stats.ttft_steps_sum += ttft
            self.stats.ttft_count += 1
            self.stats.ttft_samples.append(ttft)
        self.stats.prefills += 1
        self.stats.generated += 1
        self.tracer.on_first_token(self.replica, req, self.stats.engine_steps,
                                   slot, first=first)

    # --------------------------------------------- admission (chunked/hybrid)
    def _begin_prefill(self, req: Request, slot: int) -> tuple[int, int]:
        """Pin ``req``'s (possibly re-folded) prompt for chunked prefill;
        returns (first chunk position, total tokens)."""
        full = self._refold(req)
        self._pf_tokens[slot] = full
        if self.cache_kind != "paged":
            self._pf_prefix[slot] = 0
            return 0, len(full)
        bs = self.block_size
        # claim a free staging lane (at most two prompts mid-flight: the
        # boundary-packed newcomer takes whichever lane the finishing
        # prompt does not hold)
        lane = 0 if 0 not in self._pf_lane.values() else 1
        self._pf_lane[slot] = lane
        matched = self.manager.begin_chunked(slot, full)
        # host-tier hits re-hydrate into fresh device blocks: the copies
        # must land before the staging reads below consume them
        self._apply_pool_directives()
        self._pf_prefix[slot] = len(matched)
        for j, phys in enumerate(matched):
            self.staging = paged_dev.read_block(
                self.staging, self.cache, phys, j * bs, lane
            )
        # a fully prefix-cached prompt still recomputes its last chunk for
        # the first-token logits (pool writes for matched blocks skip)
        start = min(len(matched) * bs, (len(full) - 1) // bs * bs)
        return start, len(full)

    def _complete_chunk(self, work: PrefillChunk, pre_logits,
                        advance: bool = True):
        """Commit an executed chunk (sync mode: host-samples the first
        token from the chunk's logits when it completes the prompt).
        ``advance=False`` when the scheduler was already advanced at
        boundary-packing time (the next prompt had to begin before the
        fused dispatch was built)."""
        self.tracer.on_chunk(self.replica, work.req, work.slot,
                             self.stats.engine_steps - 1,
                             self.stats.engine_steps, work.start,
                             work.n_valid, work.bucket, work.last)
        self._flush_chunk_blocks(work)
        if advance:
            self.sched.advance(work)
        if work.last:
            req = work.req
            self.slots[work.slot] = req
            if self.cache_kind == "paged":
                self.cache = paged_dev.sync_slot(
                    self.cache, work.slot, self.manager.tables[work.slot],
                    work.start + work.n_valid,
                )
            self._end_prefill(work.slot)
            self._sample_prefill(req, work.slot, pre_logits)

    def _complete_chunk_async(self, work: PrefillChunk, advance: bool = True):
        """Async twin of :meth:`_complete_chunk`: the fused step already
        sampled the first token on device and spliced it into
        ``tok_state``; the host only does block/table bookkeeping (safe at
        dispatch time — device data-flow orders it after the step) and
        records that one more token is in flight."""
        self.tracer.on_chunk(self.replica, work.req, work.slot,
                             self.stats.engine_steps - 1,
                             self.stats.engine_steps, work.start,
                             work.n_valid, work.bucket, work.last)
        self._flush_chunk_blocks(work)
        if advance:
            self.sched.advance(work)
        if work.last:
            req = work.req
            self.slots[work.slot] = req
            if self.cache_kind == "paged":
                self.cache = paged_dev.sync_slot(
                    self.cache, work.slot, self.manager.tables[work.slot],
                    work.start + work.n_valid,
                )
            self._draft_prefill_slot(work.slot, self._pf_tokens[work.slot])
            self._end_prefill(work.slot)
            req.admit_base = len(req.out_tokens)
            req.in_flight += 1
            req.in_flight_steps += 1
            self._eos_dev = paged_dev.set_stop_id(
                self._eos_dev, work.slot, req.eos_id
            )
            self._record_first_token(req, work.slot)

    def _end_prefill(self, slot: int) -> None:
        """Release a completed prompt's per-slot prefill state (and its
        staging lane, for the paged cache)."""
        self._pf_tokens.pop(slot, None)
        self._pf_prefix.pop(slot, None)
        self._pf_lane.pop(slot, None)

    def _flush_chunk_blocks(self, work: PrefillChunk) -> None:
        if self.cache_kind != "paged":
            return
        bs = self.block_size
        lane = self._pf_lane.get(work.slot, 0)
        end = work.start + work.n_valid
        for j in range(work.start // bs, (end - 1) // bs + 1):
            if j < self._pf_prefix.get(work.slot, 0):
                continue            # prefix-cache hit: already valid
            self.cache = paged_dev.write_prompt_block(
                self.cache, self.staging, self.manager.blocks[work.slot][j],
                j * bs, lane,
            )

    # ----------------------------------------------------- block management
    def _apply_pool_directives(self) -> None:
        """Drain the pool's pending device<->host copy directives into
        actual device ops.  Must run after every manager/pool call that
        can spill or re-hydrate, *before* any subsequent write could
        clobber an involved block — device data-flow ordering then makes
        the copy land ahead of later cache updates, because every op
        threads ``self.cache``."""
        for kind, a, b in self.pool.drain_directives():
            if kind == "spill":
                self.cache = paged_dev.spill_block(self.cache, a, b)
                self.stats.spills += 1
                self.tracer.on_spill(self.replica, self.stats.engine_steps, a, b)
            else:
                self.cache = paged_dev.rehydrate_block(self.cache, a, b)
                self.stats.rehydrations += 1
                self.tracer.on_rehydrate(self.replica, self.stats.engine_steps, a, b)

    def _try_spill(self, alive) -> bool:
        """Spill-before-evict: free one device block by moving the oldest
        sequence's coldest hot block to the host tier.  The sequence
        keeps decoding (hybrid hot/cold attention, LSE-merged) — no
        re-prefill, unlike preemption.  Returns False when nothing can
        spill (no qualifying block, or host tier saturated)."""
        for s in sorted(alive, key=lambda x: self.manager.admit_seq[x]):
            if self.slots[s] is None:
                continue
            if self.manager.spill_live_prefix(s, self._kv_len(s)):
                self._apply_pool_directives()
                self.cache = paged_dev.sync_slot(
                    self.cache, s, self.manager.tables[s]
                )
                self.cache = paged_dev.sync_host_slot(
                    self.cache, s, self.manager.host_tables[s],
                    self.manager.cold_len(s),
                )
                return True
        return False

    def _kv_len(self, slot: int) -> int:
        """KV positions held for ``slot`` (last sampled token not yet
        appended — it is this step's input).  Counts in-flight tokens:
        the async engine plans appends from dispatched, not observed,
        state.  Under speculation the charges are an upper bound on the
        commits, so this over- rather than under-states the device
        length — safe for spill/export sizing."""
        req = self.slots[slot]
        return len(req.prompt) + len(req.out_tokens) + req.in_flight - 1

    def _append_span(self, slot: int) -> tuple[int, int]:
        """Inclusive position range [lo, hi] the slot's next dispatch may
        write.  With in-flight speculative windows the device length is
        only known to lie in [committed + steps, committed + charges];
        the next window then writes up to ``spec_depth`` positions past
        its start, so every position through hi needs a mapped block.
        Without speculation lo == hi == :meth:`_kv_len` — the single
        append position of the original code."""
        req = self.slots[slot]
        base = len(req.prompt) + len(req.out_tokens)
        lo = base + req.in_flight_steps - 1
        hi = base + req.in_flight - 1 + self.spec_depth
        return lo, hi

    def _preempt(self, slot: int):
        """Evict ``slot`` to the queue front; blocks return to the pool.
        Its tokens are preserved and recomputed at re-admission."""
        req = self.slots[slot]
        self.slots[slot] = None
        self.manager.free_slot(slot)
        self._apply_pool_directives()
        self.cache = paged_dev.sync_slot(
            self.cache, slot, self.manager.tables[slot], 0
        )
        self.sched.push_front(req)
        self.stats.preemptions += 1
        self.pool.stats.preemptions += 1
        self.tracer.on_preempt(self.replica, req, self.stats.engine_steps, slot)

    def _prepare_append(self, active: list[int]) -> list[int]:
        """Guarantee every active slot can write its next dispatch's
        token span (one position, or up to ``spec_depth + 1`` per
        in-flight window under speculation — see :meth:`_append_span`):
        allocate boundary blocks, copy-on-write shared tails, preempt the
        youngest sequence when the pool runs dry.  Returns the surviving
        slots.

        Async: a preemption decision snapshots ``out_tokens`` for exact
        recovery, but only the *victim's* history has to be exact — so
        its in-flight tokens are observed first (:meth:`_observe_victim`)
        while every other slot's stay in flight and the pipeline keeps
        its overlap.  The observed tokens may reveal the victim already
        finished (EOS lags one step): then its blocks are free and no
        eviction is needed.  Only when the victim is genuinely alive is
        the rest of the pipeline drained before evicting — an unobserved
        EOS on *another* slot may free enough blocks to avoid the
        preemption entirely, and one settled iteration is far cheaper
        than re-prefilling the victim's whole KV."""
        alive = set(active)
        limit = self.max_blocks * self.block_size
        for slot in sorted(active, key=lambda s: self.manager.admit_seq[s]):
            pos = None
            while slot in alive:
                if self.slots[slot] is None:
                    alive.discard(slot)     # retired during a drain below
                    break
                # a drain below can move the span: observed commits raise
                # lo (each step commits at least one token) and shrink hi
                # (unused charges refund), so pos only ever moves forward
                lo, hi = self._append_span(slot)
                if pos is None or pos < lo:
                    pos = lo
                if pos > hi or pos >= limit:
                    break       # span mapped (or clamped at the cache top:
                                # writes past it are dropped/masked on device)
                directive, payload = self.manager.ensure_append(slot, pos)
                if directive == "oom":
                    if self.pool.host_blocks and self._try_spill(alive):
                        continue    # freed a block without evicting anyone
                    victim = self.manager.youngest(alive)
                    self._observe_victim(victim)
                    if self.slots[victim] is None:
                        alive.discard(victim)   # finished: blocks already free
                        continue                # retry without evicting
                    if self._pending or self._first_pending:
                        self._drain()       # settle completions elsewhere
                        alive = {s for s in alive if self.slots[s] is not None}
                        continue            # retry before paying a re-prefill
                    self._preempt(victim)
                    alive.discard(victim)
                    continue                # retry (unless we evicted slot)
                if directive == "cow":
                    src, dst = payload
                    self.cache = paged_dev.copy_block(self.cache, src, dst)
                if directive in ("cow", "new"):
                    self.cache = paged_dev.sync_slot(
                        self.cache, slot, self.manager.tables[slot]
                    )
                pos += 1
        return [s for s in active if s in alive]

    # ------------------------------------------- boundary packing (Sarathi-SC)
    def _chunk_arrays(self, work: PrefillChunk):
        chunk = np.zeros((1, work.bucket), np.int32)
        chunk[0, :work.n_valid] = self._pf_tokens[work.slot][
            work.start:work.start + work.n_valid
        ]
        return jnp.asarray(chunk), np.int32(work.start), np.int32(work.n_valid)

    def _boundary_chunk(self, budget: int, taken: int) -> PrefillChunk | None:
        """The final chunk of the prompt on slot ``taken`` was advanced
        and left ``budget`` tokens of this iteration's dispatch unused:
        begin the next queued prompt and pack its head chunk into the
        *same* dispatch (Sarathi-SC boundary packing — both chunks ride
        one weight stream via ``_fused2``/``_solo2``), so the token
        budget stays full across prompt boundaries.  The paged cache
        stages the newcomer's chunks in the second staging lane.
        ``taken`` is excluded from the slot choice — the finishing
        prompt claims it only after this dispatch completes."""
        sched = self.sched
        if budget <= 0 or sched.inflight is not None or not len(sched):
            return None
        if self.cache_kind == "paged" and len(self._pf_lane) >= 2:
            return None             # both staging lanes held
        free = [s for s in self._free_slots() if s != taken]
        if not free:
            return None
        req = sched.pop()
        slot = free[0]
        start, total = self._begin_prefill(req, slot)
        sched.begin(req, slot, start, total)
        if req.admit_step < 0:
            req.admit_step = self.stats.engine_steps
        self.tracer.on_admit(self.replica, req, self.stats.engine_steps,
                             slot, n_tokens=total,
                             refold=bool(req.out_tokens))
        work2 = sched.pack_boundary(budget)
        if work2 is not None and self.cache_kind == "paged":
            ok = self.manager.extend_chunked(
                work2.slot, len(self._pf_tokens[work2.slot]),
                work2.start + work2.n_valid, work2.last,
            )
            if not ok:
                return None         # pool dry now: B's chunks run later
        return work2

    def _exec_solo_sync(self, work: PrefillChunk):
        """Dispatch one chunk through the solo prefill program (sync
        mode); returns the chunk's logits."""
        chunk, off, nv = self._chunk_arrays(work)
        if self.cache_kind == "paged":
            pre_logits, self.staging = self._solo(
                self.params, self.staging, chunk,
                np.int32(self._pf_lane.get(work.slot, 0)), off, nv
            )
        else:
            pre_logits, self.cache = self._solo(
                self.params, self.cache, chunk, np.int32(work.slot), off, nv
            )
        return pre_logits

    def _exec_solo_async(self, work: PrefillChunk, rng):
        """Async twin of :meth:`_exec_solo_sync`: the solo program samples
        on device and splices a completed prompt's first token into
        ``tok_state``; returns the in-flight ``pre_tok`` array."""
        chunk, off, nv = self._chunk_arrays(work)
        wslot = np.int32(work.slot)
        if self.cache_kind == "paged":
            self._tok_state, pre_tok, self.staging = self._solo(
                self.params, self.staging, self._tok_state, chunk, wslot,
                np.int32(self._pf_lane.get(work.slot, 0)), off, nv, rng,
                work.last,
            )
        else:
            self._tok_state, pre_tok, self.cache = self._solo(
                self.params, self.cache, self._tok_state,
                chunk, wslot, off, nv, rng, work.last,
            )
        return pre_tok

    # ------------------------------------------------------------ telemetry
    def _trace_prefill_dispatch(self, n_tokens: int, n_steps: int) -> StepRecord:
        """StepRecord for a whole-prompt admission prefill (decode-only
        schedule), charged at its ``ceil(L / prefill_chunk)``-step cost.
        Called only when telemetry is enabled; returns the record (already
        handed to the tracer) so the profiler can annotate it in place."""
        cm = self._cost_model
        ctx = cm.chunk_ctx_tokens(0, n_tokens)
        flops, bytes_ = cm.cost(0, 0, n_tokens, ctx)
        rec = StepRecord(
            replica=self.replica, step=self.stats.engine_steps,
            kind="prefill", decode_batch=0, prefill_tokens=n_tokens,
            bucket=None, bucket2=None,
            budget=n_steps * self.prefill_chunk,
            fill=n_tokens / max(n_steps * self.prefill_chunk, 1),
            kv_tokens=0,
            pool_util=(self.pool.utilization
                       if self.cache_kind == "paged" else None),
            host_util=(self.pool.host_utilization
                       if self.cache_kind == "paged" and self.host_blocks
                       else None),
            pipeline_depth=len(self._pending),
            flops=flops, bytes=bytes_, oi=flops / max(bytes_, 1.0),
            wall=self.tracer.wall(),
        )
        self.tracer.on_step(rec)
        return rec

    def _trace_step(self, kind: str, active: list[int],
                    work: PrefillChunk | None = None,
                    work2: PrefillChunk | None = None) -> StepRecord:
        """StepRecord for one decode/fused dispatch: composition (batch,
        chunk, budget fill, pool pressure, pipeline depth) plus analytic
        FLOPs/bytes so each dispatch lands on the paper's Fig-1 roofline.
        Called only when telemetry is enabled, from host bookkeeping the
        engine already holds — no device reads.  Returns the record
        (already handed to the tracer) so the sampled profiler can join
        its fenced wall-clock measurement onto it in place."""
        cm = self._cost_model
        kv = 0
        for i in active:
            r = self.slots[i]
            kv += len(r.prompt) + len(r.out_tokens) + r.in_flight
        pre = ctx = 0
        for w in (work, work2):
            if w is not None:
                pre += w.n_valid
                ctx += cm.chunk_ctx_tokens(w.start, w.n_valid)
        budget = (self.sched.token_budget if self.schedule == "hybrid"
                  else len(self.slots))
        flops, bytes_ = cm.cost(len(active), kv, pre, ctx)
        rec = StepRecord(
            replica=self.replica, step=self.stats.engine_steps, kind=kind,
            decode_batch=len(active), prefill_tokens=pre,
            bucket=work.bucket if work is not None else None,
            bucket2=work2.bucket if work2 is not None else None,
            budget=budget, fill=(len(active) + pre) / max(budget, 1),
            kv_tokens=kv,
            pool_util=(self.pool.utilization
                       if self.cache_kind == "paged" else None),
            host_util=(self.pool.host_utilization
                       if self.cache_kind == "paged" and self.host_blocks
                       else None),
            pipeline_depth=len(self._pending),
            flops=flops, bytes=bytes_, oi=flops / max(bytes_, 1.0),
            wall=self.tracer.wall(),
        )
        self.tracer.on_step(rec)
        return rec

    def _profile_fence(self):
        """The pytree the profiler blocks on to bracket a sampled
        dispatch: cache (+ paged staging buffers, + async token state)
        covers every array the jit chain writes.  ``block_until_ready``
        skips None subtrees, so missing pieces cost nothing."""
        return (
            self.cache,
            getattr(self, "staging", None),
            self._tok_state if self.async_mode else None,
        )

    def _dispatch_kind(self, active, work, work2) -> str:
        spec = bool(self.spec_depth and active)
        if work2 is not None:
            return "fused2" if active else "solo2"
        if work is not None:
            return ("spec_fused" if spec else "fused") if active else "solo"
        return "spec" if spec else "decode"

    # ----------------------------------------------------------------- step
    def _decode_tokens(self) -> jax.Array:
        tokens = np.zeros((len(self.slots),), np.int32)
        for i, req in enumerate(self.slots):
            if req is not None and req.out_tokens:
                tokens[i] = req.out_tokens[-1]
        return jnp.asarray(tokens)

    def _finish_decode(self, active: list[int], logits):
        next_toks = sample(logits, self._next_rng(), self.sampler)
        next_host = np.asarray(next_toks)
        for i in active:
            req = self.slots[i]
            tok = int(next_host[i])
            req.out_tokens.append(tok)
            self.stats.generated += 1
            length = len(req.prompt) + len(req.out_tokens)
            if (
                tok == req.eos_id
                or len(req.out_tokens) >= req.max_new_tokens
                or length >= self.max_seq - 1
            ):
                self._finish(i, req, self.stats.engine_steps)

    def step(self) -> bool:
        """One engine iteration.  Returns whether any work remains."""
        if self.schedule == "hybrid":
            if self.async_mode:
                return self._step_hybrid_async()
            return self._step_hybrid()
        if self.async_mode:
            return self._step_decode_only_async()
        return self._step_decode_only()

    def _step_decode_only(self) -> bool:
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if self.cache_kind == "paged" and active:
            active = self._prepare_append(active)
        if not active:
            return self.sched.has_work()
        self.stats.peak_active = max(self.stats.peak_active, len(active))

        prof = self.profiler
        sampling = prof.enabled and prof.tick()
        if sampling:
            prof.begin(self._profile_fence())
        logits, self.cache = self._decode(
            self.params, self.cache, self._decode_tokens()
        )
        if sampling:
            prof.end(self._profile_fence())
        self.stats.decode_steps += 1
        self.stats.engine_steps += 1
        if self._telemetry:
            rec = self._trace_step("decode", active)
            if sampling:
                prof.commit(rec)
        self._finish_decode(active, logits)
        return any(s is not None for s in self.slots) or self.sched.has_work()

    def _step_decode_only_async(self) -> bool:
        self._admit()
        active = self._predicted_active()
        if self.cache_kind == "paged" and active:
            active = self._prepare_append(active)
        if not active:
            self._drain()               # nothing to dispatch: settle state
            return any(s is not None for s in self.slots) or self.sched.has_work()
        self.stats.peak_active = max(self.stats.peak_active, len(active))

        prof = self.profiler
        sampling = prof.enabled and prof.tick()
        if sampling:
            prof.begin(self._profile_fence())    # settle in-flight steps
        eos = n_accept = None
        if self.spec_depth:
            (self._tok_state, toks, n_accept,
             self.cache, self.d_cache) = self._spec_step(
                self.params, self.draft_params, self.cache, self.d_cache,
                self._tok_state, self._step_rng(),
            )
        else:
            toks, eos, self.cache = self._decode_sampled(
                self.params, self.cache, self._tok_state, self._step_rng(),
                self._eos_dev, sampler=self.sampler,
            )
            self._tok_state = toks
        if sampling:
            prof.end(self._profile_fence())
        self.stats.decode_steps += 1
        self.stats.engine_steps += 1
        charge = 1
        if self.spec_depth:
            charge = self.spec_depth + 1
            self.stats.spec_steps += 1
            self.stats.draft_steps += self.spec_depth + 1
        if self._telemetry:
            rec = self._trace_step(
                "spec" if self.spec_depth else "decode", active
            )
            if sampling:
                prof.commit(rec)
            if self.spec_depth:
                self.tracer.on_spec_propose(
                    self.replica, self.stats.engine_steps,
                    self.spec_depth, len(active),
                )
        reqs = {}
        for i in active:
            req = self.slots[i]
            req.in_flight += charge
            req.in_flight_steps += 1
            reqs[i] = req
        self._dispatch(_PendingStep(
            step=self.stats.engine_steps, reqs=reqs, tokens=toks, eos=eos,
            n_accept=n_accept, charge=charge,
        ))
        return True

    def _step_hybrid(self) -> bool:
        sched = self.sched
        if sched.inflight is None and len(sched):
            free = self._free_slots()
            if free:
                req = sched.pop()
                slot = free[0]
                start, total = self._begin_prefill(req, slot)
                sched.begin(req, slot, start, total)
                if req.admit_step < 0:
                    req.admit_step = self.stats.engine_steps + 1
                self.tracer.on_admit(self.replica, req,
                                     self.stats.engine_steps, slot,
                                     n_tokens=total,
                                     refold=bool(req.out_tokens))

        active = [i for i, s in enumerate(self.slots) if s is not None]
        if self.cache_kind == "paged" and active:
            active = self._prepare_append(active)
        decision = sched.schedule(active)
        active = decision.decode_slots       # the scheduler owns the batch
        work = decision.prefill
        if work is not None and self.cache_kind == "paged":
            ok = self.manager.extend_chunked(
                work.slot, len(self._pf_tokens[work.slot]),
                work.start + work.n_valid, work.last,
            )
            if not ok:
                work = None             # pool dry: decode-only iteration
        if not active and work is None:
            return sched.has_work()

        self.stats.engine_steps += 1
        self.stats.peak_active = max(self.stats.peak_active, len(active))

        # Sarathi-SC boundary packing: when `work` finishes its prompt,
        # the next prompt begins *now* and its head chunk joins the same
        # dispatch, filling the budget the small final chunk left unused.
        # A's chunk arrays are built before _begin_prefill pins B.
        work2 = None
        pre_advanced = False
        if work is not None:
            chunk, off, nv = self._chunk_arrays(work)
            if work.last and len(sched):
                sched.advance(work)     # A rides this dispatch regardless
                pre_advanced = True
                work2 = self._boundary_chunk(
                    sched.token_budget - len(active) - work.n_valid, work.slot
                )
                if work2 is not None:
                    chunk2, off2, nv2 = self._chunk_arrays(work2)

        prof = self.profiler
        sampling = prof.enabled and prof.tick()
        if sampling:
            prof.begin(self._profile_fence())
        dec_logits = pre_logits = logits2 = None
        if work2 is not None:
            self.stats.boundary_packs += 1
            self.tracer.on_boundary_pack(self.replica, work2.req,
                                         self.stats.engine_steps, work2.slot)
            if self.cache_kind == "paged":
                laneA = np.int32(self._pf_lane.get(work.slot, 0))
                laneB = np.int32(self._pf_lane.get(work2.slot, 0))
                if active:
                    (dec_logits, pre_logits, logits2,
                     self.cache, self.staging) = self._fused2(
                        self.params, self.cache, self.staging,
                        self._decode_tokens(),
                        chunk, laneA, off, nv, chunk2, laneB, off2, nv2,
                    )
                    self.stats.decode_steps += 1
                else:
                    pre_logits, logits2, self.staging = self._solo2(
                        self.params, self.staging,
                        chunk, laneA, off, nv, chunk2, laneB, off2, nv2,
                    )
            elif active:
                dec_logits, pre_logits, logits2, self.cache = self._fused2(
                    self.params, self.cache, self._decode_tokens(),
                    chunk, np.int32(work.slot), off, nv,
                    chunk2, np.int32(work2.slot), off2, nv2,
                )
                self.stats.decode_steps += 1
            else:
                pre_logits, logits2, self.cache = self._solo2(
                    self.params, self.cache,
                    chunk, np.int32(work.slot), off, nv,
                    chunk2, np.int32(work2.slot), off2, nv2,
                )
        elif active and work is not None:
            if self.cache_kind == "paged":
                dec_logits, pre_logits, self.cache, self.staging = self._fused(
                    self.params, self.cache, self.staging,
                    self._decode_tokens(), chunk,
                    np.int32(self._pf_lane.get(work.slot, 0)), off, nv,
                )
            else:
                dec_logits, pre_logits, self.cache = self._fused(
                    self.params, self.cache, self._decode_tokens(), chunk,
                    np.int32(work.slot), off, nv,
                )
            self.stats.decode_steps += 1
        elif active:
            dec_logits, self.cache = self._decode(
                self.params, self.cache, self._decode_tokens()
            )
            self.stats.decode_steps += 1
        else:
            pre_logits = self._exec_solo_sync(work)

        if sampling:
            prof.end(self._profile_fence())
        if self._telemetry:
            rec = self._trace_step(self._dispatch_kind(active, work, work2),
                                   active, work, work2)
            if sampling:
                prof.commit(rec)
        if active:
            self._finish_decode(active, dec_logits)
        if work is not None:
            self.stats.prefill_chunks += 1
            self._complete_chunk(work, pre_logits, advance=not pre_advanced)
        if work2 is not None:
            self.stats.prefill_chunks += 1
            self._complete_chunk(work2, logits2)
        return any(s is not None for s in self.slots) or sched.has_work()

    def _step_hybrid_async(self) -> bool:
        sched = self.sched
        if sched.inflight is None and len(sched):
            free = self._free_slots()
            if free:
                req = sched.pop()
                slot = free[0]
                start, total = self._begin_prefill(req, slot)
                sched.begin(req, slot, start, total)
                if req.admit_step < 0:
                    req.admit_step = self.stats.engine_steps + 1
                self.tracer.on_admit(self.replica, req,
                                     self.stats.engine_steps, slot,
                                     n_tokens=total,
                                     refold=bool(req.out_tokens))

        active = self._predicted_active()
        if self.cache_kind == "paged" and active:
            active = self._prepare_append(active)
        decision = sched.plan_ahead(active)
        active = decision.decode_slots       # the scheduler owns the batch
        work = decision.prefill
        if work is not None and self.cache_kind == "paged":
            ok = self.manager.extend_chunked(
                work.slot, len(self._pf_tokens[work.slot]),
                work.start + work.n_valid, work.last,
            )
            if not ok:
                work = None             # pool dry: decode-only iteration
        if not active and work is None:
            self._drain()
            return any(s is not None for s in self.slots) or sched.has_work()

        self.stats.engine_steps += 1
        self.stats.peak_active = max(self.stats.peak_active, len(active))
        rng = self._step_rng()

        # boundary packing, async twin (see _step_hybrid): the next
        # prompt's head chunk joins the same sampled dispatch.  Disabled
        # under speculation — the fused2 programs have no spec variant,
        # and the budget a spec verify leaves over rarely fits two chunks
        work2 = None
        pre_advanced = False
        if work is not None:
            chunk, off, nv = self._chunk_arrays(work)
            wslot = np.int32(work.slot)
            lane = np.int32(self._pf_lane.get(work.slot, 0))
            if work.last and len(sched) and not self.spec_depth:
                sched.advance(work)
                pre_advanced = True
                work2 = self._boundary_chunk(
                    sched.token_budget - len(active) - work.n_valid, work.slot
                )
                if work2 is not None:
                    chunk2, off2, nv2 = self._chunk_arrays(work2)
                    wslot2 = np.int32(work2.slot)
                    lane2 = np.int32(self._pf_lane.get(work2.slot, 0))

        prof = self.profiler
        sampling = prof.enabled and prof.tick()
        if sampling:
            prof.begin(self._profile_fence())    # settle in-flight steps
        toks = eos = pre_tok = pre_tok2 = n_accept = None
        if work2 is not None:
            self.stats.boundary_packs += 1
            self.tracer.on_boundary_pack(self.replica, work2.req,
                                         self.stats.engine_steps, work2.slot)
            if self.cache_kind == "paged":
                if active:
                    (self._tok_state, toks, eos, pre_tok, pre_tok2,
                     self.cache, self.staging) = self._fused2(
                        self.params, self.cache, self.staging, self._tok_state,
                        chunk, wslot, lane, off, nv,
                        chunk2, wslot2, lane2, off2, nv2,
                        rng, self._eos_dev, work2.last,
                    )
                    self.stats.decode_steps += 1
                else:
                    (self._tok_state, pre_tok, pre_tok2,
                     self.staging) = self._solo2(
                        self.params, self.staging, self._tok_state,
                        chunk, wslot, lane, off, nv,
                        chunk2, wslot2, lane2, off2, nv2,
                        rng, work2.last,
                    )
            elif active:
                (self._tok_state, toks, eos, pre_tok, pre_tok2,
                 self.cache) = self._fused2(
                    self.params, self.cache, self._tok_state,
                    chunk, wslot, off, nv, chunk2, wslot2, off2, nv2,
                    rng, self._eos_dev, work2.last,
                )
                self.stats.decode_steps += 1
            else:
                self._tok_state, pre_tok, pre_tok2, self.cache = self._solo2(
                    self.params, self.cache, self._tok_state,
                    chunk, wslot, off, nv, chunk2, wslot2, off2, nv2,
                    rng, work2.last,
                )
        elif active and work is not None:
            if self.spec_depth:
                if self.cache_kind == "paged":
                    (self._tok_state, toks, n_accept, pre_tok, self.cache,
                     self.staging, self.d_cache) = self._spec_fused(
                        self.params, self.draft_params, self.cache,
                        self.staging, self.d_cache, self._tok_state,
                        chunk, wslot, lane, off, nv, rng, work.last,
                    )
                else:
                    (self._tok_state, toks, n_accept, pre_tok,
                     self.cache, self.d_cache) = self._spec_fused(
                        self.params, self.draft_params, self.cache,
                        self.d_cache, self._tok_state,
                        chunk, wslot, off, nv, rng, work.last,
                    )
            elif self.cache_kind == "paged":
                (self._tok_state, toks, eos, pre_tok,
                 self.cache, self.staging) = self._fused(
                    self.params, self.cache, self.staging, self._tok_state,
                    chunk, wslot, lane, off, nv, rng, self._eos_dev, work.last,
                )
            else:
                self._tok_state, toks, eos, pre_tok, self.cache = self._fused(
                    self.params, self.cache, self._tok_state,
                    chunk, wslot, off, nv, rng, self._eos_dev, work.last,
                )
            self.stats.decode_steps += 1
        elif active:
            if self.spec_depth:
                (self._tok_state, toks, n_accept,
                 self.cache, self.d_cache) = self._spec_step(
                    self.params, self.draft_params, self.cache, self.d_cache,
                    self._tok_state, rng,
                )
            else:
                toks, eos, self.cache = self._decode_sampled(
                    self.params, self.cache, self._tok_state, rng,
                    self._eos_dev, sampler=self.sampler,
                )
                self._tok_state = toks
            self.stats.decode_steps += 1
        else:
            pre_tok = self._exec_solo_async(work, rng)

        if sampling:
            prof.end(self._profile_fence())
        charge = 1
        if self.spec_depth and active:
            charge = self.spec_depth + 1
            self.stats.spec_steps += 1
            self.stats.draft_steps += self.spec_depth + 1
            if self.tracer.enabled:
                self.tracer.on_spec_propose(
                    self.replica, self.stats.engine_steps,
                    self.spec_depth, len(active),
                )

        if self._telemetry:
            srec = self._trace_step(self._dispatch_kind(active, work, work2),
                                    active, work, work2)
            if sampling:
                prof.commit(srec)
        reqs = {}
        for i in active:
            req = self.slots[i]
            req.in_flight += charge
            req.in_flight_steps += 1
            reqs[i] = req
        rec = _PendingStep(
            step=self.stats.engine_steps, reqs=reqs, tokens=toks, eos=eos,
            work=work, pre_tok=pre_tok, work2=work2, pre_tok2=pre_tok2,
            n_accept=n_accept, charge=charge,
        )
        if work is not None:
            self.stats.prefill_chunks += 1
            self._complete_chunk_async(work, advance=not pre_advanced)
        if work2 is not None:
            self.stats.prefill_chunks += 1
            self._complete_chunk_async(work2)
        self._dispatch(rec)
        return True

    def run(self, max_steps: int = 10_000) -> EngineStats:
        for _ in range(max_steps):
            if not self.step():
                break
        if self.async_mode:
            self._drain()           # settle out_tokens if max_steps truncated
        return self.stats

    # -------------------------------------------------------- introspection
    def kv_bytes(self) -> int:
        """Physical KV footprint of the resident cache (both modes)."""
        return kv_cache.kv_bytes(self.cache)
