"""Continuous-batching serving engine with HPU-offloaded decode.

Slot-based continuous batching (Orca-style): a fixed decode batch of
``n_slots`` sequences; finished sequences free their slot and queued
requests are prefilled into it while decode keeps running for the rest —
this is what keeps the decode batch (and thus the offloaded-attention
bandwidth utilization the paper optimizes) high.

The decode step is wrapped by ``core.pipeline.pipelined_step`` when
``sub_batches > 1`` (paper Fig. 3), and attention runs through
``core.offload`` in the layout chosen by ``core.balance.plan``.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import pipelined_step
from repro.models.registry import Model
from repro.serving import kv_cache
from repro.serving.sampler import SamplerConfig, sample

Pytree = Any


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int
    eos_id: int = -1                # -1: never stops early
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineStats:
    prefills: int = 0
    decode_steps: int = 0
    generated: int = 0
    peak_active: int = 0


class Engine:
    def __init__(
        self,
        model: Model,
        params: Pytree,
        n_slots: int,
        max_seq: int,
        sampler: SamplerConfig = SamplerConfig(),
        sub_batches: int = 1,
        rng: jax.Array | None = None,
    ):
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self.sampler = sampler
        self.cache = model.init_cache(n_slots, max_seq)
        self.slots: list[Request | None] = [None] * n_slots
        self.queue: deque[Request] = deque()
        self.stats = EngineStats()
        self.rng = rng if rng is not None else jax.random.key(0)

        self._prefill = jax.jit(model.prefill)
        step = pipelined_step(model.decode_step, sub_batches)
        self._decode = jax.jit(step)

    # ------------------------------------------------------------- requests
    def submit(self, req: Request):
        self.queue.append(req)

    def _free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    # ----------------------------------------------------------------- step
    def _admit(self):
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.popleft()
            prompt = jnp.asarray(req.prompt, jnp.int32)[None]
            sub_cache = self.model.init_cache(1, self.max_seq)
            kwargs = {}
            logits, sub_cache = self._prefill(self.params, prompt, sub_cache, **kwargs)
            self.cache = kv_cache.insert(self.cache, sub_cache, slot)
            self.slots[slot] = req
            tok = int(sample(logits, self._next_rng(), self.sampler)[0])
            req.out_tokens.append(tok)
            self.stats.prefills += 1
            self.stats.generated += 1

    def _next_rng(self) -> jax.Array:
        self.rng, sub = jax.random.split(self.rng)
        return sub

    def step(self) -> bool:
        """One engine iteration: admit -> batched decode.  Returns whether
        any work remains."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return bool(self.queue)
        self.stats.peak_active = max(self.stats.peak_active, len(active))

        tokens = np.zeros((len(self.slots),), np.int32)
        for i, req in enumerate(self.slots):
            if req is not None and req.out_tokens:
                tokens[i] = req.out_tokens[-1]
        logits, self.cache = self._decode(self.params, self.cache, jnp.asarray(tokens))
        self.stats.decode_steps += 1
        next_toks = sample(logits, self._next_rng(), self.sampler)
        next_host = np.asarray(next_toks)

        for i in active:
            req = self.slots[i]
            tok = int(next_host[i])
            req.out_tokens.append(tok)
            self.stats.generated += 1
            length = len(req.prompt) + len(req.out_tokens)
            if (
                tok == req.eos_id
                or len(req.out_tokens) >= req.max_new_tokens
                or length >= self.max_seq - 1
            ):
                req.done = True
                self.slots[i] = None
                self.cache = kv_cache.reset_slot(self.cache, i)
        return any(s is not None for s in self.slots) or bool(self.queue)

    def run(self, max_steps: int = 10_000) -> EngineStats:
        for _ in range(max_steps):
            if not self.step():
                break
        return self.stats
