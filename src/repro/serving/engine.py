"""Continuous-batching serving engine with HPU-offloaded decode.

Slot-based continuous batching (Orca-style): a fixed decode batch of
``n_slots`` sequences; finished sequences free their slot and queued
requests are prefilled into it while decode keeps running for the rest —
this is what keeps the decode batch (and thus the offloaded-attention
bandwidth utilization the paper optimizes) high.

Two cache modes (``cache_kind``):

* ``"dense"`` — the seed baseline: every slot reserves a full
  ``max_seq`` stripe of KV, admission is gated on free *slots*.
* ``"paged"`` — physical KV is a :class:`~repro.serving.paged.BlockPool`
  of fixed-size blocks; admission is gated on free *blocks* (actual HPU
  memory), shared prompt prefixes share physical blocks (copy-on-write
  on first divergent append), and running out of blocks preempts the
  youngest sequence back to the queue — it re-prefills later from its
  prompt plus the tokens already generated, so greedy output is exact.

The decode step is wrapped by ``core.pipeline.pipelined_step`` when
``sub_batches > 1`` (paper Fig. 3), and attention runs through
``core.offload`` in the layout chosen by ``core.balance.plan``.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import pipelined_step
from repro.models.registry import Model
from repro.serving import kv_cache
from repro.serving.paged import BlockPool, PagedCacheManager
from repro.serving.paged import device as paged_dev
from repro.serving.sampler import SamplerConfig, sample

Pytree = Any


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int
    eos_id: int = -1                # -1: never stops early
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineStats:
    prefills: int = 0
    decode_steps: int = 0
    generated: int = 0
    peak_active: int = 0
    preemptions: int = 0


class Engine:
    def __init__(
        self,
        model: Model,
        params: Pytree,
        n_slots: int,
        max_seq: int,
        sampler: SamplerConfig = SamplerConfig(),
        sub_batches: int = 1,
        rng: jax.Array | None = None,
        cache_kind: str = "dense",
        block_size: int = 16,
        n_blocks: int | None = None,
    ):
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self.sampler = sampler
        self.cache_kind = cache_kind
        self.slots: list[Request | None] = [None] * n_slots
        self.queue: deque[Request] = deque()
        self.stats = EngineStats()
        self.rng = rng if rng is not None else jax.random.key(0)

        self._prefill = jax.jit(model.prefill)
        if cache_kind == "paged":
            if model.paged_decode_step is None:
                raise ValueError(f"{model.cfg.family} has no paged decode path")
            if sub_batches != 1:
                raise NotImplementedError(
                    "paged cache does not compose with sub-batch pipelining yet"
                )
            self.block_size = block_size
            self.max_blocks = -(-max_seq // block_size)
            # default: same physical budget as the dense cache, + null block
            self.n_blocks = (
                n_slots * self.max_blocks + 1 if n_blocks is None else n_blocks
            )
            if self.n_blocks - 1 < self.max_blocks:
                raise ValueError(
                    f"pool of {self.n_blocks - 1} usable blocks cannot hold one "
                    f"max_seq={max_seq} sequence ({self.max_blocks} blocks)"
                )
            self.pool = BlockPool(self.n_blocks, block_size)
            self.manager = PagedCacheManager(self.pool, n_slots, self.max_blocks)
            self.cache = model.init_paged_cache(
                n_slots, self.n_blocks, block_size, self.max_blocks
            )
            self._decode = jax.jit(model.paged_decode_step)
        elif cache_kind == "dense":
            self.cache = model.init_cache(n_slots, max_seq)
            step = pipelined_step(model.decode_step, sub_batches)
            self._decode = jax.jit(step)
        else:
            raise ValueError(f"unknown cache_kind {cache_kind!r}")

    # ------------------------------------------------------------- requests
    def submit(self, req: Request):
        self.queue.append(req)

    def _free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def _next_rng(self) -> jax.Array:
        self.rng, sub = jax.random.split(self.rng)
        return sub

    # ------------------------------------------------------------ admission
    def _admit(self):
        if self.cache_kind == "paged":
            self._admit_paged()
            return
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.popleft()
            prompt = jnp.asarray(req.prompt, jnp.int32)[None]
            sub_cache = self.model.init_cache(1, self.max_seq)
            logits, sub_cache = self._prefill(self.params, prompt, sub_cache)
            self.cache = kv_cache.insert(self.cache, sub_cache, slot)
            self.slots[slot] = req
            self._sample_prefill(req, logits)

    def _admit_paged(self):
        """Admit while slots AND blocks allow; head-of-line blocks wait.

        A preempted request re-enters here with its generated tokens
        folded into the prefill, reproducing its exact decode state.
        """
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue[0]
            full = np.concatenate(
                [np.asarray(req.prompt, np.int32),
                 np.asarray(req.out_tokens, np.int32)]
            )
            # the last sampled token is input, not cache content: the KV
            # written at admission covers full[:-1]'s context plus itself,
            # i.e. exactly len(full) positions after prefill
            res = self.manager.try_admit(slot, full)
            if res is None:
                break                       # out of blocks: wait/FCFS
            self.queue.popleft()
            blocks, n_cached = res
            pad = -(-len(full) // self.block_size) * self.block_size
            sub_cache = self.model.init_cache(1, pad)
            logits, sub_cache = self._prefill(
                self.params, jnp.asarray(full, jnp.int32)[None], sub_cache
            )
            # fill only the blocks the prefix cache didn't already hold
            for j in range(n_cached, len(blocks)):
                self.cache = paged_dev.write_prompt_block(
                    self.cache, sub_cache, blocks[j], j * self.block_size
                )
            self.cache = paged_dev.sync_slot(
                self.cache, slot, self.manager.tables[slot], len(full)
            )
            self.slots[slot] = req
            self._sample_prefill(req, logits)

    def _sample_prefill(self, req: Request, logits):
        tok = int(sample(logits, self._next_rng(), self.sampler)[0])
        req.out_tokens.append(tok)
        self.stats.prefills += 1
        self.stats.generated += 1

    # ----------------------------------------------------- block management
    def _kv_len(self, slot: int) -> int:
        """KV positions held for ``slot`` (last sampled token not yet
        appended — it is this step's input)."""
        req = self.slots[slot]
        return len(req.prompt) + len(req.out_tokens) - 1

    def _preempt(self, slot: int):
        """Evict ``slot`` to the queue front; blocks return to the pool.
        Its tokens are preserved and recomputed at re-admission."""
        req = self.slots[slot]
        self.slots[slot] = None
        self.manager.free_slot(slot)
        self.cache = paged_dev.sync_slot(
            self.cache, slot, self.manager.tables[slot], 0
        )
        self.queue.appendleft(req)
        self.stats.preemptions += 1
        self.pool.stats.preemptions += 1

    def _prepare_append(self, active: list[int]) -> list[int]:
        """Guarantee every active slot can write its next token: allocate
        boundary blocks, copy-on-write shared tails, preempt the youngest
        sequence when the pool runs dry.  Returns the surviving slots."""
        alive = set(active)
        for slot in sorted(active, key=lambda s: self.manager.admit_seq[s]):
            while slot in alive:
                directive, payload = self.manager.ensure_append(
                    slot, self._kv_len(slot)
                )
                if directive == "oom":
                    victim = self.manager.youngest(alive)
                    self._preempt(victim)
                    alive.discard(victim)
                    continue                # retry (unless we evicted slot)
                if directive == "cow":
                    src, dst = payload
                    self.cache = paged_dev.copy_block(self.cache, src, dst)
                if directive in ("cow", "new"):
                    self.cache = paged_dev.sync_slot(
                        self.cache, slot, self.manager.tables[slot]
                    )
                break
        return [s for s in active if s in alive]

    # ----------------------------------------------------------------- step
    def step(self) -> bool:
        """One engine iteration: admit -> batched decode.  Returns whether
        any work remains."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if self.cache_kind == "paged" and active:
            active = self._prepare_append(active)
        if not active:
            return bool(self.queue)
        self.stats.peak_active = max(self.stats.peak_active, len(active))

        tokens = np.zeros((len(self.slots),), np.int32)
        for i, req in enumerate(self.slots):
            if req is not None and req.out_tokens:
                tokens[i] = req.out_tokens[-1]
        logits, self.cache = self._decode(self.params, self.cache, jnp.asarray(tokens))
        self.stats.decode_steps += 1
        next_toks = sample(logits, self._next_rng(), self.sampler)
        next_host = np.asarray(next_toks)

        for i in active:
            req = self.slots[i]
            tok = int(next_host[i])
            req.out_tokens.append(tok)
            self.stats.generated += 1
            length = len(req.prompt) + len(req.out_tokens)
            if (
                tok == req.eos_id
                or len(req.out_tokens) >= req.max_new_tokens
                or length >= self.max_seq - 1
            ):
                req.done = True
                self.slots[i] = None
                if self.cache_kind == "paged":
                    self.manager.free_slot(i)
                    self.cache = paged_dev.sync_slot(
                        self.cache, i, self.manager.tables[i], 0
                    )
                else:
                    self.cache = kv_cache.reset_slot(self.cache, i)
        return any(s is not None for s in self.slots) or bool(self.queue)

    def run(self, max_steps: int = 10_000) -> EngineStats:
        for _ in range(max_steps):
            if not self.step():
                break
        return self.stats

    # -------------------------------------------------------- introspection
    def kv_bytes(self) -> int:
        """Physical KV footprint of the resident cache (both modes)."""
        return kv_cache.kv_bytes(self.cache)