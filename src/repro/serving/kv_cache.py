"""Slot-level KV cache management for continuous batching.

All model families expose caches as flat dicts whose non-``lengths``
leaves carry the batch dimension at axis 1 (stacked layers/slots at axis
0) — so slot insert/evict is family-agnostic: we slice axis 1 (axis 0 for
``lengths``).  The cache lives sharded in the HPU layout
(``Model.cache_specs``); slot writes are index updates that XLA keeps
local to the owning shards.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


def batch_axis(key: str) -> int:
    return 0 if key == "lengths" else 1


def n_slots(cache: Pytree) -> int:
    return cache["lengths"].shape[0]


def insert(cache: Pytree, sub: Pytree, slot: int) -> Pytree:
    """Write a single-sequence cache ``sub`` (batch size 1) into ``slot``."""
    out = {}
    for k, v in cache.items():
        ax = batch_axis(k)
        idx = [slice(None)] * v.ndim
        idx[ax] = slot
        out[k] = v.at[tuple(idx)].set(jnp.squeeze(sub[k], axis=ax))
    return out


def export_slot(cache: Pytree, slot: int) -> Pytree:
    """Gather one slot's stripe as a batch-1 sub-cache (the inverse of
    :func:`insert`): the dense-cache migration payload.  Includes the
    slot's ``lengths`` entry, so ``insert`` on the destination replica
    restores both KV content and logical length in one call."""
    out = {}
    for k, v in cache.items():
        ax = batch_axis(k)
        out[k] = jnp.take(v, jnp.asarray([slot]), axis=ax)
    return out


def reset_slot(cache: Pytree, slot: int) -> Pytree:
    """Zero a finished slot (length <- 0 frees it logically)."""
    out = {}
    for k, v in cache.items():
        ax = batch_axis(k)
        idx = [slice(None)] * v.ndim
        idx[ax] = slot
        out[k] = v.at[tuple(idx)].set(jnp.zeros(()).astype(v.dtype))
    return out


def kv_bytes(cache: Pytree) -> int:
    return sum(v.size * v.dtype.itemsize for v in jax.tree.leaves(cache))
