"""Paged KV-cache subsystem (vLLM-style, HPU-pooled).

Physical KV memory is a pool of fixed-size blocks shared by every
sequence; per-sequence block tables map logical positions to physical
blocks.  Admission is gated on free *blocks* (actual memory) instead of
free slots, shared prompt prefixes share physical blocks via a chain
hash with copy-on-write on first divergence, and block exhaustion
preempts the youngest sequence back to the queue.
"""
from repro.serving.paged.block_pool import BlockPool, PoolStats, chain_key
from repro.serving.paged.manager import PagedCacheManager
from repro.serving.paged import device

__all__ = ["BlockPool", "PoolStats", "chain_key", "PagedCacheManager", "device"]
