"""Fixed-size KV block pool: free-list allocator, refcounts, prefix hashing.

The paper's scaling argument (§VI) is that KV *capacity*, not compute,
bounds large-batch decode — so physical cache memory must be a fungible
pool, not per-slot reservations.  ``BlockPool`` manages the physical side
of that pool entirely on the host: device arrays never move; allocation
is bookkeeping over block ids.

Conventions
-----------
* Block id 0 is the **null/trash block**: it is never allocated, every
  unused block-table entry points at it, and inactive decode lanes write
  their (ignored) K/V there.  Usable capacity is ``n_blocks - 1``.
* A *full* block whose contents are a pure function of a token prefix is
  registered under a chain hash ``key_j = (key_{j-1}, tokens_j)`` so a
  later request with the same prefix reuses the physical block
  (vLLM-style prefix caching).  Partial tail blocks register too — they
  match only byte-identical prompts — and are invalidated the moment a
  sequence appends to them in place (contents diverge from the key).
* Shared blocks are copy-on-write: the *appending* sequence copies, the
  remaining owners keep the original (see ``PagedCacheManager``).
"""
from __future__ import annotations

import dataclasses
from typing import Hashable


@dataclasses.dataclass
class PoolStats:
    allocs: int = 0          # fresh physical blocks handed out
    frees: int = 0           # blocks returned to the free list
    hash_hits: int = 0       # prefix-cache lookups that found a block
    cow_copies: int = 0      # copy-on-write block duplications
    preemptions: int = 0     # sequences evicted for block pressure
    peak_in_use: int = 0


class BlockPool:
    def __init__(self, n_blocks: int, block_size: int):
        if n_blocks < 2:
            raise ValueError(f"need >= 2 blocks (1 usable + null), got {n_blocks}")
        self.n_blocks = n_blocks
        self.block_size = block_size
        # LIFO free list, low ids first out — keeps tests deterministic
        self._free = list(range(n_blocks - 1, 0, -1))
        self._ref: dict[int, int] = {}
        self._key_to_block: dict[Hashable, int] = {}
        self._block_to_key: dict[int, Hashable] = {}
        self.stats = PoolStats()

    # ------------------------------------------------------------- capacity
    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return (self.n_blocks - 1) - len(self._free)

    @property
    def utilization(self) -> float:
        """Fraction of usable blocks currently owned — the cluster
        router's load signal for KV memory pressure."""
        return self.in_use / max(self.n_blocks - 1, 1)

    # ----------------------------------------------------------- allocation
    def alloc(self) -> int:
        """Take a free block (refcount 1).  Raises when the pool is dry —
        callers gate on ``free_count`` and preempt instead."""
        if not self._free:
            raise RuntimeError("BlockPool exhausted")
        b = self._free.pop()
        self._ref[b] = 1
        self.stats.allocs += 1
        self.stats.peak_in_use = max(self.stats.peak_in_use, self.in_use)
        return b

    def refcount(self, block: int) -> int:
        return self._ref.get(block, 0)

    def incref(self, block: int) -> None:
        self._ref[block] += 1

    def decref(self, block: int) -> None:
        self._ref[block] -= 1
        if self._ref[block] == 0:
            del self._ref[block]
            self.invalidate(block)
            self._free.append(block)
            self.stats.frees += 1

    # ------------------------------------------------------- prefix caching
    def lookup(self, key: Hashable) -> int | None:
        b = self._key_to_block.get(key)
        if b is not None:
            self.stats.hash_hits += 1
        return b

    def peek(self, key: Hashable) -> int | None:
        """Stat-free :meth:`lookup`: read-only probes (the cluster
        router's prefix-affinity scoring) must not count as cache hits."""
        return self._key_to_block.get(key)

    def register(self, key: Hashable, block: int) -> None:
        # a colliding re-register (identical content written twice) keeps
        # the newest mapping; both directions stay consistent
        old = self._key_to_block.get(key)
        if old is not None:
            self._block_to_key.pop(old, None)
        self._key_to_block[key] = block
        self._block_to_key[block] = key

    def invalidate(self, block: int) -> None:
        """Drop the hash entry for ``block`` (content changed or freed)."""
        key = self._block_to_key.pop(block, None)
        if key is not None:
            self._key_to_block.pop(key, None)


def chain_key(prev: Hashable, block_tokens: tuple[int, ...]) -> Hashable:
    """Prefix-chain hash key: identifies a block by the whole token prefix
    ending in it (tuple length distinguishes partial from full blocks)."""
    return (prev, block_tokens)
