"""Fixed-size KV block pool: free-list allocator, refcounts, prefix hashing.

The paper's scaling argument (§VI) is that KV *capacity*, not compute,
bounds large-batch decode — so physical cache memory must be a fungible
pool, not per-slot reservations.  ``BlockPool`` manages the physical side
of that pool entirely on the host: device arrays never move; allocation
is bookkeeping over block ids.

Conventions
-----------
* Block id 0 is the **null/trash block**: it is never allocated, every
  unused block-table entry points at it, and inactive decode lanes write
  their (ignored) K/V there.  Usable capacity is ``n_blocks - 1``.
* A *full* block whose contents are a pure function of a token prefix is
  registered under a chain hash ``key_j = (key_{j-1}, tokens_j)`` so a
  later request with the same prefix reuses the physical block
  (vLLM-style prefix caching).  Partial tail blocks register too — they
  match only byte-identical prompts — and are invalidated the moment a
  sequence appends to them in place (contents diverge from the key).
* Shared blocks are copy-on-write: the *appending* sequence copies, the
  remaining owners keep the original (see ``PagedCacheManager``).

Host tier (``host_blocks > 0``)
-------------------------------
A second, host-memory pool of the same block granularity (host id 0 is
again the null block).  Two flows feed it:

* **free-time spill** — when a hash-registered device block's refcount
  hits 0, its contents spill to a host block instead of vanishing: the
  prefix stays re-hydratable (a later identical prompt copies it back
  device-ward instead of recomputing the prefill).  Host capacity is a
  victim cache: unreferenced host blocks are LRU-evicted to make room.
* **live spill** — ``PagedCacheManager.spill_live_prefix`` moves a live
  sequence's cold leading blocks host-ward under pool pressure
  (spill-before-evict), ref-holding the host block until the slot frees.

The pool never touches device arrays: every spill/rehydrate decision is
emitted as a ``("spill", dev, host)`` / ``("rehydrate", host, dev)``
directive on :attr:`directives`; the engine drains them into the actual
device<->host block copies (``serving/paged/device.py``) before any
subsequent pool write can clobber the source.

Migration (cross-replica handoff)
---------------------------------
:meth:`BlockPool.export_blocks` releases a departing sequence's blocks
refcount-aware: a sole-owner block frees outright, a shared block only
decrefs (the caller copies its contents out first — copy-on-export — so
remaining owners and the hash entry stay intact).  On the destination,
:meth:`BlockPool.import_blocks` allocates fresh blocks but dedups
against blocks already resident under the same chain-hash key (incref
instead of a device copy), so migrating a popular prefix twice costs
one copy.
"""
from __future__ import annotations

import dataclasses
from typing import Hashable


@dataclasses.dataclass
class PoolStats:
    allocs: int = 0          # fresh physical blocks handed out
    frees: int = 0           # blocks returned to the free list
    hash_hits: int = 0       # prefix-cache lookups that found a block
    cow_copies: int = 0      # copy-on-write block duplications
    preemptions: int = 0     # sequences evicted for block pressure
    peak_in_use: int = 0
    spills: int = 0          # device blocks copied host-ward (both flows)
    rehydrates: int = 0      # host blocks copied back device-ward
    host_evictions: int = 0  # cold host blocks dropped for host pressure
    host_peak_in_use: int = 0
    exports: int = 0         # blocks released to a migrating sequence
    imports: int = 0         # blocks landed from a migrating sequence
    import_dedup: int = 0    # import positions satisfied by a resident block


class BlockPool:
    def __init__(self, n_blocks: int, block_size: int, host_blocks: int = 0):
        if n_blocks < 2:
            raise ValueError(f"need >= 2 blocks (1 usable + null), got {n_blocks}")
        self.n_blocks = n_blocks
        self.block_size = block_size
        # LIFO free list, low ids first out — keeps tests deterministic
        self._free = list(range(n_blocks - 1, 0, -1))
        self._ref: dict[int, int] = {}
        self._key_to_block: dict[Hashable, int] = {}
        self._block_to_key: dict[int, Hashable] = {}
        self.stats = PoolStats()
        # ------------------------------------------------------- host tier
        self.host_blocks = host_blocks
        self._host_free = list(range(host_blocks, 0, -1))
        self._host_ref: dict[int, int] = {}
        self._key_to_host: dict[Hashable, int] = {}
        self._host_to_key: dict[int, Hashable] = {}
        self._host_lru: list[int] = []       # unreferenced host blocks, oldest first
        self.directives: list[tuple] = []    # pending device<->host copies

    # ------------------------------------------------------------- capacity
    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return (self.n_blocks - 1) - len(self._free)

    @property
    def utilization(self) -> float:
        """Fraction of usable blocks currently owned — the cluster
        router's load signal for KV memory pressure."""
        return self.in_use / max(self.n_blocks - 1, 1)

    # ----------------------------------------------------------- allocation
    def alloc(self) -> int:
        """Take a free block (refcount 1).  Raises when the pool is dry —
        callers gate on ``free_count`` and preempt instead."""
        if not self._free:
            raise RuntimeError("BlockPool exhausted")
        b = self._free.pop()
        self._ref[b] = 1
        self.stats.allocs += 1
        self.stats.peak_in_use = max(self.stats.peak_in_use, self.in_use)
        return b

    def refcount(self, block: int) -> int:
        return self._ref.get(block, 0)

    def incref(self, block: int) -> None:
        self._ref[block] += 1

    def decref(self, block: int) -> None:
        self._ref[block] -= 1
        if self._ref[block] == 0:
            del self._ref[block]
            key = self._block_to_key.get(block)
            if (self.host_blocks and key is not None
                    and key not in self._key_to_host):
                # free-time spill: keep the dying prefix re-hydratable
                hb = self._host_reserve()
                if hb is not None:
                    self.directives.append(("spill", block, hb))
                    self.host_register(key, hb)
                    self._host_lru.append(hb)
                    self.stats.spills += 1
            self.invalidate(block)
            self._free.append(block)
            self.stats.frees += 1

    # ------------------------------------------------------------ migration
    def export_blocks(self, ids: list[int]) -> list[bool]:
        """Release a migrating sequence's blocks from *this* pool after
        their contents were gathered device-side (``copy_blocks_out``).

        Refcount-aware: a shared-prefix block is **copy-on-export** — the
        peer replica copies the payload while the remaining owners here
        keep the physical block *and* its hash entry untouched (only this
        sequence's reference drops).  A privately-owned block frees
        through the normal :meth:`decref` path, so a hash-registered
        prefix still free-time-spills to the host tier: migrating a
        sequence away does not cold-start this replica's prefix cache.

        Returns per-block ``was_shared`` flags (diagnostics/tests).
        """
        shared = []
        for b in ids:
            if b == 0:
                # cold (live-spilled) marker — callers exclude these
                raise ValueError("cannot export a cold (host-resident) block")
            shared.append(self.refcount(b) > 1)
            self.decref(b)
        self.stats.exports += len(ids)
        return shared

    def import_blocks(
        self, keys: list
    ) -> tuple[list[int], list[bool]] | None:
        """Allocate landing blocks for a migrating sequence described by
        its per-block hash ``keys`` (None = unkeyed: diverged tail or
        decode headroom).

        A key already resident in *this* pool's prefix hash is reused
        (incref, no device copy — migration dedups against the
        destination's prefix cache; contents are identical by
        construction since the key is a chain hash of the whole token
        prefix).  Everything else allocates a fresh block, registered
        under its key so the migrated prefix is matchable here.

        Returns ``(block_ids, needs_copy)`` aligned with ``keys``, or
        ``None`` — nothing mutated — when the free list cannot supply the
        fresh blocks (the caller spills or declines the migration).
        """
        hits = [self.peek(k) if k is not None else None for k in keys]
        fresh = sum(1 for h in hits if h is None)
        if fresh > self.free_count:
            return None
        ids, needs = [], []
        for k, hit in zip(keys, hits):
            if hit is not None:
                self.incref(hit)
                ids.append(hit)
                needs.append(False)
                self.stats.import_dedup += 1
            else:
                b = self.alloc()
                if k is not None:
                    self.register(k, b)
                ids.append(b)
                needs.append(True)
        self.stats.imports += len(ids)
        return ids, needs

    # ------------------------------------------------------- prefix caching
    def lookup(self, key: Hashable) -> int | None:
        b = self._key_to_block.get(key)
        if b is not None:
            self.stats.hash_hits += 1
        return b

    def peek(self, key: Hashable) -> int | None:
        """Stat-free :meth:`lookup`: read-only probes (the cluster
        router's prefix-affinity scoring) must not count as cache hits."""
        return self._key_to_block.get(key)

    def register(self, key: Hashable, block: int) -> None:
        # a colliding re-register (identical content written twice) keeps
        # the newest mapping; both directions stay consistent
        old = self._key_to_block.get(key)
        if old is not None:
            self._block_to_key.pop(old, None)
        self._key_to_block[key] = block
        self._block_to_key[block] = key

    def invalidate(self, block: int) -> None:
        """Drop the hash entry for ``block`` (content changed or freed)."""
        key = self._block_to_key.pop(block, None)
        if key is not None:
            self._key_to_block.pop(key, None)

    # ------------------------------------------------------------ host tier
    @property
    def host_in_use(self) -> int:
        return self.host_blocks - len(self._host_free)

    @property
    def host_utilization(self) -> float:
        return self.host_in_use / max(self.host_blocks, 1)

    def _host_reserve(self) -> int | None:
        """Take a host block id, LRU-evicting an unreferenced cold host
        block under pressure.  None when every host block is ref-held."""
        if not self._host_free:
            if not self._host_lru:
                return None
            victim = self._host_lru.pop(0)
            self.host_invalidate(victim)
            self._host_free.append(victim)
            self.stats.host_evictions += 1
        hb = self._host_free.pop()
        self.stats.host_peak_in_use = max(
            self.stats.host_peak_in_use, self.host_in_use
        )
        return hb

    def host_alloc(self) -> int | None:
        """Take a ref-held host block (live spill).  None when the host
        tier is saturated with ref-held blocks."""
        hb = self._host_reserve()
        if hb is not None:
            self._host_ref[hb] = 1
        return hb

    def host_refcount(self, hb: int) -> int:
        return self._host_ref.get(hb, 0)

    def host_incref(self, hb: int) -> None:
        # a cold (unreferenced) host block becoming ref-held leaves the
        # LRU eviction candidate list
        if self._host_ref.get(hb, 0) == 0 and hb in self._host_lru:
            self._host_lru.remove(hb)
        self._host_ref[hb] = self._host_ref.get(hb, 0) + 1

    def host_decref(self, hb: int) -> None:
        self._host_ref[hb] -= 1
        if self._host_ref[hb] == 0:
            del self._host_ref[hb]
            if hb in self._host_to_key:
                # registered prefix: keep as an evictable cold cache entry
                self._host_lru.append(hb)
            else:
                self._host_free.append(hb)

    def host_lookup(self, key: Hashable) -> int | None:
        hb = self._key_to_host.get(key)
        if hb is not None:
            self.stats.hash_hits += 1
        return hb

    def host_peek(self, key: Hashable) -> int | None:
        """Stat-free :meth:`host_lookup` for read-only probes."""
        return self._key_to_host.get(key)

    def host_register(self, key: Hashable, hb: int) -> None:
        old = self._key_to_host.get(key)
        if old is not None:
            self._host_to_key.pop(old, None)
        self._key_to_host[key] = hb
        self._host_to_key[hb] = key

    def host_invalidate(self, hb: int) -> None:
        key = self._host_to_key.pop(hb, None)
        if key is not None:
            self._key_to_host.pop(key, None)

    def drain_directives(self) -> list[tuple]:
        """Hand the pending device<->host copy directives to the engine
        (cleared here; the engine must apply them before the next write
        to any involved device block)."""
        out, self.directives = self.directives, []
        return out


def chain_key(prev: Hashable, block_tokens: tuple[int, ...]) -> Hashable:
    """Prefix-chain hash key: identifies a block by the whole token prefix
    ending in it (tuple length distinguishes partial from full blocks)."""
    return (prev, block_tokens)
