"""Jitted device ops on the physical block pool arrays.

The pool K/V leaves are laid out kernel-native, ``(layers, n_blocks,
kv_heads, block_size, head_dim)`` (``models.*.paged_cache_defs``, heads
before positions so decode attention streams it without relayout); all
host-side
allocator decisions reduce to three device primitives: scatter a prefill
slice into a block, duplicate a block (copy-on-write), and refresh one
block-table row.  Block ids arrive as traced scalars so admission never
recompiles.

Two whole-block transfer families ride the same layout: host-tier
moves (:func:`spill_block` / :func:`rehydrate_block`, device<->host in
storage dtype) and cross-replica migration
(:func:`copy_blocks_out` gathers a block-id list into a compact
payload, :func:`copy_blocks_in` scatters it into the destination pool —
quantized pools move payload + scale pools as-is, bit-exact, no
dequant/requant round trip).

The module also hosts the async engine's tiny per-slot state vectors
(:func:`feed_token` token feedback, :func:`set_stop_id` stop flags):
same donated, recompile-free update pattern, shared by both cache kinds.
"""
from __future__ import annotations

import functools
import warnings
from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any

# the pool argument is donated: these are in-place block updates and the
# engine always replaces its cache reference, so XLA may alias in->out
# instead of copying the whole (L, n_blocks, ...) pool per call.  The CPU
# backend does not implement donation and warns every compile; that
# fallback (a copy) is exactly the pre-donation behavior, so silence it.
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable"
)
_donate0 = functools.partial(jax.jit, donate_argnums=(0,))


@_donate0
def _copy_block(pool: jax.Array, src, dst) -> jax.Array:
    return pool.at[:, dst].set(pool[:, src])


@_donate0
def _write_block(pool: jax.Array, sub: jax.Array, phys, start, lane) -> jax.Array:
    """Copy ``sub[:, lane, start:start+block_size]`` into pool block
    ``phys``.

    The prefill sub-cache is sequence-major (L, lanes, S, Hkv, Dh); one
    block's worth is transposed to the pool's heads-major layout here —
    a (block_size, Hkv) tile per layer, negligible next to the pool.
    ``lane`` is a traced scalar: boundary packing runs two prefills in
    the same staging cache and drains either lane without recompiling.
    """
    bs = pool.shape[3]
    blk = jax.lax.dynamic_slice_in_dim(sub[:, lane], start, bs, axis=1)
    blk = jnp.swapaxes(blk, 1, 2)                 # (L, Hkv, bs, Dh)
    return jax.lax.dynamic_update_slice(
        pool, blk[:, None].astype(pool.dtype), (0, phys, 0, 0, 0)
    )


@functools.partial(
    jax.jit, donate_argnums=(0, 1), static_argnames=("kv_dtype",)
)
def _write_block_q(
    pool: jax.Array, spool: jax.Array, sub: jax.Array, phys, start, lane,
    *, kv_dtype: str,
) -> tuple[jax.Array, jax.Array]:
    """Quantizing :func:`_write_block`: the bf16 staging tile quantizes
    per (head, position) vector on the way into the pool; the scale pool
    gets the matching (L, Hkv, bs) tile."""
    from repro.kernels import ref

    bs = pool.shape[3]
    blk = jax.lax.dynamic_slice_in_dim(sub[:, lane], start, bs, axis=1)
    blk = jnp.swapaxes(blk, 1, 2)                 # (L, Hkv, bs, Dh)
    payload, scale = ref.kv_quantize(blk, kv_dtype)
    pool = jax.lax.dynamic_update_slice(pool, payload[:, None], (0, phys, 0, 0, 0))
    spool = jax.lax.dynamic_update_slice(spool, scale[:, None], (0, phys, 0, 0))
    return pool, spool


def copy_block(cache: Pytree, src: int, dst: int) -> Pytree:
    """COW: duplicate physical block ``src`` into ``dst`` (k and v, and
    their scale blocks when the pool is quantized)."""
    out = {
        **cache,
        "k": _copy_block(cache["k"], src, dst),
        "v": _copy_block(cache["v"], src, dst),
    }
    if "k_scale" in cache:
        out["k_scale"] = _copy_block(cache["k_scale"], src, dst)
        out["v_scale"] = _copy_block(cache["v_scale"], src, dst)
    return out


def write_prompt_block(
    cache: Pytree, sub_cache: Pytree, phys: int, start: int, lane: int = 0,
) -> Pytree:
    """Scatter prompt KV positions ``[start, start+block_size)`` from a
    prefill staging lane (seq padded to a block multiple) into physical
    block ``phys`` — quantizing on the way in when the pool is int8/fp8
    (the staging cache always holds full-precision KV)."""
    if "k_scale" in cache:
        kv_dtype = "int8" if cache["k"].dtype == jnp.int8 else "fp8"
        k, ks = _write_block_q(
            cache["k"], cache["k_scale"], sub_cache["k"], phys, start, lane,
            kv_dtype=kv_dtype,
        )
        v, vs = _write_block_q(
            cache["v"], cache["v_scale"], sub_cache["v"], phys, start, lane,
            kv_dtype=kv_dtype,
        )
        return {**cache, "k": k, "v": v, "k_scale": ks, "v_scale": vs}
    return {
        **cache,
        "k": _write_block(cache["k"], sub_cache["k"], phys, start, lane),
        "v": _write_block(cache["v"], sub_cache["v"], phys, start, lane),
    }


@_donate0
def _read_block(sub: jax.Array, pool: jax.Array, phys, start, lane) -> jax.Array:
    """Inverse of ``_write_block``: copy pool block ``phys`` into staging
    lane ``lane`` at positions [start, start+block_size)."""
    blk = jnp.swapaxes(pool[:, phys], 1, 2)[:, None]   # (L, 1, bs, Hkv, Dh)
    return jax.lax.dynamic_update_slice(
        sub, blk.astype(sub.dtype), (0, lane, start, 0, 0)
    )


@_donate0
def _read_block_q(
    sub: jax.Array, pool: jax.Array, spool: jax.Array, phys, start, lane,
) -> jax.Array:
    """Dequantizing :func:`_read_block` for int8/fp8 pools."""
    from repro.kernels import ref

    blk = ref.kv_dequantize(pool[:, phys], spool[:, phys], sub.dtype)
    blk = jnp.swapaxes(blk, 1, 2)[:, None]             # (L, 1, bs, Hkv, Dh)
    return jax.lax.dynamic_update_slice(sub, blk, (0, lane, start, 0, 0))


def read_block(
    sub_cache: Pytree, cache: Pytree, phys: int, start: int, lane: int = 0,
) -> Pytree:
    """Hydrate a prefill staging lane from a prefix-cache-hit block, so
    chunked-prefill attention sees the shared prefix's K/V without
    recomputing it.  Quantized pools dequantize on the way out (staging
    stays full precision)."""
    if "k_scale" in cache:
        return {
            **sub_cache,
            "k": _read_block_q(
                sub_cache["k"], cache["k"], cache["k_scale"], phys, start, lane
            ),
            "v": _read_block_q(
                sub_cache["v"], cache["v"], cache["v_scale"], phys, start, lane
            ),
        }
    return {
        **sub_cache,
        "k": _read_block(sub_cache["k"], cache["k"], phys, start, lane),
        "v": _read_block(sub_cache["v"], cache["v"], phys, start, lane),
    }


@_donate0
def _xfer_block(dst_pool: jax.Array, src_pool: jax.Array, src, dst) -> jax.Array:
    """Copy one block between two pools with the same trailing layout
    (device<->host spill traffic; payloads move in storage dtype, so a
    quantized block spills quantized — 1 byte/elem over the slow link)."""
    return dst_pool.at[:, dst].set(src_pool[:, src].astype(dst_pool.dtype))


def spill_block(cache: Pytree, dev: int, host: int) -> Pytree:
    """Apply a ``("spill", dev, host)`` directive: copy device block
    ``dev`` into host-tier block ``host`` (k, v, and scales)."""
    out = {
        **cache,
        "host_k": _xfer_block(cache["host_k"], cache["k"], dev, host),
        "host_v": _xfer_block(cache["host_v"], cache["v"], dev, host),
    }
    if "k_scale" in cache:
        out["host_k_scale"] = _xfer_block(cache["host_k_scale"], cache["k_scale"], dev, host)
        out["host_v_scale"] = _xfer_block(cache["host_v_scale"], cache["v_scale"], dev, host)
    return out


def rehydrate_block(cache: Pytree, host: int, dev: int) -> Pytree:
    """Apply a ``("rehydrate", host, dev)`` directive: copy host-tier
    block ``host`` back into device block ``dev``."""
    out = {
        **cache,
        "k": _xfer_block(cache["k"], cache["host_k"], host, dev),
        "v": _xfer_block(cache["v"], cache["host_v"], host, dev),
    }
    if "k_scale" in cache:
        out["k_scale"] = _xfer_block(cache["k_scale"], cache["host_k_scale"], host, dev)
        out["v_scale"] = _xfer_block(cache["v_scale"], cache["host_v_scale"], host, dev)
    return out


# NOT donated: the gathered payload must outlive the source pool (the
# exporting engine keeps stepping while the destination lands the copy)
@jax.jit
def _gather_blocks(pool: jax.Array, ids: jax.Array) -> jax.Array:
    return pool[:, ids]


@_donate0
def _scatter_blocks(
    pool: jax.Array, payload: jax.Array, src_sel: jax.Array, dst_ids: jax.Array
) -> jax.Array:
    return pool.at[:, dst_ids].set(payload[:, src_sel].astype(pool.dtype))


def copy_blocks_out(cache: Pytree, ids: list[int]) -> Pytree:
    """Gather a migrating sequence's physical blocks out of this pool in
    **storage dtype**: a quantized pool exports its int8/fp8 payload bytes
    plus the matching scale-pool tiles, so migration across replicas of
    the same ``kv_dtype`` tier is bit-exact (no dequant/requant round
    trip).  Returns a ``{"k": (L, n, Hkv, bs, Dh), ...}`` payload pytree
    detached from the pool (the source keeps stepping afterwards)."""
    idx = jnp.asarray(ids, jnp.int32)
    out = {
        "k": _gather_blocks(cache["k"], idx),
        "v": _gather_blocks(cache["v"], idx),
    }
    if "k_scale" in cache:
        out["k_scale"] = _gather_blocks(cache["k_scale"], idx)
        out["v_scale"] = _gather_blocks(cache["v_scale"], idx)
    return out


def copy_blocks_in(
    cache: Pytree, payload: Pytree, src_sel: list[int], dst_ids: list[int]
) -> Pytree:
    """Scatter payload columns ``src_sel`` (positions in the exported
    block list) into this pool's blocks ``dst_ids``.  The selection lets
    the importer skip positions its own prefix cache already holds
    (``BlockPool.import_blocks`` dedup).  Storage-dtype on both sides:
    same-tier migration moves bytes, never values."""
    sel = jnp.asarray(src_sel, jnp.int32)
    idx = jnp.asarray(dst_ids, jnp.int32)
    out = {
        **cache,
        "k": _scatter_blocks(cache["k"], payload["k"], sel, idx),
        "v": _scatter_blocks(cache["v"], payload["v"], sel, idx),
    }
    if "k_scale" in cache:
        out["k_scale"] = _scatter_blocks(
            cache["k_scale"], payload["k_scale"], sel, idx
        )
        out["v_scale"] = _scatter_blocks(
            cache["v_scale"], payload["v_scale"], sel, idx
        )
    return out


@_donate0
def _set_row(tables: jax.Array, slot, row: jax.Array) -> jax.Array:
    return tables.at[slot].set(row)


# NOT donated: the async engine's pending-step records may still hold a
# reference to the array being updated (it doubles as a step output)
@jax.jit
def _set_scalar(arr: jax.Array, slot, value) -> jax.Array:
    return arr.at[slot].set(value)


def feed_token(tok_state: jax.Array, slot: int, token) -> jax.Array:
    """Async engine: push one slot's next decode input into the
    device-resident token feedback vector (``token`` may be a host int or
    a 0-d device array — a prefill's first sampled token never needs to
    round-trip through the host before the next decode step consumes
    it).  Used for both cache kinds; lives here with the engine's other
    donated per-slot device primitives."""
    return _set_scalar(tok_state, slot, jnp.asarray(token, jnp.int32))


def set_stop_id(eos_ids: jax.Array, slot: int, eos_id: int) -> jax.Array:
    """Refresh one slot's on-device stop id (-1 = never stops).  The
    fused sampled step compares each sampled token against this vector to
    produce the per-slot EOS flag the host observes one step late."""
    return _set_scalar(eos_ids, slot, jnp.int32(eos_id))


def sync_slot(cache: Pytree, slot: int, row, length: int | None = None) -> Pytree:
    """Push one host block-table row (and optionally the slot length) to
    the device cache."""
    out = {
        **cache,
        "block_tables": _set_row(
            cache["block_tables"], slot, jnp.asarray(row, jnp.int32)
        ),
    }
    if length is not None:
        out["lengths"] = out["lengths"].at[slot].set(jnp.int32(length))
    return out


def sync_host_slot(cache: Pytree, slot: int, row, cold_len: int) -> Pytree:
    """Push one slot's host block-table row and cold-prefix length (the
    hot attention window's start) to the device cache."""
    out = {
        **cache,
        "host_tables": _set_row(
            cache["host_tables"], slot, jnp.asarray(row, jnp.int32)
        ),
    }
    out["cold_lengths"] = _set_scalar(out["cold_lengths"], slot, jnp.int32(cold_len))
    return out
