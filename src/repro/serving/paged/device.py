"""Jitted device ops on the physical block pool arrays.

The pool K/V leaves are laid out kernel-native, ``(layers, n_blocks,
kv_heads, block_size, head_dim)`` (``models.*.paged_cache_defs``, heads
before positions so decode attention streams it without relayout); all
host-side
allocator decisions reduce to three device primitives: scatter a prefill
slice into a block, duplicate a block (copy-on-write), and refresh one
block-table row.  Block ids arrive as traced scalars so admission never
recompiles.

The module also hosts the async engine's tiny per-slot state vectors
(:func:`feed_token` token feedback, :func:`set_stop_id` stop flags):
same donated, recompile-free update pattern, shared by both cache kinds.
"""
from __future__ import annotations

import functools
import warnings
from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any

# the pool argument is donated: these are in-place block updates and the
# engine always replaces its cache reference, so XLA may alias in->out
# instead of copying the whole (L, n_blocks, ...) pool per call.  The CPU
# backend does not implement donation and warns every compile; that
# fallback (a copy) is exactly the pre-donation behavior, so silence it.
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable"
)
_donate0 = functools.partial(jax.jit, donate_argnums=(0,))


@_donate0
def _copy_block(pool: jax.Array, src, dst) -> jax.Array:
    return pool.at[:, dst].set(pool[:, src])


@_donate0
def _write_block(pool: jax.Array, sub: jax.Array, phys, start) -> jax.Array:
    """Copy ``sub[:, 0, start:start+block_size]`` into pool block ``phys``.

    The prefill sub-cache is sequence-major (L, 1, S, Hkv, Dh); one
    block's worth is transposed to the pool's heads-major layout here —
    a (block_size, Hkv) tile per layer, negligible next to the pool.
    """
    bs = pool.shape[3]
    blk = jax.lax.dynamic_slice_in_dim(sub[:, 0], start, bs, axis=1)
    blk = jnp.swapaxes(blk, 1, 2)                 # (L, Hkv, bs, Dh)
    return jax.lax.dynamic_update_slice(
        pool, blk[:, None].astype(pool.dtype), (0, phys, 0, 0, 0)
    )


def copy_block(cache: Pytree, src: int, dst: int) -> Pytree:
    """COW: duplicate physical block ``src`` into ``dst`` (k and v)."""
    return {
        **cache,
        "k": _copy_block(cache["k"], src, dst),
        "v": _copy_block(cache["v"], src, dst),
    }


def write_prompt_block(cache: Pytree, sub_cache: Pytree, phys: int, start: int) -> Pytree:
    """Scatter prompt KV positions ``[start, start+block_size)`` from a
    prefill sub-cache (batch 1, seq padded to a block multiple) into
    physical block ``phys``."""
    return {
        **cache,
        "k": _write_block(cache["k"], sub_cache["k"], phys, start),
        "v": _write_block(cache["v"], sub_cache["v"], phys, start),
    }


@_donate0
def _read_block(sub: jax.Array, pool: jax.Array, phys, start) -> jax.Array:
    """Inverse of ``_write_block``: copy pool block ``phys`` into the
    sequence-major staging cache at positions [start, start+block_size)."""
    blk = jnp.swapaxes(pool[:, phys], 1, 2)[:, None]   # (L, 1, bs, Hkv, Dh)
    return jax.lax.dynamic_update_slice(
        sub, blk.astype(sub.dtype), (0, 0, start, 0, 0)
    )


def read_block(sub_cache: Pytree, cache: Pytree, phys: int, start: int) -> Pytree:
    """Hydrate a prefill staging cache from a prefix-cache-hit block, so
    chunked-prefill attention sees the shared prefix's K/V without
    recomputing it."""
    return {
        **sub_cache,
        "k": _read_block(sub_cache["k"], cache["k"], phys, start),
        "v": _read_block(sub_cache["v"], cache["v"], phys, start),
    }


@_donate0
def _set_row(tables: jax.Array, slot, row: jax.Array) -> jax.Array:
    return tables.at[slot].set(row)


# NOT donated: the async engine's pending-step records may still hold a
# reference to the array being updated (it doubles as a step output)
@jax.jit
def _set_scalar(arr: jax.Array, slot, value) -> jax.Array:
    return arr.at[slot].set(value)


def feed_token(tok_state: jax.Array, slot: int, token) -> jax.Array:
    """Async engine: push one slot's next decode input into the
    device-resident token feedback vector (``token`` may be a host int or
    a 0-d device array — a prefill's first sampled token never needs to
    round-trip through the host before the next decode step consumes
    it).  Used for both cache kinds; lives here with the engine's other
    donated per-slot device primitives."""
    return _set_scalar(tok_state, slot, jnp.asarray(token, jnp.int32))


def set_stop_id(eos_ids: jax.Array, slot: int, eos_id: int) -> jax.Array:
    """Refresh one slot's on-device stop id (-1 = never stops).  The
    fused sampled step compares each sampled token against this vector to
    produce the per-slot EOS flag the host observes one step late."""
    return _set_scalar(eos_ids, slot, jnp.int32(eos_id))


def sync_slot(cache: Pytree, slot: int, row, length: int | None = None) -> Pytree:
    """Push one host block-table row (and optionally the slot length) to
    the device cache."""
    out = {
        **cache,
        "block_tables": _set_row(
            cache["block_tables"], slot, jnp.asarray(row, jnp.int32)
        ),
    }
    if length is not None:
        out["lengths"] = out["lengths"].at[slot].set(jnp.int32(length))
    return out
