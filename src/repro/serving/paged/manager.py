"""Per-slot block tables over a shared :class:`BlockPool`.

Host-side logical bookkeeping for the paged cache: which physical blocks
each serving slot owns, in prompt order.  Device arrays (the block pool
itself and the int32 ``block_tables`` the kernels read) are owned by the
engine; the manager only decides ids and hands the engine directives
("copy block a->b", "table row changed").

Admission (``try_admit``) walks the prompt block-by-block through the
pool's prefix hash: matched blocks are shared (incref, no KV write);
the rest are freshly allocated and must be filled from the prefill
pass.  Decode-time appends (``ensure_append``) allocate a block at each
block boundary and copy-on-write a shared tail on the first divergent
append.

With a host tier (``pool.host_blocks > 0``) the matching walks extend to
the pool's *host* prefix hash: a host-resident block re-hydrates into a
fresh device block (a ``("rehydrate", host, dev)`` directive the engine
turns into a device copy) and counts as cached — the prefill compute is
saved even though the device block is new.  Under pool pressure
:meth:`spill_live_prefix` moves a live slot's cold leading blocks the
other way (spill-before-evict): the slot keeps decoding hybrid —
device kernel over its hot window, host path over the spilled prefix —
instead of being preempted and re-prefilled.
"""
from __future__ import annotations

import numpy as np

from repro.serving.paged.block_pool import BlockPool, chain_key


class PagedCacheManager:
    def __init__(self, pool: BlockPool, n_slots: int, max_blocks: int):
        self.pool = pool
        self.n_slots = n_slots
        self.max_blocks = max_blocks
        self.tables = np.zeros((n_slots, max_blocks), np.int32)
        self.blocks: list[list[int]] = [[] for _ in range(n_slots)]
        # hash key backing each owned block (None once content diverges)
        self.keys: list[list] = [[] for _ in range(n_slots)]
        self.admit_seq = [-1] * n_slots   # admission order; max = youngest
        self._counter = 0
        # prompt-wide key chain for a chunked admission in progress
        self._chunk_keys: dict[int, list] = {}
        # host tier: per-slot cold prefix (leading blocks live-spilled to
        # host memory).  host_tables[s, :cold] holds the host block ids;
        # blocks[s][j] == 0 marks a cold position; host_ids[s] are the
        # ref-held host blocks to release at teardown.
        self.host_tables = np.zeros((n_slots, max_blocks), np.int32)
        self.host_ids: list[list[int]] = [[] for _ in range(n_slots)]
        self.cold_blocks = [0] * n_slots

    def cold_len(self, slot: int) -> int:
        """Tokens of ``slot``'s prefix resident on the host tier (the hot
        attention window starts here)."""
        return self.cold_blocks[slot] * self.pool.block_size

    # ------------------------------------------------------------ admission
    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.pool.block_size)

    # ------------------------------------------------------ read-only probes
    def _probe_walk(self, tokens: np.ndarray) -> tuple[int, int]:
        """Stat-free matching walk: ``(device_hits, total_hits)`` in
        blocks, where total includes host-tier hits (re-hydratable: the
        prefill compute is saved, but a fresh device block is still
        consumed)."""
        bs = self.pool.block_size
        need = self.blocks_for(len(tokens))
        key, dev, total = None, 0, 0
        for j in range(need):
            key = chain_key(key, tuple(int(t) for t in tokens[j * bs:(j + 1) * bs]))
            if self.pool.peek(key) is not None:
                dev += 1
                total += 1
            elif self.pool.host_blocks and self.pool.host_peek(key) is not None:
                total += 1
            else:
                break
        return dev, total

    def probe_prefix(self, tokens: np.ndarray) -> int:
        """Longest prefix of ``tokens`` already resident in the pool's
        prefix hash (either tier), in tokens.  Side-effect free: no
        increfs, no allocation, no stats — the cluster router calls this
        on every replica per request to score prefix affinity, and a
        probe must not perturb the replica it does not choose."""
        _, total = self._probe_walk(tokens)
        return min(len(tokens), total * self.pool.block_size)

    def admit_shortfall(self, tokens: np.ndarray) -> int:
        """Fresh blocks an admission of ``tokens`` would allocate right
        now: total blocks minus *device*-resident prefix hits (a host hit
        saves the prefill but still needs a device block to re-hydrate
        into), plus the decode boundary headroom block when the prompt
        exactly fills its blocks.  Read-only (mirrors :meth:`try_admit`'s
        capacity check without mutating anything) — the admission probe
        behind ``Engine.can_admit``."""
        bs = self.pool.block_size
        need = self.blocks_for(len(tokens))
        dev, _ = self._probe_walk(tokens)
        headroom = 1 if (len(tokens) % bs == 0 and need < self.max_blocks) else 0
        return need - dev + headroom

    def try_admit(self, slot: int, tokens: np.ndarray):
        """Reserve blocks for ``tokens`` in ``slot``.

        Returns ``(block_ids, n_cached)`` — the first ``n_cached`` blocks
        were prefix-cache hits and already hold valid KV — or ``None``
        when the pool cannot supply the fresh blocks (caller waits or
        preempts).  Nothing is mutated on the ``None`` path.
        """
        bs = self.pool.block_size
        need = self.blocks_for(len(tokens))
        if need > self.max_blocks:
            raise ValueError(f"{len(tokens)} tokens > {self.max_blocks} blocks/seq")
        toks = [tuple(int(t) for t in tokens[j * bs:(j + 1) * bs]) for j in range(need)]

        # matched walk over both tiers: (key, device block | None, host
        # block | None).  A host hit re-hydrates into a fresh device
        # block, so only device hits reduce the fresh-block bill.
        matched: list[tuple[object, int | None, int | None]] = []
        key = None
        for j in range(need):
            key = chain_key(key, toks[j])
            b = self.pool.lookup(key)
            if b is not None:
                matched.append((key, b, None))
                continue
            hb = self.pool.host_lookup(key) if self.pool.host_blocks else None
            if hb is None:
                break
            matched.append((key, None, hb))
        n_dev = sum(1 for _, b, _ in matched if b is not None)
        # when the prompt exactly fills its blocks the very first decode
        # append needs a fresh block — reserve it now (not merely check),
        # or a later admission can consume it and the new sequence gets
        # preempted in the same step its prefill just ran
        headroom = 1 if (len(tokens) % bs == 0 and need < self.max_blocks) else 0
        if need - n_dev + headroom > self.pool.free_count:
            return None

        ids, keys = [], []
        for k, b, hb in matched:
            if b is not None:
                self.pool.incref(b)
            else:
                # re-hydrate: fresh device block, KV copied back from host
                b = self.pool.alloc()
                self.pool.directives.append(("rehydrate", hb, b))
                self.pool.register(k, b)
                self.pool.stats.rehydrates += 1
            ids.append(b)
            keys.append(k)
        key = matched[-1][0] if matched else None
        for j in range(len(matched), need):
            key = chain_key(key, toks[j])
            b = self.pool.alloc()
            self.pool.register(key, b)
            ids.append(b)
            keys.append(key)
        if headroom:
            # decode-only block: owned, mapped, but no prompt KV to write
            # and never hash-registered
            ids.append(self.pool.alloc())
            keys.append(None)

        self.blocks[slot] = ids
        self.keys[slot] = keys
        self.tables[slot, :] = 0
        self.tables[slot, :len(ids)] = ids
        self.admit_seq[slot] = self._counter
        self._counter += 1
        # prompt blocks only (copy: the internal list mutates later) —
        # the caller fills blocks[n_cached:need] from the prefill pass
        return list(ids[:need]), len(matched)

    # -------------------------------------------- chunked (partial) admission
    def begin_chunked(self, slot: int, tokens: np.ndarray) -> list[int]:
        """Start a chunked admission: share the prefix-cache hit blocks
        only (increfs, no allocation — cannot fail for lack of blocks);
        fresh blocks are acquired chunk-by-chunk via
        :meth:`extend_chunked`.  Returns the matched physical block ids
        (their KV is already valid and must be copied into the prefill
        staging cache)."""
        bs = self.pool.block_size
        need = self.blocks_for(len(tokens))
        if need > self.max_blocks:
            raise ValueError(f"{len(tokens)} tokens > {self.max_blocks} blocks/seq")
        toks = [tuple(int(t) for t in tokens[j * bs:(j + 1) * bs]) for j in range(need)]
        chain, key = [], None
        for j in range(need):
            key = chain_key(key, toks[j])
            chain.append(key)

        matched: list[int] = []
        for j in range(need):
            b = self.pool.lookup(chain[j])
            if b is not None:
                self.pool.incref(b)
            else:
                # host-tier hit: re-hydrate when a free device block is
                # available now; otherwise stop the walk (shorter prefix
                # hit — begin_chunked must stay unable to fail)
                hb = self.pool.host_lookup(chain[j]) if self.pool.host_blocks else None
                if hb is None or self.pool.free_count == 0:
                    break
                b = self.pool.alloc()   # refcount 1, no incref needed
                self.pool.directives.append(("rehydrate", hb, b))
                self.pool.register(chain[j], b)
                self.pool.stats.rehydrates += 1
            matched.append(b)

        self.blocks[slot] = list(matched)
        self.keys[slot] = chain[:len(matched)]
        self.tables[slot, :] = 0
        self.tables[slot, :len(matched)] = matched
        self.admit_seq[slot] = self._counter
        self._counter += 1
        self._chunk_keys[slot] = chain
        return matched

    def extend_chunked(self, slot: int, n_prompt: int, end: int, final: bool) -> bool:
        """Acquire the fresh blocks one chunk needs: enough to cover
        prompt positions ``< end``, plus the decode boundary block when
        the *final* chunk exactly fills its blocks (the headroom
        reservation, deferred from admission to the last chunk).  Returns
        False (side-effect free) when the pool cannot supply them now —
        the chunk stalls and is retried while decode keeps running."""
        bs = self.pool.block_size
        chain = self._chunk_keys[slot]
        have = len(self.blocks[slot])
        need = self.blocks_for(end)
        headroom = 1 if (
            final and n_prompt % bs == 0 and self.blocks_for(n_prompt) < self.max_blocks
        ) else 0
        fresh = max(0, need - have) + headroom
        if fresh > self.pool.free_count:
            return False
        for j in range(have, need):
            b = self.pool.alloc()
            self.pool.register(chain[j], b)
            self.blocks[slot].append(b)
            self.keys[slot].append(chain[j])
            self.tables[slot, j] = b
        if headroom:
            # decode-only block: owned, mapped, never hash-registered
            b = self.pool.alloc()
            self.blocks[slot].append(b)
            self.keys[slot].append(None)
            self.tables[slot, len(self.blocks[slot]) - 1] = b
        if final:
            self._chunk_keys.pop(slot, None)
        return True

    # ------------------------------------------------------------ live spill
    def spill_live_prefix(self, slot: int, length: int) -> bool:
        """Spill ``slot``'s oldest hot block to the host tier, freeing one
        device block without preempting the sequence (spill-before-evict).

        ``length`` is the slot's current KV length.  Only a *full* block
        strictly below the append block qualifies (the hot attention
        window must keep covering the append position), and only a
        privately-owned one (a shared block is attended hot by its other
        owners, who cannot follow it to the host tier).  Returns False
        when no block qualifies or the host tier is saturated — the
        caller falls back to preemption.
        """
        bs = self.pool.block_size
        j = self.cold_blocks[slot]
        if j >= length // bs or j >= len(self.blocks[slot]):
            return False
        b = self.blocks[slot][j]
        if self.pool.refcount(b) != 1:
            return False
        hb = self.pool.host_alloc()
        if hb is None:
            return False
        key = self.keys[slot][j]
        self.pool.directives.append(("spill", b, hb))
        if key is not None and self.pool.host_peek(key) is None:
            # the prefix stays matchable for future prompts, now host-side
            self.pool.host_register(key, hb)
        # drop the device hash entry *before* decref so the free path
        # does not auto-spill a second copy
        self.pool.invalidate(b)
        self.pool.decref(b)   # privately owned: frees the device block
        self.pool.stats.spills += 1
        self.blocks[slot][j] = 0
        self.keys[slot][j] = None
        self.tables[slot, j] = 0
        self.host_tables[slot, j] = hb
        self.host_ids[slot].append(hb)
        self.cold_blocks[slot] = j + 1
        return True

    # --------------------------------------------------------------- decode
    def ensure_append(self, slot: int, length: int):
        """Make position ``length`` of ``slot`` writable before a decode
        step appends there.

        Returns one of::

            ("ready", None)        tail block private, in-place append ok
            ("new",   block)       fresh block mapped at the boundary
            ("cow",   (src, dst))  shared tail duplicated; engine must
                                   device-copy src -> dst
            ("oom",   None)        pool dry; caller preempts and retries
        """
        bs = self.pool.block_size
        idx, off = length // bs, length % bs
        if off == 0:
            if idx < len(self.blocks[slot]):
                # boundary block already reserved at admission (exact-
                # multiple prompt): private, empty, nothing to invalidate
                return ("ready", None)
            if self.pool.free_count == 0:
                return ("oom", None)
            b = self.pool.alloc()
            self.blocks[slot].append(b)
            self.keys[slot].append(None)
            self.tables[slot, idx] = b
            return ("new", b)
        tail = self.blocks[slot][idx]
        if self.pool.refcount(tail) > 1:
            if self.pool.free_count == 0:
                return ("oom", None)
            dst = self.pool.alloc()
            self.pool.decref(tail)   # remaining owners keep the original
            self.blocks[slot][idx] = dst
            self.keys[slot][idx] = None
            self.tables[slot, idx] = dst
            self.pool.stats.cow_copies += 1
            return ("cow", (tail, dst))
        # private tail: appending mutates content, so its hash entry
        # (keyed to the old prefix) must not match future prompts
        self.pool.invalidate(tail)
        self.keys[slot][idx] = None
        return ("ready", None)

    # ------------------------------------------------------------- migration
    def export_slot(self, slot: int) -> tuple[list[int], list]:
        """Detach ``slot``'s blocks for migration to a peer replica.

        Returns ``(block_ids, keys)`` — the physical ids to gather
        (``device.copy_blocks_out``) and the hash-key chain describing
        them (the import ticket; None entries are diverged tails or
        decode headroom).  The blocks are released pool-side via
        :meth:`BlockPool.export_blocks` (shared-prefix blocks stay with
        their remaining owners — copy-on-export), and the slot's
        bookkeeping resets without the decrefs :meth:`free_slot` would
        double-apply.  Callers must reject slots with a cold (host-tier)
        prefix first: only device-resident sequences migrate.
        """
        if self.cold_blocks[slot]:
            raise ValueError(f"slot {slot} has a cold host-tier prefix")
        ids = list(self.blocks[slot])
        keys = list(self.keys[slot])
        self.pool.export_blocks(ids)
        self.blocks[slot] = []
        self.keys[slot] = []
        self.tables[slot, :] = 0
        self.admit_seq[slot] = -1
        self._chunk_keys.pop(slot, None)
        return ids, keys

    def import_shortfall(self, keys: list, length: int) -> int:
        """Fresh blocks an import of ``(keys, length)`` would allocate
        right now (read-only mirror of :meth:`import_slot`'s capacity
        check, including the decode-boundary headroom block)."""
        keys = self._with_headroom(keys, length)
        return sum(1 for k in keys if k is None or self.pool.peek(k) is None)

    def _with_headroom(self, keys: list, length: int) -> list:
        """Append the decode-boundary headroom key when the migrated KV
        exactly fills its blocks and no block covers the append position —
        mirroring ``try_admit``'s reservation so the destination's first
        decode append never lands on a dry pool."""
        bs = self.pool.block_size
        keys = list(keys)
        if (length % bs == 0 and len(keys) == length // bs
                and len(keys) < self.max_blocks):
            keys.append(None)
        return keys

    def import_slot(
        self, slot: int, keys: list, length: int
    ) -> tuple[list[int], list[bool]] | None:
        """Land a migrating sequence in ``slot``: allocate/dedup blocks
        for its key chain (:meth:`BlockPool.import_blocks`), reserve the
        decode-boundary headroom block when needed, and install the block
        table.  Returns ``(block_ids, needs_copy)`` aligned with the
        *original* ``keys`` plus any trailing headroom block (headroom has
        no payload column to copy), or ``None`` — nothing mutated — when
        the pool cannot supply the fresh blocks."""
        keys = self._with_headroom(keys, length)
        res = self.pool.import_blocks(keys)
        if res is None:
            return None
        ids, needs = res
        self.blocks[slot] = list(ids)
        self.keys[slot] = list(keys)
        self.tables[slot, :] = 0
        self.tables[slot, :len(ids)] = ids
        self.admit_seq[slot] = self._counter
        self._counter += 1
        return ids, needs

    # ------------------------------------------------------------- teardown
    def free_slot(self, slot: int) -> None:
        for b in self.blocks[slot]:
            if b:   # 0 marks a live-spilled (cold) position
                self.pool.decref(b)
        for hb in self.host_ids[slot]:
            # registered host blocks demote to the evictable cold cache;
            # unregistered duplicates free outright
            self.pool.host_decref(hb)
        self.blocks[slot] = []
        self.keys[slot] = []
        self.tables[slot, :] = 0
        self.admit_seq[slot] = -1
        self._chunk_keys.pop(slot, None)
        self.host_tables[slot, :] = 0
        self.host_ids[slot] = []
        self.cold_blocks[slot] = 0

    def youngest(self, slots) -> int:
        return max(slots, key=lambda s: self.admit_seq[s])
