"""Per-slot block tables over a shared :class:`BlockPool`.

Host-side logical bookkeeping for the paged cache: which physical blocks
each serving slot owns, in prompt order.  Device arrays (the block pool
itself and the int32 ``block_tables`` the kernels read) are owned by the
engine; the manager only decides ids and hands the engine directives
("copy block a->b", "table row changed").

Admission (``try_admit``) walks the prompt block-by-block through the
pool's prefix hash: matched blocks are shared (incref, no KV write);
the rest are freshly allocated and must be filled from the prefill
pass.  Decode-time appends (``ensure_append``) allocate a block at each
block boundary and copy-on-write a shared tail on the first divergent
append.
"""
from __future__ import annotations

import numpy as np

from repro.serving.paged.block_pool import BlockPool, chain_key


class PagedCacheManager:
    def __init__(self, pool: BlockPool, n_slots: int, max_blocks: int):
        self.pool = pool
        self.n_slots = n_slots
        self.max_blocks = max_blocks
        self.tables = np.zeros((n_slots, max_blocks), np.int32)
        self.blocks: list[list[int]] = [[] for _ in range(n_slots)]
        # hash key backing each owned block (None once content diverges)
        self.keys: list[list] = [[] for _ in range(n_slots)]
        self.admit_seq = [-1] * n_slots   # admission order; max = youngest
        self._counter = 0
        # prompt-wide key chain for a chunked admission in progress
        self._chunk_keys: dict[int, list] = {}

    # ------------------------------------------------------------ admission
    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.pool.block_size)

    # ------------------------------------------------------ read-only probes
    def probe_prefix(self, tokens: np.ndarray) -> int:
        """Longest prefix of ``tokens`` already resident in the pool's
        prefix hash, in tokens.  Side-effect free: no increfs, no
        allocation, no stats — the cluster router calls this on every
        replica per request to score prefix affinity, and a probe must
        not perturb the replica it does not choose."""
        bs = self.pool.block_size
        need = self.blocks_for(len(tokens))
        key, hit = None, 0
        for j in range(need):
            key = chain_key(key, tuple(int(t) for t in tokens[j * bs:(j + 1) * bs]))
            if self.pool.peek(key) is None:
                break
            hit = min(len(tokens), (j + 1) * bs)
        return hit

    def admit_shortfall(self, tokens: np.ndarray) -> int:
        """Fresh blocks an admission of ``tokens`` would allocate right
        now: total blocks minus resident prefix hits, plus the decode
        boundary headroom block when the prompt exactly fills its blocks.
        Read-only (mirrors :meth:`try_admit`'s capacity check without
        mutating anything) — the admission probe behind
        ``Engine.can_admit``."""
        bs = self.pool.block_size
        need = self.blocks_for(len(tokens))
        hit = self.probe_prefix(tokens)
        matched = need if hit >= len(tokens) else hit // bs
        headroom = 1 if (len(tokens) % bs == 0 and need < self.max_blocks) else 0
        return need - matched + headroom

    def try_admit(self, slot: int, tokens: np.ndarray):
        """Reserve blocks for ``tokens`` in ``slot``.

        Returns ``(block_ids, n_cached)`` — the first ``n_cached`` blocks
        were prefix-cache hits and already hold valid KV — or ``None``
        when the pool cannot supply the fresh blocks (caller waits or
        preempts).  Nothing is mutated on the ``None`` path.
        """
        bs = self.pool.block_size
        need = self.blocks_for(len(tokens))
        if need > self.max_blocks:
            raise ValueError(f"{len(tokens)} tokens > {self.max_blocks} blocks/seq")
        toks = [tuple(int(t) for t in tokens[j * bs:(j + 1) * bs]) for j in range(need)]

        matched: list[tuple[int, object]] = []
        key = None
        for j in range(need):
            key = chain_key(key, toks[j])
            b = self.pool.lookup(key)
            if b is None:
                break
            matched.append((b, key))
        # when the prompt exactly fills its blocks the very first decode
        # append needs a fresh block — reserve it now (not merely check),
        # or a later admission can consume it and the new sequence gets
        # preempted in the same step its prefill just ran
        headroom = 1 if (len(tokens) % bs == 0 and need < self.max_blocks) else 0
        if need - len(matched) + headroom > self.pool.free_count:
            return None

        ids, keys = [], []
        for b, k in matched:
            self.pool.incref(b)
            ids.append(b)
            keys.append(k)
        key = matched[-1][1] if matched else None
        for j in range(len(matched), need):
            key = chain_key(key, toks[j])
            b = self.pool.alloc()
            self.pool.register(key, b)
            ids.append(b)
            keys.append(key)
        if headroom:
            # decode-only block: owned, mapped, but no prompt KV to write
            # and never hash-registered
            ids.append(self.pool.alloc())
            keys.append(None)

        self.blocks[slot] = ids
        self.keys[slot] = keys
        self.tables[slot, :] = 0
        self.tables[slot, :len(ids)] = ids
        self.admit_seq[slot] = self._counter
        self._counter += 1
        # prompt blocks only (copy: the internal list mutates later) —
        # the caller fills blocks[n_cached:need] from the prefill pass
        return list(ids[:need]), len(matched)

    # -------------------------------------------- chunked (partial) admission
    def begin_chunked(self, slot: int, tokens: np.ndarray) -> list[int]:
        """Start a chunked admission: share the prefix-cache hit blocks
        only (increfs, no allocation — cannot fail for lack of blocks);
        fresh blocks are acquired chunk-by-chunk via
        :meth:`extend_chunked`.  Returns the matched physical block ids
        (their KV is already valid and must be copied into the prefill
        staging cache)."""
        bs = self.pool.block_size
        need = self.blocks_for(len(tokens))
        if need > self.max_blocks:
            raise ValueError(f"{len(tokens)} tokens > {self.max_blocks} blocks/seq")
        toks = [tuple(int(t) for t in tokens[j * bs:(j + 1) * bs]) for j in range(need)]
        chain, key = [], None
        for j in range(need):
            key = chain_key(key, toks[j])
            chain.append(key)

        matched: list[int] = []
        for j in range(need):
            b = self.pool.lookup(chain[j])
            if b is None:
                break
            matched.append(b)
        for b in matched:
            self.pool.incref(b)

        self.blocks[slot] = list(matched)
        self.keys[slot] = chain[:len(matched)]
        self.tables[slot, :] = 0
        self.tables[slot, :len(matched)] = matched
        self.admit_seq[slot] = self._counter
        self._counter += 1
        self._chunk_keys[slot] = chain
        return matched

    def extend_chunked(self, slot: int, n_prompt: int, end: int, final: bool) -> bool:
        """Acquire the fresh blocks one chunk needs: enough to cover
        prompt positions ``< end``, plus the decode boundary block when
        the *final* chunk exactly fills its blocks (the headroom
        reservation, deferred from admission to the last chunk).  Returns
        False (side-effect free) when the pool cannot supply them now —
        the chunk stalls and is retried while decode keeps running."""
        bs = self.pool.block_size
        chain = self._chunk_keys[slot]
        have = len(self.blocks[slot])
        need = self.blocks_for(end)
        headroom = 1 if (
            final and n_prompt % bs == 0 and self.blocks_for(n_prompt) < self.max_blocks
        ) else 0
        fresh = max(0, need - have) + headroom
        if fresh > self.pool.free_count:
            return False
        for j in range(have, need):
            b = self.pool.alloc()
            self.pool.register(chain[j], b)
            self.blocks[slot].append(b)
            self.keys[slot].append(chain[j])
            self.tables[slot, j] = b
        if headroom:
            # decode-only block: owned, mapped, never hash-registered
            b = self.pool.alloc()
            self.blocks[slot].append(b)
            self.keys[slot].append(None)
            self.tables[slot, len(self.blocks[slot]) - 1] = b
        if final:
            self._chunk_keys.pop(slot, None)
        return True

    # --------------------------------------------------------------- decode
    def ensure_append(self, slot: int, length: int):
        """Make position ``length`` of ``slot`` writable before a decode
        step appends there.

        Returns one of::

            ("ready", None)        tail block private, in-place append ok
            ("new",   block)       fresh block mapped at the boundary
            ("cow",   (src, dst))  shared tail duplicated; engine must
                                   device-copy src -> dst
            ("oom",   None)        pool dry; caller preempts and retries
        """
        bs = self.pool.block_size
        idx, off = length // bs, length % bs
        if off == 0:
            if idx < len(self.blocks[slot]):
                # boundary block already reserved at admission (exact-
                # multiple prompt): private, empty, nothing to invalidate
                return ("ready", None)
            if self.pool.free_count == 0:
                return ("oom", None)
            b = self.pool.alloc()
            self.blocks[slot].append(b)
            self.keys[slot].append(None)
            self.tables[slot, idx] = b
            return ("new", b)
        tail = self.blocks[slot][idx]
        if self.pool.refcount(tail) > 1:
            if self.pool.free_count == 0:
                return ("oom", None)
            dst = self.pool.alloc()
            self.pool.decref(tail)   # remaining owners keep the original
            self.blocks[slot][idx] = dst
            self.keys[slot][idx] = None
            self.tables[slot, idx] = dst
            self.pool.stats.cow_copies += 1
            return ("cow", (tail, dst))
        # private tail: appending mutates content, so its hash entry
        # (keyed to the old prefix) must not match future prompts
        self.pool.invalidate(tail)
        self.keys[slot][idx] = None
        return ("ready", None)

    # ------------------------------------------------------------- teardown
    def free_slot(self, slot: int) -> None:
        for b in self.blocks[slot]:
            self.pool.decref(b)
        self.blocks[slot] = []
        self.keys[slot] = []
        self.tables[slot, :] = 0
        self.admit_seq[slot] = -1
        self._chunk_keys.pop(slot, None)

    def youngest(self, slots) -> int:
        return max(slots, key=lambda s: self.admit_seq[s])
