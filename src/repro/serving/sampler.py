"""Token sampling (greedy / temperature / top-k), pure JAX."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0   # 0 -> greedy
    top_k: int = 0             # 0 -> no truncation


def sample(logits: jax.Array, rng: jax.Array, cfg: SamplerConfig) -> jax.Array:
    """logits (B, V) -> tokens (B,) int32."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / cfg.temperature
    if cfg.top_k > 0:
        kth = jax.lax.top_k(logits, cfg.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(rng, logits, axis=-1).astype(jnp.int32)
