"""Token sampling (greedy / temperature / top-k), pure JAX.

Two entry points with identical semantics:

* :func:`sample_on_device` — jit-traceable; the async engine folds it
  into the fused decode / prefill-chunk step so the per-step host
  transfer is ``[batch]`` sampled ids instead of ``[batch, vocab]``
  logits, and the next step can consume the tokens device-to-device.
  ``cfg`` must be a static (hashable) argument under ``jax.jit``.
* :func:`sample` — the host-side oracle the synchronous engine uses;
  ``tests/test_sampler.py`` asserts the two agree token-for-token under
  a fixed rng for greedy, temperature, and top-k configs.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0   # 0 -> greedy
    top_k: int = 0             # 0 -> no truncation


def sample_on_device(logits: jax.Array, rng: jax.Array, cfg: SamplerConfig) -> jax.Array:
    """logits (B, V) -> tokens (B,) int32, traceable inside a jit step.

    The branches below are Python-level on the *static* ``cfg``, so each
    sampler config lowers to a single straight-line program (greedy
    compiles to one argmax — no rng use at all).
    """
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / cfg.temperature
    if cfg.top_k > 0:
        kth = jax.lax.top_k(scaled, cfg.top_k)[0][..., -1:]
        scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
    return jax.random.categorical(rng, scaled, axis=-1).astype(jnp.int32)


def sample(logits: jax.Array, rng: jax.Array, cfg: SamplerConfig) -> jax.Array:
    """Host oracle: logits (B, V) -> tokens (B,) int32.

    Kept as an independent implementation (not a wrapper) so the
    device/host parity test actually compares two code paths.
    """
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / cfg.temperature
    if cfg.top_k > 0:
        kth = jax.lax.top_k(logits, cfg.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(rng, logits, axis=-1).astype(jnp.int32)
