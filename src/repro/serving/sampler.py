"""Token sampling (greedy / temperature / top-k), pure JAX.

Two entry points with identical semantics:

* :func:`sample_on_device` — jit-traceable; the async engine folds it
  into the fused decode / prefill-chunk step so the per-step host
  transfer is ``[batch]`` sampled ids instead of ``[batch, vocab]``
  logits, and the next step can consume the tokens device-to-device.
  ``cfg`` must be a static (hashable) argument under ``jax.jit``.
* :func:`sample` — the host-side oracle the synchronous engine uses;
  ``tests/test_sampler.py`` asserts the two agree token-for-token under
  a fixed rng for greedy, temperature, and top-k configs.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0   # 0 -> greedy
    top_k: int = 0             # 0 -> no truncation


def sample_on_device(logits: jax.Array, rng: jax.Array, cfg: SamplerConfig) -> jax.Array:
    """logits (B, V) -> tokens (B,) int32, traceable inside a jit step.

    The branches below are Python-level on the *static* ``cfg``, so each
    sampler config lowers to a single straight-line program (greedy
    compiles to one argmax — no rng use at all).
    """
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / cfg.temperature
    if cfg.top_k > 0:
        kth = jax.lax.top_k(scaled, cfg.top_k)[0][..., -1:]
        scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
    return jax.random.categorical(rng, scaled, axis=-1).astype(jnp.int32)


def _transformed(logits: jax.Array, cfg: SamplerConfig) -> jax.Array:
    """The sampling distribution's logits under ``cfg`` (temperature > 0):
    the exact transform :func:`sample_on_device` samples from, factored
    out so speculative rejection sampling scores draft and target under
    the *same* modified distribution."""
    scaled = logits.astype(jnp.float32) / cfg.temperature
    if cfg.top_k > 0:
        kth = jax.lax.top_k(scaled, cfg.top_k)[0][..., -1:]
        scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
    return scaled


def spec_draft_sample(
    logits: jax.Array, rng: jax.Array, cfg: SamplerConfig
) -> tuple[jax.Array, jax.Array | None]:
    """Draft proposal for one speculative position.

    logits (B, V) -> (token (B,) int32, probs (B, V) f32 | None).  The
    probs are the draft's full sampling distribution (None for greedy,
    where acceptance is an argmax match and needs no probabilities);
    rejection sampling divides by them, so they must be the distribution
    the token was *actually* drawn from.
    """
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), None
    scaled = _transformed(logits, cfg)
    tok = jax.random.categorical(rng, scaled, axis=-1).astype(jnp.int32)
    return tok, jax.nn.softmax(scaled, axis=-1)


def spec_verify_tokens(
    logits: jax.Array,
    drafts: jax.Array | None,
    draft_probs: jax.Array | None,
    rng: jax.Array,
    cfg: SamplerConfig,
) -> tuple[jax.Array, jax.Array]:
    """Accept/reject k draft tokens against the target's verify logits.

    ``logits`` (B, T, V) with T = k+1: position ``t`` is the target's
    distribution over the successor of verify input ``t``; ``drafts``
    (B, k) are the proposals d_1..d_k (None when k == 0); ``draft_probs``
    (B, k, V) their sampling distributions (None for greedy).  Returns
    ``(emitted (B, T) int32, n_accept (B,) int32)`` where positions
    ``0 .. n_accept`` of ``emitted`` are the step's valid output tokens
    (accepted drafts plus one bonus/correction token) and later positions
    are garbage the caller must ignore.

    Greedy accepts while the draft matches the target argmax, so the
    emitted stream is *token-identical* to non-speculative greedy
    decoding.  With temperature, standard rejection sampling
    (accept d with prob min(1, p_t(d)/p_d(d)), resample rejections from
    the clipped residual ``max(p_t - p_d, 0)``) makes each emitted token
    an exact sample from the target's (temperature/top-k modified)
    distribution regardless of draft quality.
    """
    B, T, V = logits.shape
    k = T - 1
    if cfg.temperature <= 0.0:
        tgt = jnp.argmax(logits, axis=-1).astype(jnp.int32)       # (B, T)
        if k == 0:
            return tgt, jnp.zeros((B,), jnp.int32)
        match = (drafts == tgt[:, :k]).astype(jnp.int32)
        n_accept = jnp.sum(jnp.cumprod(match, axis=1), axis=1)
        return tgt, n_accept
    p_t = jax.nn.softmax(_transformed(logits, cfg), axis=-1)      # (B, T, V)
    r_acc, r_res = jax.random.split(rng)
    bidx = jnp.arange(B)
    if k > 0:
        p_t_d = jnp.take_along_axis(p_t[:, :k], drafts[..., None], -1)[..., 0]
        p_d_d = jnp.take_along_axis(draft_probs, drafts[..., None], -1)[..., 0]
        u = jax.random.uniform(r_acc, (B, k))
        # u < p_t/p_d, written multiplicatively so p_d -> 0 stays finite
        accept = (u * p_d_d < p_t_d).astype(jnp.int32)
        n_accept = jnp.sum(jnp.cumprod(accept, axis=1), axis=1)   # (B,)
        # pad a zero draft distribution at position k: a fully accepted
        # window's bonus token is a direct target sample (residual = p_t)
        q_pad = jnp.concatenate(
            [draft_probs, jnp.zeros((B, 1, V), p_t.dtype)], axis=1
        )
        emitted = jnp.concatenate([drafts, jnp.zeros((B, 1), jnp.int32)], axis=1)
    else:
        n_accept = jnp.zeros((B,), jnp.int32)
        q_pad = jnp.zeros_like(p_t)
        emitted = jnp.zeros((B, 1), jnp.int32)
    p_a = p_t[bidx, n_accept]                                     # (B, V)
    q_a = q_pad[bidx, n_accept]
    resid = jnp.clip(p_a - q_a, 0.0, None)
    denom = jnp.sum(resid, axis=-1, keepdims=True)
    # an exhausted residual (p_t == p_d pointwise) falls back to p_t
    resid = jnp.where(denom > 0, resid / jnp.maximum(denom, 1e-30), p_a)
    bonus = jax.random.categorical(r_res, jnp.log(resid + 1e-30), axis=-1)
    emitted = emitted.at[bidx, n_accept].set(bonus.astype(jnp.int32))
    return emitted, n_accept


def sample(logits: jax.Array, rng: jax.Array, cfg: SamplerConfig) -> jax.Array:
    """Host oracle: logits (B, V) -> tokens (B,) int32.

    Kept as an independent implementation (not a wrapper) so the
    device/host parity test actually compares two code paths.
    """
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / cfg.temperature
    if cfg.top_k > 0:
        kth = jax.lax.top_k(logits, cfg.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(rng, logits, axis=-1).astype(jnp.int32)
