"""Token-budget iteration scheduler (Sarathi-style chunked prefill).

The paper's headline gain comes from keeping the compute-bound and
memory-bound halves of the workload busy *simultaneously*: dense GEMMs on
the GPU while the HPU serves GEMV-shaped decode attention.  The serving
analogue is hybrid batching — each engine iteration carries one decode
token per active slot *plus* up to ``prefill_chunk`` tokens of the
head-of-queue prompt, so a prefill chunk rides along the decode batch's
weight stream instead of stalling it (HGCA / Sarathi-SC; PAPERS.md).

The :class:`Scheduler` owns the request queue and, each iteration, packs
that hybrid batch under a hard **token budget**:

* decode tokens always take priority — every active slot decodes every
  step (the fixed-shape decode batch cannot be split), and the budget
  must cover at least ``n_slots`` tokens;
* whatever budget remains funds at most one prefill chunk of the
  in-flight prompt, clipped to ``prefill_chunk``;
* chunk lengths are padded up to a small **bucket set** (halvings of
  ``prefill_chunk`` down to :data:`MIN_BUCKET`), so every jit shape the
  engine ever sees comes from ``{decode} x {buckets}`` — serving any mix
  of prompt lengths compiles at most ``O(len(buckets))`` programs,
  instead of one whole-prompt prefill program per distinct length.

For the paged cache, non-final chunks are rounded down to end on a KV
block boundary (``block_size``), so a sequence acquires only the blocks
its next chunk needs — partial-prompt admission, shrinking the up-front
boundary-headroom reservation to the final chunk.

The scheduler is purely host-side bookkeeping: the engine executes the
:class:`Decision` (fused model step), then calls :meth:`advance` on the
chunk it actually ran (a paged engine may stall a chunk when the pool is
dry; the scheduler simply re-offers it next iteration).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

MIN_BUCKET = 8


def chunk_buckets(prefill_chunk: int, floor: int = MIN_BUCKET) -> list[int]:
    """Descending bucket set: ``prefill_chunk`` halved down to ``floor``
    (or just ``[prefill_chunk]`` when it is already <= floor)."""
    if prefill_chunk < 1:
        raise ValueError(f"prefill_chunk must be >= 1, got {prefill_chunk}")
    out = [prefill_chunk]
    while out[-1] > floor:
        out.append(max(floor, (out[-1] + 1) // 2))
    return out


@dataclasses.dataclass
class PrefillChunk:
    """One chunk of one prompt: positions [start, start + n_valid)."""

    req: Any
    slot: int
    start: int          # absolute position of the chunk's first token
    n_valid: int        # real tokens in the chunk
    bucket: int         # padded (compiled) chunk length, n_valid <= bucket
    last: bool          # completes the prompt -> sample the first token


@dataclasses.dataclass
class Decision:
    """What one engine iteration runs: the decode batch + one chunk."""

    decode_slots: list[int]
    prefill: PrefillChunk | None

    def tokens_packed(self) -> int:
        return len(self.decode_slots) + (
            self.prefill.n_valid if self.prefill is not None else 0
        )


@dataclasses.dataclass
class _Inflight:
    req: Any
    slot: int
    pos: int            # next unprefilled position
    total: int          # prompt length (incl. re-folded generated tokens)


class Scheduler:
    def __init__(
        self,
        n_slots: int,
        max_seq: int,
        mode: str = "decode-only",
        prefill_chunk: int = 32,
        token_budget: int | None = None,
        block_size: int | None = None,
        spec_width: int = 1,
    ):
        if mode not in ("decode-only", "hybrid"):
            raise ValueError(f"unknown schedule mode {mode!r}")
        if spec_width < 1:
            raise ValueError(f"spec_width must be >= 1, got {spec_width}")
        self.mode = mode
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.prefill_chunk = prefill_chunk
        self.block_size = block_size
        # speculative decoding makes every decode slot a (k+1)-position
        # verify, so each active slot charges spec_width = k+1 budget
        # tokens — a prefill chunk only gets what the verifies leave over
        self.spec_width = spec_width
        self.token_budget = (
            n_slots * spec_width + prefill_chunk
            if token_budget is None else token_budget
        )
        if self.token_budget < n_slots * spec_width:
            raise ValueError(
                f"token_budget={self.token_budget} cannot cover "
                f"{spec_width} verify position(s) per slot "
                f"(n_slots={n_slots}, spec_width={spec_width})"
            )
        if mode == "hybrid" and block_size is not None:
            if prefill_chunk < block_size or prefill_chunk % block_size:
                raise ValueError(
                    f"paged hybrid scheduling needs prefill_chunk "
                    f"({prefill_chunk}) to be a positive multiple of "
                    f"block_size ({block_size})"
                )
        self.buckets = chunk_buckets(prefill_chunk)
        self.queue: deque = deque()
        self.inflight: _Inflight | None = None

    # --------------------------------------------------------------- queue
    def submit(self, req) -> None:
        self.queue.append(req)

    def __len__(self) -> int:
        return len(self.queue)

    def has_work(self) -> bool:
        return bool(self.queue) or self.inflight is not None

    def peek(self):
        return self.queue[0]

    def pop(self):
        return self.queue.popleft()

    def push_front(self, req) -> None:
        """Preempted requests rejoin at the head (exact-recovery FCFS)."""
        self.queue.appendleft(req)

    # ------------------------------------------------------------ chunking
    def begin(self, req, slot: int, start: int, total: int) -> None:
        """Pin ``req`` as the in-flight prefill on ``slot``; its first
        chunk starts at ``start`` (> 0 when a prompt prefix was served
        from the paged prefix cache)."""
        assert self.inflight is None, "one in-flight prefill at a time"
        self.inflight = _Inflight(req=req, slot=slot, pos=start, total=total)

    def pick_bucket(self, n: int) -> int:
        return min(b for b in self.buckets if b >= n)

    def schedule(self, active_slots: list[int]) -> Decision:
        """Pack one iteration: every active slot decodes; leftover budget
        funds one chunk of the in-flight prompt."""
        return self._pack(active_slots)

    def plan_ahead(self, planned_active: list[int]) -> Decision:
        """Async dispatch-ahead path: pack iteration *t+1* while iteration
        *t* is still executing on the device.

        Everything the packing reads is *planned*, not observed, state:
        ``planned_active`` is the engine's predicted active set (length /
        max-new retirements are host-deterministic at dispatch time; EOS
        retirements lag one step and are masked by the engine), and
        ``self.inflight`` already reflects chunks :meth:`advance`-d at
        their dispatch — the chunk *will* run, device data-flow ordering
        guarantees it, so host bookkeeping may run ahead of execution.
        The packing rule itself is identical to :meth:`schedule`; that is
        what keeps ``--async off`` greedy token-identical.
        """
        return self._pack(planned_active)

    def _pack(self, active_slots: list[int]) -> Decision:
        work = None
        if self.mode == "hybrid":
            work = self._make_chunk(
                self.token_budget - len(active_slots) * self.spec_width
            )
        return Decision(decode_slots=list(active_slots), prefill=work)

    def _make_chunk(self, budget: int) -> PrefillChunk | None:
        """Clip the in-flight prompt's next chunk to ``budget`` tokens."""
        fl = self.inflight
        if fl is None or budget <= 0:
            return None
        remaining = fl.total - fl.pos
        n = min(self.prefill_chunk, budget, remaining)
        if self.block_size is not None and 0 < n < remaining:
            # non-final chunks end on a KV block boundary so completed
            # blocks flush to the pool as they fill
            n = (fl.pos + n) // self.block_size * self.block_size - fl.pos
        if n <= 0:
            return None
        return PrefillChunk(
            req=fl.req, slot=fl.slot, start=fl.pos, n_valid=n,
            bucket=self.pick_bucket(n), last=fl.pos + n == fl.total,
        )

    def pack_boundary(self, budget: int) -> PrefillChunk | None:
        """Sarathi-SC boundary packing: when one prompt's *final* partial
        chunk left part of the iteration's budget unused, fund the head
        chunk of the next prompt with the leftover — the engine calls
        this after :meth:`advance`-ing the final chunk and
        :meth:`begin`-ing the next prompt, still inside the same
        iteration, so the token budget stays full across prompt
        boundaries instead of idling for a step."""
        return self._make_chunk(budget)

    def advance(self, work: PrefillChunk) -> None:
        """Commit an executed chunk; the last chunk retires the in-flight
        entry (the engine then owns the now-decoding slot)."""
        fl = self.inflight
        assert fl is not None and fl.pos == work.start
        fl.pos = work.start + work.n_valid
        if work.last:
            self.inflight = None
