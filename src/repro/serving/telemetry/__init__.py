"""Serving telemetry: request-span tracing, step timelines, metrics.

* :class:`Tracer` / :data:`NULL_TRACER` — one span tree per request on
  the engine-step clock (``tracer.py``);
* :class:`StepRecord` / :class:`DispatchCostModel` — per-dispatch
  composition + analytic FLOPs/bytes/OI (``timeline.py``);
* :class:`DispatchProfiler` / :data:`NULL_PROFILER` — sampled fenced
  wall-clock per dispatch, joined with the analytic costs into measured
  MFU/MBU/bandwidth (``profiler.py``);
* :class:`SLOMonitor` — TTFT/TPOT targets, sliding-window attainment,
  goodput (``slo.py``);
* :class:`MetricsRegistry` + builders — the single reporting view over
  engine/cluster stats with exact percentiles (``metrics.py``);
* Perfetto/Chrome-trace and metrics JSON exporters (``export.py``);
* :func:`render_dashboard` — periodic terminal snapshot
  (``dashboard.py``).

Telemetry is zero-cost when disabled (engines default to
:data:`NULL_TRACER` and :data:`NULL_PROFILER`) and — except for the
profiler's explicitly sampled fences — records only at host-side
dispatch/observe boundaries, never inside jit-traced code.
"""
from repro.serving.telemetry.dashboard import render_dashboard
from repro.serving.telemetry.export import (
    build_request_trees,
    to_chrome_trace,
    validate_trace,
    write_metrics,
    write_trace,
)
from repro.serving.telemetry.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    cluster_registry,
    engine_registry,
    percentile,
)
from repro.serving.telemetry.profiler import (
    NULL_PROFILER,
    DispatchProfiler,
    NullDispatchProfiler,
    ProfileSample,
    make_profiler,
)
from repro.serving.telemetry.slo import SLOMonitor
from repro.serving.telemetry.timeline import DispatchCostModel, StepRecord
from repro.serving.telemetry.tracer import (
    NULL_TRACER,
    TRACK_QUEUE,
    TRACK_ROUTER,
    TRACK_STEPS,
    Event,
    NullTracer,
    Span,
    Tracer,
)

__all__ = [
    "NULL_PROFILER",
    "NULL_TRACER",
    "TRACK_QUEUE",
    "TRACK_ROUTER",
    "TRACK_STEPS",
    "Counter",
    "DispatchCostModel",
    "DispatchProfiler",
    "Event",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NullDispatchProfiler",
    "NullTracer",
    "ProfileSample",
    "SLOMonitor",
    "Span",
    "StepRecord",
    "Tracer",
    "build_request_trees",
    "cluster_registry",
    "engine_registry",
    "make_profiler",
    "percentile",
    "render_dashboard",
    "to_chrome_trace",
    "validate_trace",
    "write_metrics",
    "write_trace",
]
