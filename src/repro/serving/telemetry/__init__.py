"""Serving telemetry: request-span tracing, step timelines, metrics.

* :class:`Tracer` / :data:`NULL_TRACER` — one span tree per request on
  the engine-step clock (``tracer.py``);
* :class:`StepRecord` / :class:`DispatchCostModel` — per-dispatch
  composition + analytic FLOPs/bytes/OI (``timeline.py``);
* :class:`MetricsRegistry` + builders — the single reporting view over
  engine/cluster stats with exact percentiles (``metrics.py``);
* Perfetto/Chrome-trace and metrics JSON exporters (``export.py``).

Telemetry is zero-cost when disabled (engines default to
:data:`NULL_TRACER`) and records only at host-side dispatch/observe
boundaries — never inside jit-traced code.
"""
from repro.serving.telemetry.export import (
    build_request_trees,
    to_chrome_trace,
    validate_trace,
    write_metrics,
    write_trace,
)
from repro.serving.telemetry.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    cluster_registry,
    engine_registry,
    percentile,
)
from repro.serving.telemetry.timeline import DispatchCostModel, StepRecord
from repro.serving.telemetry.tracer import (
    NULL_TRACER,
    TRACK_QUEUE,
    TRACK_ROUTER,
    TRACK_STEPS,
    Event,
    NullTracer,
    Span,
    Tracer,
)

__all__ = [
    "NULL_TRACER",
    "TRACK_QUEUE",
    "TRACK_ROUTER",
    "TRACK_STEPS",
    "Counter",
    "DispatchCostModel",
    "Event",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NullTracer",
    "Span",
    "StepRecord",
    "Tracer",
    "build_request_trees",
    "cluster_registry",
    "engine_registry",
    "percentile",
    "to_chrome_trace",
    "validate_trace",
    "write_metrics",
    "write_trace",
]
