"""Terminal dashboard: one periodic snapshot line-block per interval.

``--dashboard N`` on the serve CLI prints this every N driver rounds —
the operator's live view of the same state the trace and metrics record:
per-replica queue depth, active slots, dispatch-ahead pipeline depth,
block-pool / host-tier utilization, generated-token counters, plus the
SLO attainment line (:meth:`SLOMonitor.describe`) and the measured
MFU/MBU line (:meth:`DispatchProfiler.describe`) when those are on.

Pure string rendering over host-side bookkeeping — no device reads, no
extra work recorded into the run being observed.
"""
from __future__ import annotations


def _engine_line(eng) -> str:
    active = sum(s is not None for s in eng.slots)
    line = (f"  r{eng.replica}[{eng.role[0].upper()}] "
            f"queue={len(eng.sched)} active={active}/{len(eng.slots)} "
            f"depth={len(eng._pending)} gen={eng.stats.generated}")
    if eng.cache_kind == "paged":
        line += f" pool={eng.pool.utilization:.2f}"
        if eng.host_blocks:
            line += f" host={eng.pool.host_utilization:.2f}"
    return line


def render_dashboard(serv, round_no: int, slo=None, profiler=None) -> str:
    """Render one snapshot of an Engine or Cluster front-end."""
    engines = getattr(serv, "engines", None) or [serv]
    queue = getattr(serv, "queue", None)
    head = f"[round {round_no}]"
    if queue is not None:
        head += f" global_queue={len(queue)}"
    lines = [head]
    lines.extend(_engine_line(e) for e in engines)
    if slo is not None:
        lines.append("  " + slo.describe())
    if profiler is not None and getattr(profiler, "enabled", False):
        lines.append("  " + profiler.describe())
    return "\n".join(lines)


__all__ = ["render_dashboard"]
