"""Exporters: Perfetto/Chrome-trace JSON, metrics dumps, span-tree views.

The trace format is the Chrome Trace Event JSON flavor Perfetto loads
directly (``ui.perfetto.dev`` -> Open trace file):

* one **process row per replica** (pid = replica index) with one thread
  row per engine slot, plus reserved rows for the admission queue and
  the per-dispatch step timeline;
* request lifecycle spans are complete events (``ph: "X"``), lifecycle
  markers are instant events (``ph: "i"``), and per-dispatch
  composition (operational intensity, budget fill, pool utilization,
  pipeline depth) is emitted both as args on the step-timeline spans and
  as counter tracks (``ph: "C"``) so Perfetto draws them as graphs;
* routing decisions live on a synthetic ``cluster`` process row.

Positions come from the deterministic engine-step clock: one engine step
renders as :data:`TICK_US` microseconds (1 ms), so traces from the same
workload diff cleanly run-to-run.  Wall-clock stamps, when the tracer
recorded them (``Tracer(wall=True)``), ride along in each event's args —
annotations, not positions, because the async engine records completions
at observe time, where wall timestamps would misplace spans that
actually overlapped on device.

:func:`validate_trace` is the small schema both the tests and the CI
traced-serve smoke assert against; :func:`build_request_trees` folds the
flat span/event lists back into one tree per request for structural
checks.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Any

from repro.serving.telemetry.tracer import (
    TRACK_QUEUE,
    TRACK_ROUTER,
    TRACK_STEPS,
    Event,
    Span,
    Tracer,
)

TICK_US = 1000          # one engine step = 1000 us = 1 ms in the trace
CLUSTER_PID = 10_000    # synthetic process row for router decisions

_PH_ALLOWED = {"X", "i", "C", "M"}


# ------------------------------------------------------------- chrome trace
def _meta(pid: int, tid: int | None, name: str) -> dict:
    ev: dict[str, Any] = {
        "name": "process_name" if tid is None else "thread_name",
        "ph": "M", "pid": pid, "tid": 0 if tid is None else tid, "ts": 0,
        "args": {"name": name},
    }
    return ev


def _span_event(s: Span) -> dict:
    end = s.end if s.end is not None else s.start
    args: dict[str, Any] = {"uid": s.uid, "start_step": s.start,
                            "end_step": end, **s.attrs}
    if s.t_start is not None:
        args["wall_start"] = s.t_start
    if s.t_end is not None:
        args["wall_end"] = s.t_end
    return {
        "name": f"{s.name} u{s.uid}" if s.uid >= 0 else s.name,
        "cat": "request", "ph": "X", "pid": s.replica, "tid": s.track,
        "ts": s.start * TICK_US, "dur": max(end - s.start, 0) * TICK_US,
        "args": args,
    }


def _instant_event(e: Event) -> dict:
    pid = CLUSTER_PID if e.replica < 0 else e.replica
    args: dict[str, Any] = {"uid": e.uid, "step": e.step, **e.attrs}
    if e.t is not None:
        args["wall"] = e.t
    return {
        "name": e.name, "cat": "lifecycle", "ph": "i", "s": "t",
        "pid": pid, "tid": e.track, "ts": e.step * TICK_US, "args": args,
    }


def to_chrome_trace(tracer: Tracer) -> dict:
    """Render one tracer's records as a Perfetto-loadable trace dict."""
    events: list[dict] = []
    replicas = tracer.replicas()
    slot_tracks: dict[int, set[int]] = {r: set() for r in replicas}
    for s in tracer.spans:
        if 0 <= s.track < TRACK_QUEUE:
            slot_tracks.setdefault(s.replica, set()).add(s.track)
    for r in sorted(slot_tracks):
        events.append(_meta(r, None, f"replica {r}"))
        for t in sorted(slot_tracks[r]):
            events.append(_meta(r, t, f"slot {t}"))
        events.append(_meta(r, TRACK_QUEUE, "queue"))
        events.append(_meta(r, TRACK_STEPS, "steps"))

    for s in tracer.spans:
        events.append(_span_event(s))
    has_router = False
    for e in tracer.events:
        if e.replica < 0:
            has_router = True
        events.append(_instant_event(e))
        if e.name == "spec_verify":
            # acceptance as a counter track: Perfetto graphs accepted
            # draft tokens per speculative window next to the step rows
            events.append({
                "name": "accepted_per_step", "ph": "C", "pid": e.replica,
                "tid": 0, "ts": e.step * TICK_US,
                "args": {"accepted_per_step": e.attrs.get("accepted", 0)},
            })
    if has_router:
        events.append(_meta(CLUSTER_PID, None, "cluster"))
        events.append(_meta(CLUSTER_PID, TRACK_ROUTER, "router"))

    for rec in tracer.steps:
        ts = (rec.step - 1) * TICK_US       # dispatch rec.step spans (step-1, step]
        events.append({
            "name": rec.kind, "cat": "dispatch", "ph": "X",
            "pid": rec.replica, "tid": TRACK_STEPS, "ts": ts, "dur": TICK_US,
            "args": rec.as_dict(),
        })
        counters = {"oi": rec.oi, "budget_fill": rec.fill,
                    "pipeline_depth": rec.pipeline_depth}
        if rec.pool_util is not None:
            counters["pool_util"] = rec.pool_util
        if rec.host_util is not None:
            counters["host_util"] = rec.host_util
        if rec.measured_s is not None:
            # sampled-profiler join: the measured twin of the analytic
            # oi track, graphed by Perfetto as the live Fig-8 view
            counters["measured_mfu"] = rec.measured_mfu
            counters["measured_mbu"] = rec.measured_mbu
            counters["achieved_gbps"] = rec.achieved_gbps
        for cname, val in counters.items():
            events.append({
                "name": cname, "ph": "C", "pid": rec.replica, "tid": 0,
                "ts": ts, "args": {cname: val},
            })
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"clock": "engine_steps", "tick_us": TICK_US},
    }


def write_trace(tracer: Tracer, path: str | Path) -> Path:
    """Validate and write the Chrome/Perfetto trace JSON."""
    obj = to_chrome_trace(tracer)
    problems = validate_trace(obj)
    if problems:
        raise ValueError(f"invalid trace: {problems[:5]}")
    path = Path(path)
    path.write_text(json.dumps(obj, indent=1) + "\n")
    return path


def write_metrics(registry, path: str | Path, extra: dict | None = None) -> Path:
    """Flat JSON dump of a :class:`MetricsRegistry` snapshot."""
    payload = dict(registry.snapshot())
    if extra:
        payload.update(extra)
    path = Path(path)
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    return path


# --------------------------------------------------------------- validation
def validate_trace(obj) -> list[str]:
    """Schema check for the exported trace; returns problem strings
    (empty = valid).  Intentionally small — enough for tests and the CI
    smoke to reject a malformed export, not a full Perfetto validator."""
    problems: list[str] = []
    if not isinstance(obj, dict):
        return ["top level is not an object"]
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            problems.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _PH_ALLOWED:
            problems.append(f"{where}: bad ph {ph!r}")
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            problems.append(f"{where}: missing name")
        for field in ("pid", "tid"):
            if not isinstance(ev.get(field), int):
                problems.append(f"{where}: {field} not an int")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"{where}: bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"{where}: bad dur {dur!r}")
        if ph in ("C", "M") and not isinstance(ev.get("args"), dict):
            problems.append(f"{where}: {ph} event needs args")
        if len(problems) >= 20:
            problems.append("... (truncated)")
            break
    return problems


# ---------------------------------------------------------------- span trees
@dataclasses.dataclass
class RequestTree:
    """One request's lifecycle, folded back into a tree: the synthesized
    root covers submit -> finish; children are the flat spans in step
    order; events are the instant markers."""

    replica: int
    uid: int
    start: int
    end: int | None
    spans: list[Span]
    events: list[Event]
    finished: bool

    def child(self, name: str) -> list[Span]:
        return [s for s in self.spans if s.name == name]

    def marks(self, name: str) -> list[Event]:
        return [e for e in self.events if e.name == name]

    def well_formed(self) -> list[str]:
        """Structural invariants every complete request tree must hold;
        returns problem strings (empty = well-formed)."""
        p: list[str] = []
        uid = f"u{self.uid}@r{self.replica}"
        queued = self.child("queued")
        chunks = self.child("prefill_chunk")
        decodes = self.child("decode")
        # A request migrated onto this replica (disaggregated serving)
        # was queued, chunked, admitted and produced its first token on
        # the *source* replica — its history here starts mid-decode.
        migrated_in = bool(self.marks("kv_migrate_in"))
        if not queued and not migrated_in:
            p.append(f"{uid}: no queued span")
        if not chunks and not migrated_in:
            p.append(f"{uid}: no prefill_chunk span")
        for s in self.spans:
            if s.closed and s.end < s.start:
                p.append(f"{uid}: span {s.name} ends before it starts")
        if self.finished:
            for s in self.spans:
                if not s.closed:
                    p.append(f"{uid}: finished request left {s.name} open")
            if not decodes:
                p.append(f"{uid}: finished request has no decode span")
            if not self.marks("finish"):
                p.append(f"{uid}: finished request has no finish event")
        # chunks advance monotonically through the (re-folded) prompt and
        # never overlap in positions within one admission
        pos = -1
        for c in chunks:
            if c.attrs.get("requeued"):
                continue
            start = c.attrs["pos"]
            if c.attrs["last"]:
                pos = -1            # next admission (refold) restarts
                continue
            if start < pos:
                p.append(f"{uid}: chunk positions regressed at {start}")
            pos = start
        admits = self.marks("admitted")
        if not admits and not migrated_in:
            p.append(f"{uid}: no admitted event")
        first = self.marks("first_token")
        if self.finished and not first and not migrated_in:
            p.append(f"{uid}: finished request has no first_token event")
        if first and admits and first[0].step < admits[0].step:
            p.append(f"{uid}: first_token before admission")
        # preemption bookkeeping: every preempted event pairs with a
        # refolded re-admission (or the run ended mid-queue)
        n_pre = len(self.marks("preempted"))
        n_refold = len(self.marks("refolded"))
        if self.finished and n_refold < n_pre:
            p.append(f"{uid}: {n_pre} preemptions but {n_refold} refolds")
        return p


def build_request_trees(tracer: Tracer) -> dict[tuple[int, int], RequestTree]:
    """Fold the tracer's flat records into one tree per (replica, uid)."""
    spans: dict[tuple[int, int], list[Span]] = {}
    events: dict[tuple[int, int], list[Event]] = {}
    for s in tracer.spans:
        spans.setdefault((s.replica, s.uid), []).append(s)
    for e in tracer.events:
        if e.replica < 0:
            continue
        events.setdefault((e.replica, e.uid), []).append(e)
    trees: dict[tuple[int, int], RequestTree] = {}
    for key, st in tracer.requests.items():
        ss = sorted(spans.get(key, []), key=lambda s: (s.start, s.track))
        es = sorted(events.get(key, []), key=lambda e: e.step)
        ends = [s.end for s in ss if s.end is not None]
        trees[key] = RequestTree(
            replica=key[0], uid=key[1], start=st.submit_step,
            end=max(ends) if ends else None, spans=ss, events=es,
            finished=st.finished,
        )
    return trees
