"""Metrics registry: counters, gauges, and exact-percentile histograms.

``EngineStats`` stays the engine's hot-path store (cheap int bumps on a
dataclass), but everything *reported* — the serve CLI printout, the
``--metrics-out`` JSON dump, cluster aggregates, CI trajectory metrics —
goes through a :class:`MetricsRegistry` built from it, so there is one
naming scheme and one percentile definition everywhere.
``tests/test_telemetry.py`` pins the registry's numbers to the legacy
``EngineStats`` fields exactly.

Histograms keep raw samples (serving runs here are O(requests), not
O(tokens), samples) so ``p50/p90/p99`` are exact nearest-rank
percentiles, not bucket interpolations — the satellite requirement that
a measured p99 TTFT be a TTFT some request actually saw.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any


def percentile(samples, p: float) -> float:
    """Exact nearest-rank percentile over raw samples.

    Edge cases are pinned by ``tests/test_observatory.py``: no samples
    -> 0.0 (a snapshot of an empty histogram must not error), one sample
    -> that sample for every ``p``, and ``p`` outside [0, 100] clamps to
    the min/max sample instead of indexing out of range.
    """
    s = sorted(samples)
    if not s:
        return 0.0
    p = min(max(p, 0.0), 100.0)
    rank = max(1, math.ceil(p / 100.0 * len(s)))
    return float(s[min(rank, len(s)) - 1])


@dataclasses.dataclass
class Counter:
    value: float = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


@dataclasses.dataclass
class Gauge:
    value: float = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Raw-sample histogram with exact percentiles."""

    def __init__(self):
        self.samples: list[float] = []

    def observe(self, v: float) -> None:
        self.samples.append(float(v))

    def extend(self, vs) -> None:
        self.samples.extend(float(v) for v in vs)

    @property
    def count(self) -> int:
        return len(self.samples)

    @property
    def sum(self) -> float:
        return float(sum(self.samples))

    @property
    def mean(self) -> float:
        return self.sum / max(self.count, 1)

    def percentile(self, p: float) -> float:
        return percentile(self.samples, p)


class MetricsRegistry:
    """Flat name -> metric map with a JSON-ready snapshot.

    Histogram ``name`` expands in the snapshot to ``name_count``,
    ``name_mean``, ``name_p50``, ``name_p90``, ``name_p99``.
    """

    def __init__(self):
        self._metrics: dict[str, Any] = {}

    def _get(self, name: str, kind):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = kind()
        elif not isinstance(m, kind):
            raise TypeError(f"metric {name!r} is {type(m).__name__}, "
                            f"not {kind.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def names(self) -> list[str]:
        return sorted(self._metrics)

    def snapshot(self) -> dict[str, float]:
        out: dict[str, float] = {}
        for name in self.names():
            m = self._metrics[name]
            if isinstance(m, Histogram):
                out[f"{name}_count"] = float(m.count)
                out[f"{name}_mean"] = m.mean
                for p in (50, 90, 99):
                    out[f"{name}_p{p}"] = m.percentile(p)
            else:
                out[name] = float(m.value)
        return out

    def render(self, prefix: str = "") -> str:
        return " ".join(f"{prefix}{k}={v:.4g}"
                        for k, v in self.snapshot().items())


# --------------------------------------------------------------- builders
_ENGINE_COUNTERS = (
    "prefills", "prefill_chunks", "boundary_packs", "decode_steps",
    "engine_steps", "generated", "preemptions", "victim_drains",
    "spills", "rehydrations", "migrations_out", "migrations_in",
    "spec_steps", "draft_steps", "drafted_tokens", "accepted_tokens",
)


def engine_registry(stats, pool_stats=None) -> MetricsRegistry:
    """The single reporting view over one engine's ``EngineStats`` (plus
    its ``PoolStats`` when serving from the paged cache)."""
    reg = MetricsRegistry()
    for name in _ENGINE_COUNTERS:
        reg.counter(name).inc(getattr(stats, name))
    reg.gauge("peak_active").set(stats.peak_active)
    reg.gauge("tokens_per_step").set(stats.tokens_per_step)
    reg.gauge("mean_ttft_steps").set(stats.mean_ttft_steps)
    reg.histogram("ttft_steps").extend(stats.ttft_samples)
    reg.histogram("per_token_steps").extend(stats.per_token_samples)
    # speculative decoding: overall acceptance ratio plus the per-window
    # acceptance-fraction distribution (one sample per observed verify row)
    reg.gauge("spec_accept_rate").set(stats.acceptance_rate)
    reg.histogram("spec_accept_frac").extend(
        getattr(stats, "spec_accept_samples", ())
    )
    if pool_stats is not None:
        for name in ("allocs", "frees", "hash_hits", "cow_copies",
                     "spills", "rehydrates", "host_evictions"):
            reg.counter(f"pool_{name}").inc(getattr(pool_stats, name))
        reg.gauge("pool_peak_in_use").set(pool_stats.peak_in_use)
        reg.gauge("pool_host_peak_in_use").set(pool_stats.host_peak_in_use)
    return reg


def cluster_registry(cstats) -> MetricsRegistry:
    """Cluster-wide reporting view: replica engines aggregated (TTFT and
    per-token samples pooled across replicas for cluster percentiles)
    plus the router/queue counters."""
    reg = MetricsRegistry()
    reg.counter("rounds").inc(cstats.rounds)
    reg.counter("generated").inc(cstats.generated)
    reg.counter("preemptions").inc(cstats.preemptions)
    reg.counter("spills").inc(cstats.spills)
    reg.counter("kv_spills").inc(cstats.kv_spills)
    reg.counter("kv_rehydrations").inc(cstats.kv_rehydrations)
    reg.counter("prefix_hit_tokens").inc(cstats.prefix_hit_tokens)
    reg.counter("probed_tokens").inc(cstats.probed_tokens)
    reg.counter("migrations").inc(cstats.migrations)
    reg.counter("refold_moves").inc(cstats.refold_moves)
    reg.gauge("tokens_per_round").set(cstats.tokens_per_round)
    reg.gauge("mean_queue_wait_rounds").set(cstats.mean_queue_wait_rounds)
    reg.gauge("mean_ttft_steps").set(cstats.mean_ttft_steps)
    reg.gauge("mean_ttft_rounds").set(cstats.mean_ttft_rounds)
    reg.histogram("ttft_rounds").extend(cstats.ttft_rounds_samples)
    reg.gauge("prefix_hit_rate").set(cstats.prefix_hit_rate)
    reg.gauge("load_imbalance").set(cstats.load_imbalance)
    ttft = reg.histogram("ttft_steps")
    tpt = reg.histogram("per_token_steps")
    for r in cstats.replicas:
        ttft.extend(r.engine.ttft_samples)
        tpt.extend(r.engine.per_token_samples)
        reg.gauge(f"replica{r.replica}_utilization").set(
            r.utilization(cstats.rounds)
        )
        reg.counter(f"replica{r.replica}_routed").inc(r.routed)
        reg.counter(f"replica{r.replica}_generated").inc(r.engine.generated)
        reg.gauge(f"replica{r.replica}_role").set(
            ("mixed", "prefill", "decode").index(r.role)
        )
    return reg
