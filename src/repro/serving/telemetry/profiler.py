"""Sampled per-dispatch wall-clock profiler: the measured half of Fig 8.

The step timeline (``timeline.py``) charges every dispatch *analytic*
FLOPs/bytes from the roofline model; nothing there measures what the
hardware actually achieved.  :class:`DispatchProfiler` closes that gap
by timing a **sample** of dispatches between two
``jax.block_until_ready`` fences and joining the measured seconds with
the dispatch's analytic cost:

* ``measured_mfu``  = flops / (seconds * device peak FLOP/s)
* ``measured_mbu``  = bytes / (seconds * device peak HBM B/s)
* ``achieved_gbps`` = bytes / seconds / 1e9

Sampling contract
-----------------
Fencing a dispatch drains the async dispatch-ahead pipeline (the *pre*
fence waits out all previously dispatched steps so queued work is not
billed to this one; the *post* fence waits for this dispatch alone), so
timing **every** step would serialize the engine back to sync mode.  The
profiler therefore fences only every ``sample_every``-th dispatch —
``sample_every=1`` is the sync mode that times every dispatch — and the
unsampled majority keep full overlap.  The measured interval covers one
step's host-side composition plus its device execution, which is exactly
the per-dispatch cost the paper's utilization figures are about.

The profiler never touches tokens, RNG, or scheduler state: greedy
outputs are bit-identical with it enabled (pinned by
``tests/test_observatory.py``).  Engines default to
:data:`NULL_PROFILER`, whose hooks are no-ops and whose
``enabled = False`` lets the engine skip the per-dispatch bookkeeping
entirely — the same zero-cost contract as :data:`~repro.serving.telemetry.tracer.NULL_TRACER`.
"""
from __future__ import annotations

import dataclasses
import time

import jax

from repro.core.oi import DEVICES, Device


class NullDispatchProfiler:
    """The disabled profiler: every hook is a no-op and ``enabled`` is
    False so engines skip sampling decisions and record joins entirely."""

    enabled = False
    samples: tuple = ()

    def tick(self) -> bool:
        return False

    def begin(self, fence) -> None:
        pass

    def end(self, fence) -> None:
        pass

    def commit(self, record) -> None:
        pass


NULL_PROFILER = NullDispatchProfiler()


@dataclasses.dataclass
class ProfileSample:
    """One fenced dispatch: measured seconds joined with analytic cost."""

    replica: int
    step: int                   # engine-step id of the dispatch
    kind: str                   # decode | fused | solo | spec | ...
    bucket: int | None          # compiled prefill-chunk bucket (None: none)
    decode_batch: int
    seconds: float              # fence-to-fence wall clock
    flops: float                # analytic FLOPs (DispatchCostModel)
    bytes: float                # analytic HBM bytes
    oi: float                   # flops / bytes
    measured_mfu: float
    measured_mbu: float
    achieved_gbps: float

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class DispatchProfiler:
    """Samples dispatch wall-clock between ``block_until_ready`` fences
    and joins it with the step's analytic FLOPs/bytes — a live Fig 8.

    One profiler instance may be shared by many replicas (the cluster
    passes the same object to every engine); the sampling counter is
    then global across replicas, which only spreads the fence cost.
    """

    enabled = True

    def __init__(self, sample_every: int = 8, device: str | Device = "TPU-V5E"):
        if sample_every < 1:
            raise ValueError(f"sample_every must be >= 1, got {sample_every}")
        self.sample_every = sample_every
        self.device = DEVICES[device] if isinstance(device, str) else device
        self.samples: list[ProfileSample] = []
        self._n = 0             # dispatches seen (sampled or not)
        self._t0: float | None = None
        self._dt: float | None = None

    @property
    def sync(self) -> bool:
        """Sync mode: every dispatch is fenced and timed."""
        return self.sample_every == 1

    # ------------------------------------------------------------ sampling
    def tick(self) -> bool:
        """Count one dispatch; True when this one should be fenced."""
        self._n += 1
        return self._n % self.sample_every == 0

    def begin(self, fence) -> None:
        """Pre-dispatch fence: wait out all previously dispatched device
        work so the sampled interval bills only the next dispatch."""
        jax.block_until_ready(fence)
        self._t0 = time.perf_counter()

    def end(self, fence) -> None:
        """Post-dispatch fence: wait for the sampled dispatch itself."""
        jax.block_until_ready(fence)
        self._dt = time.perf_counter() - self._t0
        self._t0 = None

    def commit(self, record) -> None:
        """Join the fenced interval with the dispatch's StepRecord: append
        a :class:`ProfileSample` and annotate the record in place so the
        Perfetto exporter can emit measured counter tracks."""
        dt = self._dt
        self._dt = None
        if dt is None or record is None:
            return
        dt = max(dt, 1e-9)
        mfu = record.flops / (dt * self.device.flops)
        mbu = record.bytes / (dt * self.device.bw)
        gbps = record.bytes / dt / 1e9
        record.measured_s = dt
        record.measured_mfu = mfu
        record.measured_mbu = mbu
        record.achieved_gbps = gbps
        self.samples.append(ProfileSample(
            replica=record.replica, step=record.step, kind=record.kind,
            bucket=record.bucket, decode_batch=record.decode_batch,
            seconds=dt, flops=record.flops, bytes=record.bytes, oi=record.oi,
            measured_mfu=mfu, measured_mbu=mbu, achieved_gbps=gbps,
        ))

    # ----------------------------------------------------------- reporting
    def summary(self) -> dict[tuple, dict[str, float]]:
        """Aggregate per ``(kind, bucket, decode_batch)``: sample count,
        mean seconds, and mean measured MFU/MBU/bandwidth — the measured
        twin of the paper's Fig-8 rows."""
        groups: dict[tuple, list[ProfileSample]] = {}
        for s in self.samples:
            groups.setdefault((s.kind, s.bucket, s.decode_batch), []).append(s)
        out: dict[tuple, dict[str, float]] = {}
        for key in sorted(groups, key=lambda k: (k[0], k[1] or 0, k[2])):
            ss = groups[key]
            n = len(ss)
            out[key] = {
                "n": float(n),
                "seconds": sum(s.seconds for s in ss) / n,
                "oi": sum(s.oi for s in ss) / n,
                "measured_mfu": sum(s.measured_mfu for s in ss) / n,
                "measured_mbu": sum(s.measured_mbu for s in ss) / n,
                "achieved_gbps": sum(s.achieved_gbps for s in ss) / n,
            }
        return out

    def register(self, reg) -> None:
        """Publish the measured view into a :class:`MetricsRegistry`:
        overall gauges plus per-dispatch sample histograms."""
        reg.counter("profiled_dispatches").inc(len(self.samples))
        reg.gauge("profile_sample_every").set(self.sample_every)
        if not self.samples:
            return
        n = len(self.samples)
        reg.gauge("measured_mfu").set(
            sum(s.measured_mfu for s in self.samples) / n
        )
        reg.gauge("measured_mbu").set(
            sum(s.measured_mbu for s in self.samples) / n
        )
        reg.gauge("achieved_gbps").set(
            sum(s.achieved_gbps for s in self.samples) / n
        )
        reg.histogram("dispatch_seconds").extend(
            s.seconds for s in self.samples
        )

    def describe(self) -> str:
        """One-line measured summary for the terminal dashboard."""
        if not self.samples:
            return "measured: no samples yet"
        n = len(self.samples)
        mfu = sum(s.measured_mfu for s in self.samples) / n
        mbu = sum(s.measured_mbu for s in self.samples) / n
        bw = sum(s.achieved_gbps for s in self.samples) / n
        return (f"measured[{self.device.name}]: mfu={mfu:.4f} mbu={mbu:.4f} "
                f"bw={bw:.1f}GB/s (n={n}, every {self.sample_every})")


def make_profiler(sample_every: int,
                  device: str = "TPU-V5E") -> DispatchProfiler | NullDispatchProfiler:
    """CLI helper: ``sample_every <= 0`` means disabled (NULL profiler),
    ``1`` is sync mode, ``N`` fences every Nth dispatch."""
    if sample_every <= 0:
        return NULL_PROFILER
    return DispatchProfiler(sample_every=sample_every, device=device)


__all__ = [
    "NULL_PROFILER",
    "DispatchProfiler",
    "NullDispatchProfiler",
    "ProfileSample",
    "make_profiler",
]
