"""Per-dispatch step timeline: what each fused step contained and cost.

The engine appends one :class:`StepRecord` per model dispatch (decode,
fused hybrid, solo prefill, boundary-packed, or whole admission prefill)
describing the dispatch's *composition* — decode batch size, prefill
chunk and bucket, token-budget fill fraction, block-pool utilization,
dispatch-ahead pipeline depth — plus analytic FLOPs/bytes from
:func:`repro.analysis.roofline.dispatch_flops_bytes`, so the live run
reports the same operational-intensity accounting as the paper's Fig-1
roofline: decode-only dispatches sit deep in the memory-bound regime,
fused dispatches climb toward the ridge because the prefill chunk's
GEMMs reuse the weight stream the decode batch already paid for.

Records are built **only when telemetry is enabled** (the engine guards
on ``tracer.enabled or profiler.enabled``) and only from host-side
bookkeeping the engine already maintains — never from device arrays, so
the dispatch-ahead pipeline keeps its overlap.  When the sampled
:class:`~repro.serving.telemetry.profiler.DispatchProfiler` fences a
dispatch, it annotates that record's ``measured_*`` fields in place.
"""
from __future__ import annotations

import dataclasses

from repro.analysis.roofline import dispatch_flops_bytes


@dataclasses.dataclass
class StepRecord:
    """One model dispatch, as the scheduler/engine composed it."""

    replica: int
    step: int                   # engine_steps id of this dispatch
    kind: str                   # decode | fused | fused2 | solo | solo2 | prefill
    decode_batch: int           # decode lanes in the dispatch
    prefill_tokens: int         # real prefill tokens (both chunks if packed)
    bucket: int | None          # compiled chunk bucket (None: no chunk)
    bucket2: int | None         # boundary-packed second chunk's bucket
    budget: int                 # token budget the scheduler packed against
    fill: float                 # (decode + prefill) / budget
    kv_tokens: int              # KV positions attended by the decode batch
    pool_util: float | None     # paged block-pool utilization (None: dense)
    pipeline_depth: int         # dispatched-but-unobserved steps (async)
    flops: float                # analytic FLOPs for this dispatch
    bytes: float                # analytic HBM bytes for this dispatch
    oi: float                   # operational intensity = flops / bytes
    host_util: float | None = None  # host KV tier utilization (None: no tier)
    wall: float | None = None   # perf_counter at dispatch (Tracer(wall=True))
    # measured join (DispatchProfiler, sampled dispatches only): fenced
    # wall-clock seconds and the utilization it implies vs device peaks
    measured_s: float | None = None
    measured_mfu: float | None = None
    measured_mbu: float | None = None
    achieved_gbps: float | None = None

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class DispatchCostModel:
    """Analytic per-dispatch cost, seeded only by the model config.

    Thin stateful wrapper over
    :func:`repro.analysis.roofline.dispatch_flops_bytes` so the engine
    computes scalar host arithmetic per traced dispatch — no HLO walks,
    no device work.
    """

    def __init__(self, cfg):
        self.cfg = cfg

    def cost(self, n_decode: int, kv_tokens: int, prefill_tokens: int = 0,
             prefill_ctx_tokens: int = 0) -> tuple[float, float]:
        return dispatch_flops_bytes(
            self.cfg, n_decode, kv_tokens, prefill_tokens, prefill_ctx_tokens
        )

    @staticmethod
    def chunk_ctx_tokens(start: int, n_valid: int) -> int:
        """Total context positions a causal chunk at offset ``start``
        attends: query i (0-based) sees ``start + i + 1`` positions."""
        return n_valid * start + n_valid * (n_valid + 1) // 2
