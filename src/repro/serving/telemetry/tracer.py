"""Request-span tracing on the engine-step clock.

The paper's argument is an accounting argument — co-processing wins only
if you can see where each step's time and bytes go — so the tracer
records *everything the engine already knows at its host-side dispatch
and observe boundaries* and nothing more: no timers inside jit-traced
code, no device syncs, no extra transfers.  Every record is stamped on
the deterministic ``EngineStats.engine_steps`` clock (the same clock TTFT
and tokens/step are measured on), with optional wall-clock timestamps
(``Tracer(wall=True)``) riding along as annotations.

One request produces one span tree::

    request (synthesized at export)
    ├── queued          submit -> admitted          (re-opens on preemption)
    ├── prefill_chunk   one per executed chunk      (whole prefill = 1 span)
    ├── ...             (hybrid: xN, boundary-packed chunks included)
    └── decode          first_token -> finish       (ends early on preempt)

plus instant events: ``admitted``, ``refolded`` (re-admission after a
preemption, generated tokens folded into the prefill), ``first_token``,
``preempted``, ``boundary_packed``, ``finish``, ``slo_breach`` (a
declared TTFT/TPOT target missed — ``Tracer(slo=monitor)`` forwards
first-token/finish observations to an
:class:`~repro.serving.telemetry.slo.SLOMonitor`), and cluster-level
``route`` events (policy, chosen replica, spill).

Async dispatch-ahead engines close spans at *observe* time, one step
after the dispatch that produced the tokens.  Observe-time closes
therefore carry two wall stamps when ``wall=True``: the close's own
``t_end`` and a ``wall_dispatch`` attr looked up from the step's
dispatch record — viewers can reconstruct the true device overlap from
the pair.

Tracks: spans carry a ``(replica, track)`` address — ``track`` is the
engine slot the work ran on, or one of the reserved tracks
(:data:`TRACK_QUEUE` for pre-admission waits, :data:`TRACK_STEPS` for
the per-dispatch timeline, :data:`TRACK_ROUTER` on the cluster row for
routing decisions).  ``repro.serving.telemetry.export`` turns these into
one Perfetto/Chrome-trace track per replica slot.

Disaggregated serving splits one request's history across replicas:
``on_migrate`` closes the source replica's spans and drops paired
``kv_migrate`` / ``kv_migrate_in`` instant marks (``on_refold_move``
likewise for re-placed preemptees), so a migrated request renders as
two half-trees joined by the marks — trace validation treats the marks
as the join key.

Zero-cost when disabled: engines default to :data:`NULL_TRACER`, whose
hooks are no-ops and whose ``enabled = False`` lets the engine skip even
building the per-dispatch :class:`~repro.serving.telemetry.timeline.StepRecord`.
Nothing here ever touches a jit-traced code path.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

# reserved track ids (engine slots occupy 0..n_slots-1)
TRACK_QUEUE = 1000
TRACK_STEPS = 1001
TRACK_ROUTER = 1002


@dataclasses.dataclass
class Span:
    """A closed or still-open interval on one (replica, track) row."""

    replica: int
    track: int
    uid: int
    name: str
    start: int                  # engine-step clock
    end: int | None = None
    t_start: float | None = None    # wall clock (perf_counter), optional
    t_end: float | None = None
    attrs: dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def closed(self) -> bool:
        return self.end is not None


@dataclasses.dataclass
class Event:
    """An instant marker on one (replica, track) row."""

    replica: int
    track: int
    uid: int
    name: str
    step: int
    t: float | None = None
    attrs: dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class _RequestState:
    """Per-request open-span bookkeeping (host-side only)."""

    uid: int
    replica: int
    submit_step: int
    prompt_len: int
    queued: Span | None = None
    decode: Span | None = None
    finished: bool = False
    # request arrived by KV migration: its queued/prefill history lives
    # on the source replica's state (well-formedness checks adapt)
    migrated_in: bool = False


class NullTracer:
    """The disabled tracer: every hook is a no-op, ``enabled`` is False
    so engines skip building records entirely.  ``bind`` and friends
    return ``self`` so one singleton serves every call site."""

    enabled = False
    round = 0

    def on_submit(self, replica, req, step):
        pass

    def on_admit(self, replica, req, step, slot, n_tokens, refold=False):
        pass

    def on_chunk(self, replica, req, slot, start_step, end_step, pos,
                 n_valid, bucket, last):
        pass

    def on_first_token(self, replica, req, step, slot, first=True):
        pass

    def on_finish(self, replica, req, step, slot):
        pass

    def on_preempt(self, replica, req, step, slot):
        pass

    def on_boundary_pack(self, replica, req, step, slot):
        pass

    def on_spill(self, replica, step, dev_block, host_block):
        pass

    def on_rehydrate(self, replica, step, host_block, dev_block):
        pass

    def on_spec_propose(self, replica, step, depth, batch):
        pass

    def on_spec_verify(self, replica, step, accepted, batch):
        pass

    def on_step(self, record):
        pass

    def on_route(self, uid, replica, policy, rank_pos, hit_tokens, probed):
        pass

    def on_migrate(self, req, src_replica, src_step, src_slot,
                   dst_replica, dst_step, dst_slot, n_blocks):
        pass

    def on_refold_move(self, req, src_replica, dst_replica):
        pass

    def wall(self):
        return None


NULL_TRACER = NullTracer()


class Tracer:
    """Collects spans/events/step records from engines and the cluster
    router.  One tracer instance may be shared by many replicas — each
    hook takes the calling replica's index.

    The engine-step clock is **per replica** (each engine counts its own
    dispatches); the exporter keeps replicas on separate process rows so
    the clocks never mix.  ``wall=True`` additionally stamps every record
    with ``time.perf_counter()`` for cross-replica alignment.
    """

    enabled = True

    def __init__(self, wall: bool = False, slo=None):
        self.use_wall = wall
        self.slo = slo                          # optional SLOMonitor
        self.spans: list[Span] = []
        self.events: list[Event] = []
        self.steps: list = []                   # StepRecord, append order
        self.requests: dict[tuple[int, int], _RequestState] = {}
        self.round = 0                          # cluster round (set by Cluster)
        # (replica, step) -> wall stamp of that step's *dispatch*, so
        # observe-time closes (async lands them a step later) can carry
        # both stamps and trace viewers see the true overlap
        self._step_wall: dict[tuple[int, int], float] = {}

    def wall(self) -> float | None:
        return time.perf_counter() if self.use_wall else None

    def _dispatch_wall(self, replica: int, step: int) -> float | None:
        return self._step_wall.get((replica, step)) if self.use_wall else None

    # ------------------------------------------------------ request lifecycle
    def _state(self, replica: int, req) -> _RequestState:
        key = (replica, req.uid)
        st = self.requests.get(key)
        if st is None:
            st = _RequestState(uid=req.uid, replica=replica, submit_step=0,
                               prompt_len=len(req.prompt))
            self.requests[key] = st
        return st

    def _event(self, replica, track, uid, name, step, **attrs) -> None:
        self.events.append(Event(replica=replica, track=track, uid=uid,
                                 name=name, step=step, t=self.wall(),
                                 attrs=attrs))

    def on_submit(self, replica: int, req, step: int) -> None:
        st = self._state(replica, req)
        st.submit_step = step
        st.queued = Span(replica=replica, track=TRACK_QUEUE, uid=req.uid,
                         name="queued", start=step, t_start=self.wall(),
                         attrs={"prompt_len": len(req.prompt)})
        self.spans.append(st.queued)

    def on_admit(self, replica: int, req, step: int, slot: int,
                 n_tokens: int, refold: bool = False) -> None:
        """Close the queued span; a re-admission after preemption also
        emits ``refolded`` (generated tokens folded into the prefill)."""
        st = self._state(replica, req)
        if st.queued is not None and not st.queued.closed:
            st.queued.end = step
            st.queued.t_end = self.wall()
        st.queued = None
        self._event(replica, slot, req.uid, "admitted", step,
                    slot=slot, n_tokens=n_tokens)
        if refold:
            self._event(replica, slot, req.uid, "refolded", step,
                        slot=slot, n_tokens=n_tokens)

    def on_chunk(self, replica: int, req, slot: int, start_step: int,
                 end_step: int, pos: int, n_valid: int,
                 bucket: int | None, last: bool) -> None:
        """One executed prefill chunk (a whole decode-only prefill is one
        chunk covering its ceil(L/prefill_chunk)-step cost)."""
        attrs = {"pos": pos, "n_valid": n_valid, "bucket": bucket,
                 "last": last}
        wd = self._dispatch_wall(replica, end_step)
        if wd is not None:
            attrs["wall_dispatch"] = wd
        self.spans.append(Span(
            replica=replica, track=slot, uid=req.uid, name="prefill_chunk",
            start=start_step, end=end_step, t_end=self.wall(), attrs=attrs,
        ))

    def on_first_token(self, replica: int, req, step: int, slot: int,
                       first: bool = True) -> None:
        """Prefill completed: open the decode span.  ``first`` is False on
        a post-preemption re-admission (the true first token was already
        emitted before the preemption)."""
        st = self._state(replica, req)
        if first:
            self._event(replica, slot, req.uid, "first_token", step,
                        slot=slot)
            if self.slo is not None:
                ttft = max(step - st.submit_step, 0)
                if self.slo.observe_ttft(req.uid, ttft):
                    self._event(replica, slot, req.uid, "slo_breach", step,
                                metric="ttft", value=ttft,
                                target=self.slo.ttft_target)
        st.decode = Span(replica=replica, track=slot, uid=req.uid,
                         name="decode", start=step, t_start=self.wall())
        wd = self._dispatch_wall(replica, step)
        if wd is not None:
            st.decode.attrs["wall_dispatch"] = wd
        self.spans.append(st.decode)

    def on_finish(self, replica: int, req, step: int, slot: int) -> None:
        st = self._state(replica, req)
        wd = self._dispatch_wall(replica, step)
        if st.decode is not None and not st.decode.closed:
            st.decode.end = step
            st.decode.t_end = self.wall()
            st.decode.attrs["generated"] = len(req.out_tokens)
            if wd is not None:
                # async closes land at observe time, one step after the
                # dispatch that produced the final token: record both
                # stamps so viewers can show the true device overlap
                st.decode.attrs["wall_dispatch"] = wd
        st.decode = None
        st.finished = True
        attrs = {"generated": len(req.out_tokens)}
        if wd is not None:
            attrs["wall_dispatch"] = wd
        self._event(replica, slot, req.uid, "finish", step, **attrs)
        if self.slo is not None:
            gen = len(req.out_tokens)
            first_step = getattr(req, "first_token_step", -1)
            tpot = ((step - first_step) / max(gen - 1, 1)
                    if 0 <= first_step <= step else 0.0)
            if self.slo.observe_finish(req.uid, tpot, gen):
                self._event(replica, slot, req.uid, "slo_breach", step,
                            metric="tpot", value=tpot,
                            target=self.slo.tpot_target)

    def on_preempt(self, replica: int, req, step: int, slot: int) -> None:
        """Eviction to the queue: the decode span ends here (marked), and
        a fresh queued span opens — the request is waiting again."""
        st = self._state(replica, req)
        if st.decode is not None and not st.decode.closed:
            st.decode.end = step
            st.decode.t_end = self.wall()
            st.decode.attrs["preempted"] = True
            wd = self._dispatch_wall(replica, step)
            if wd is not None:
                st.decode.attrs["wall_dispatch"] = wd
        st.decode = None
        self._event(replica, slot, req.uid, "preempted", step, slot=slot)
        st.queued = Span(replica=replica, track=TRACK_QUEUE, uid=req.uid,
                         name="queued", start=step, t_start=self.wall(),
                         attrs={"requeued": True})
        self.spans.append(st.queued)

    def on_boundary_pack(self, replica: int, req, step: int, slot: int) -> None:
        self._event(replica, slot, req.uid, "boundary_packed", step,
                    slot=slot)

    # ------------------------------------------------------------ KV tiering
    def on_spill(self, replica: int, step: int, dev_block: int,
                 host_block: int) -> None:
        """One KV block copied device -> host tier (free-time or live
        spill).  Not tied to a request: stamped on the steps track."""
        self._event(replica, TRACK_STEPS, -1, "kv_spill", step,
                    dev=dev_block, host=host_block)

    def on_rehydrate(self, replica: int, step: int, host_block: int,
                     dev_block: int) -> None:
        """One KV block copied host tier -> device (prefix re-hydration)."""
        self._event(replica, TRACK_STEPS, -1, "kv_rehydrate", step,
                    host=host_block, dev=dev_block)

    # ------------------------------------------------- speculative decoding
    def on_spec_propose(self, replica: int, step: int, depth: int,
                        batch: int) -> None:
        """One speculative dispatch: ``depth`` draft tokens proposed per
        slot for ``batch`` decode slots.  Not tied to a request: stamped
        on the steps track at dispatch."""
        self._event(replica, TRACK_STEPS, -1, "spec_propose", step,
                    depth=depth, batch=batch)

    def on_spec_verify(self, replica: int, step: int, accepted: int,
                       batch: int) -> None:
        """One speculative window observed: ``accepted`` draft tokens
        (bonus tokens excluded) accepted across ``batch`` slots.  Stamped
        at the window's *dispatch* step (the pending record's clock), so
        propose/verify marks pair up on the timeline."""
        self._event(replica, TRACK_STEPS, -1, "spec_verify", step,
                    accepted=accepted, batch=batch)

    # ------------------------------------------------------------- timeline
    def on_step(self, record) -> None:
        """Append one per-dispatch StepRecord (built by the engine only
        when ``enabled`` — see ``Engine._trace_step``)."""
        self.steps.append(record)
        if record.wall is not None:
            self._step_wall[(record.replica, record.step)] = record.wall

    # --------------------------------------------------------------- router
    def on_route(self, uid: int, replica: int, policy: str, rank_pos: int,
                 hit_tokens: int, probed: int) -> None:
        """A cluster routing decision, stamped on the cluster round clock
        (``self.round``, maintained by ``Cluster.step``)."""
        self._event(-1, TRACK_ROUTER, uid, "route", self.round,
                    chosen=replica, policy=policy, spill=rank_pos > 0,
                    rank_pos=rank_pos, hit_tokens=hit_tokens, probed=probed)

    # ------------------------------------------------------------- migration
    def on_migrate(self, req, src_replica: int, src_step: int, src_slot: int,
                   dst_replica: int, dst_step: int, dst_slot: int,
                   n_blocks: int) -> None:
        """A resident request's KV migrated between replicas (the
        disaggregated prefill->decode handoff).  The source's decode span
        closes (``migrated=True``), a fresh decode span opens on the
        destination's clock, and three markers land: ``kv_migrate_out``
        on the source slot row, ``kv_migrate_in`` on the destination slot
        row, and the cluster-level ``kv_migrate`` mark on the router row
        (the one CI's ``--expect-migrate-marks`` counts)."""
        src = self._state(src_replica, req)
        if src.decode is not None and not src.decode.closed:
            src.decode.end = src_step
            src.decode.t_end = self.wall()
            src.decode.attrs["migrated"] = True
            src.decode.attrs["dst_replica"] = dst_replica
        src.decode = None
        self._event(src_replica, src_slot, req.uid, "kv_migrate_out",
                    src_step, dst=dst_replica, blocks=n_blocks)
        key = (dst_replica, req.uid)
        dst = self.requests.get(key)
        if dst is None:
            dst = _RequestState(uid=req.uid, replica=dst_replica,
                                submit_step=dst_step,
                                prompt_len=len(req.prompt))
            self.requests[key] = dst
        dst.migrated_in = True
        dst.decode = Span(replica=dst_replica, track=dst_slot, uid=req.uid,
                          name="decode", start=dst_step, t_start=self.wall(),
                          attrs={"migrated_in": True, "src_replica": src_replica})
        self.spans.append(dst.decode)
        self._event(dst_replica, dst_slot, req.uid, "kv_migrate_in",
                    dst_step, src=src_replica, blocks=n_blocks)
        self._event(-1, TRACK_ROUTER, req.uid, "kv_migrate", self.round,
                    src=src_replica, dst=dst_replica, blocks=n_blocks)

    def on_refold_move(self, req, src_replica: int, dst_replica: int) -> None:
        """A preempted request's refold re-placed off its home replica
        (router-driven refold placement), marked on the router row."""
        self._event(-1, TRACK_ROUTER, req.uid, "refold_move", self.round,
                    src=src_replica, dst=dst_replica)
        # the request now queues on the destination: close any open
        # queued span at home and open one there
        src = self._state(src_replica, req)
        if src.queued is not None and not src.queued.closed:
            src.queued.end = src.queued.start
            src.queued.t_end = self.wall()
            src.queued.attrs["moved"] = True
        src.queued = None
        dst = self._state(dst_replica, req)
        dst.migrated_in = True
        dst.queued = Span(replica=dst_replica, track=TRACK_QUEUE, uid=req.uid,
                          name="queued", start=req.submit_step,
                          t_start=self.wall(), attrs={"refold_move": True})
        self.spans.append(dst.queued)

    # ---------------------------------------------------------- introspection
    def replicas(self) -> list[int]:
        """Replica indices that produced any record (cluster row -1 excluded)."""
        seen = {s.replica for s in self.spans}
        seen |= {e.replica for e in self.events}
        seen |= {r.replica for r in self.steps}
        return sorted(i for i in seen if i >= 0)
