"""Deterministic seeded workload generator + open-loop arrival driver.

ROADMAP open item 5's first half: serving scenarios are not a list of
prompts, they are *arrival processes* with structure the router and
cache can exploit (or be hurt by).  :func:`build_workload` produces a
seeded, fully deterministic arrival schedule — the same ``(kind, seed)``
always yields byte-identical prompts and rounds — in five shapes:

* ``random``   — every request at round 0, lengths uniform in
  ``[4, max_seq/2)``: the legacy serve-CLI workload, kept as the default
  so existing smokes and benchmarks measure the same thing;
* ``poisson``  — open-loop Poisson arrivals at ``rate`` requests/round
  (exponential inter-arrival gaps, cumulative-summed onto the round
  clock);
* ``bursty``   — the same mean rate delivered in bursts of ``burst``
  simultaneous requests: the head-of-line / queue-depth stress shape;
* ``chat-fan`` — groups of ``fan`` requests share one prompt prefix and
  arrive within a few rounds of each other (fan-out of one conversation
  to many users): the shape prefix-affinity routing and hash-based
  block sharing are built for;
* ``rag``      — a few long shared documents, each queried by many
  requests with short unique suffixes: long-prefix reuse with
  decode-light tails;
* ``agentic``  — tool-loop sessions: the initial request is short, and
  every completion is resubmitted by the driver with the prior output
  folded into a **grown prefix** plus a fresh query (``turns`` rounds of
  this per session).

:class:`WorkloadDriver` plays a schedule against an :class:`Engine` or
:class:`Cluster` on its own step/round clock: arrivals are submitted
when their round comes up, agentic completions are resubmitted after a
``think`` delay, and the run ends only when every submitted request —
including grown resubmissions — has finished.  Grown prefixes are
clipped to a tail window so prompt + generation always fits ``max_seq``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.serving.engine import Request

WORKLOADS = ("random", "poisson", "bursty", "chat-fan", "rag", "agentic")


@dataclasses.dataclass
class Arrival:
    """One scheduled request: a prompt due at a cluster round.  The
    driver assigns uids at submission (sessions respawn with fresh
    uids, so generator-side ids would collide)."""

    round: int
    prompt: np.ndarray
    max_new_tokens: int
    session: int = -1           # agentic session id (-1: one-shot)
    turns_left: int = 0         # resubmissions still owed by the session


def _prompt(rng: np.random.Generator, length: int, vocab: int) -> np.ndarray:
    return rng.integers(1, vocab, size=int(length)).astype(np.int32)


def _poisson_rounds(rng: np.random.Generator, n: int, rate: float) -> list[int]:
    gaps = rng.exponential(1.0 / max(rate, 1e-6), size=n)
    return [int(r) for r in np.floor(np.cumsum(gaps))]


def build_workload(kind: str, n_requests: int, *, vocab: int, max_seq: int,
                   max_new: int, seed: int = 0, rate: float = 0.5,
                   burst: int = 4, fan: int = 4,
                   turns: int = 3) -> list[Arrival]:
    """Build a deterministic arrival schedule (sorted by round).

    ``rate`` paces the open-loop kinds in requests/round; ``burst``,
    ``fan`` and ``turns`` shape their namesake kinds.  Prompt lengths
    respect ``len(prompt) + max_new <= max_seq - 2`` so every arrival
    (and every grown agentic resubmission) is admissible.
    """
    if kind not in WORKLOADS:
        raise ValueError(f"unknown workload {kind!r} (known: "
                         f"{', '.join(WORKLOADS)})")
    rng = np.random.default_rng(seed)
    budget = max(6, max_seq - max_new - 2)
    out: list[Arrival] = []

    if kind == "random":
        hi = max(5, max_seq // 2)
        for _ in range(n_requests):
            plen = int(rng.integers(4, hi))
            out.append(Arrival(0, _prompt(rng, plen, vocab), max_new))

    elif kind == "poisson":
        rounds = _poisson_rounds(rng, n_requests, rate)
        hi = max(5, min(max_seq // 2, budget))
        for r in rounds:
            plen = int(rng.integers(4, hi))
            out.append(Arrival(r, _prompt(rng, plen, vocab), max_new))

    elif kind == "bursty":
        gap = max(1, round(burst / max(rate, 1e-6)))
        hi = max(5, min(max_seq // 2, budget))
        for i in range(n_requests):
            plen = int(rng.integers(4, hi))
            out.append(Arrival((i // burst) * gap,
                               _prompt(rng, plen, vocab), max_new))

    elif kind == "chat-fan":
        prefix_len = max(4, budget // 3)
        suffix_hi = max(3, budget // 6)
        group_rounds = _poisson_rounds(rng, -(-n_requests // fan),
                                       rate / max(fan, 1))
        for g, r0 in enumerate(group_rounds):
            prefix = _prompt(rng, prefix_len, vocab)
            for _ in range(min(fan, n_requests - g * fan)):
                suffix = _prompt(rng, int(rng.integers(2, suffix_hi + 1)),
                                 vocab)
                out.append(Arrival(r0 + int(rng.integers(0, 3)),
                                   np.concatenate([prefix, suffix]),
                                   max_new))

    elif kind == "rag":
        doc_len = max(6, (budget * 3) // 5)
        n_docs = max(1, n_requests // 6)
        docs = [_prompt(rng, doc_len, vocab) for _ in range(n_docs)]
        rounds = _poisson_rounds(rng, n_requests, rate)
        q_hi = max(3, min(8, budget - doc_len))
        for r in rounds:
            doc = docs[int(rng.integers(0, n_docs))]
            query = _prompt(rng, int(rng.integers(2, q_hi + 1)), vocab)
            out.append(Arrival(r, np.concatenate([doc, query]), max_new))

    elif kind == "agentic":
        rounds = _poisson_rounds(rng, n_requests, rate)
        hi = max(5, budget // 4)
        for s, r in enumerate(rounds):
            plen = int(rng.integers(4, hi))
            out.append(Arrival(r, _prompt(rng, plen, vocab), max_new,
                               session=s, turns_left=max(turns - 1, 0)))

    out.sort(key=lambda a: a.round)
    return out


def grow_prompt(prompt: np.ndarray, out_tokens: list[int],
                query: np.ndarray, max_seq: int,
                max_new: int) -> np.ndarray:
    """Agentic resubmission prompt: prior prompt + prior output + a new
    query, clipped to a *tail* window (the sliding-context convention)
    so the grown prompt plus the next generation still fits ``max_seq``."""
    grown = np.concatenate([
        prompt, np.asarray(out_tokens, dtype=np.int32), query
    ]).astype(np.int32)
    budget = max(4, max_seq - max_new - 2)
    return grown[-budget:] if len(grown) > budget else grown


class WorkloadDriver:
    """Play an arrival schedule against one serving front-end (an
    :class:`~repro.serving.engine.Engine` or a
    :class:`~repro.serving.cluster.Cluster`) on its own clock.

    Each driver round submits the arrivals that are due, steps the
    server once, and harvests finished agentic sessions into grown-
    prefix resubmissions due ``think`` rounds later.  ``on_round``
    (e.g. the ``--dashboard`` renderer) fires after every round.
    """

    def __init__(self, serv, arrivals: list[Arrival], *, vocab: int,
                 max_seq: int, seed: int = 0, think: int = 2,
                 on_round=None):
        self.serv = serv
        self.arrivals = sorted(arrivals, key=lambda a: a.round)
        self.rng = np.random.default_rng(seed + 0x5EED)
        self.vocab = vocab
        self.max_seq = max_seq
        self.think = think
        self.on_round = on_round
        self.submitted: list[Request] = []
        self.resubmits = 0
        self.rounds = 0
        self._next_uid = 0
        # uid -> originating Arrival, parked until the request finishes
        self._sessions: dict[int, tuple[Request, Arrival]] = {}

    def _submit(self, arr: Arrival) -> None:
        req = Request(uid=self._next_uid, prompt=arr.prompt,
                      max_new_tokens=arr.max_new_tokens)
        self._next_uid += 1
        self.serv.submit(req)
        self.submitted.append(req)
        if arr.turns_left > 0:
            self._sessions[req.uid] = (req, arr)

    def _grow(self, req: Request, arr: Arrival) -> Arrival:
        query = self.rng.integers(1, self.vocab,
                                  size=int(self.rng.integers(2, 7)))
        prompt = grow_prompt(req.prompt, req.out_tokens,
                             query.astype(np.int32), self.max_seq,
                             arr.max_new_tokens)
        self.resubmits += 1
        return Arrival(round=self.rounds + self.think, prompt=prompt,
                       max_new_tokens=arr.max_new_tokens,
                       session=arr.session, turns_left=arr.turns_left - 1)

    def run(self, max_rounds: int = 100_000) -> int:
        """Drive until every arrival (and every agentic resubmission)
        has been submitted and finished; returns rounds elapsed."""
        i = 0
        followups: list[Arrival] = []
        while self.rounds < max_rounds:
            while i < len(self.arrivals) and \
                    self.arrivals[i].round <= self.rounds:
                self._submit(self.arrivals[i])
                i += 1
            due = [a for a in followups if a.round <= self.rounds]
            if due:
                followups = [a for a in followups if a.round > self.rounds]
                for a in due:
                    self._submit(a)
            busy = self.serv.step()
            finished = [uid for uid, (req, _) in self._sessions.items()
                        if req.done]
            for uid in finished:
                req, arr = self._sessions.pop(uid)
                followups.append(self._grow(req, arr))
            self.rounds += 1
            if self.on_round is not None:
                self.on_round(self.rounds)
            if (not busy and i >= len(self.arrivals) and not followups
                    and not self._sessions):
                break
        # settle async pipelines (mirrors Engine.run / Cluster.run)
        engines = getattr(self.serv, "engines", None) or [self.serv]
        for eng in engines:
            if eng.async_mode:
                eng._drain()
        harvest = getattr(self.serv, "_harvest_first_tokens", None)
        if harvest is not None:
            harvest()
        return self.rounds


__all__ = ["WORKLOADS", "Arrival", "WorkloadDriver", "build_workload",
           "grow_prompt"]
