"""Gradient compression for the data-parallel all-reduce.

int8 quantization with error feedback (residual carried across steps):
the gradient is scaled per-leaf to int8, reduced in int8 (4x fewer bytes
on the `data` axis all-reduce), dequantized, and the quantization error is
added back to the next step's gradient.  ``compress`` / ``decompress`` are
pure functions so the numerics are unit-testable on CPU; the byte saving
is realized when the reduce runs over the int8 payload (see
``distributed.collectives.int8_psum``).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any

INT8_MAX = 127.0


def init_error(params: Pytree) -> Pytree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(grads: Pytree, error: Pytree):
    """-> (int8 payload, scales, new_error)."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / INT8_MAX
        q = jnp.clip(jnp.round(g32 / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
        new_e = g32 - q.astype(jnp.float32) * scale
        return q, scale, new_e

    out = jax.tree.map(one, grads, error)
    def istuple(x):
        return isinstance(x, tuple)

    q = jax.tree.map(lambda t: t[0], out, is_leaf=istuple)
    s = jax.tree.map(lambda t: t[1], out, is_leaf=istuple)
    e = jax.tree.map(lambda t: t[2], out, is_leaf=istuple)
    return q, s, e


def decompress(q: Pytree, scales: Pytree) -> Pytree:
    return jax.tree.map(lambda qi, si: qi.astype(jnp.float32) * si, q, scales)


def compress_grads(grads: Pytree, error: Pytree):
    """Round-trip (numerics of a compressed all-reduce) + new error state."""
    q, s, e = compress(grads, error)
    return decompress(q, s), e
