"""Pure-JAX AdamW + LR schedules (no optax in this container).

Schedules: cosine, constant, and WSD (warmup-stable-decay) — the MiniCPM
schedule the minicpm-2b config calls for.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig

Pytree = Any


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------
def make_schedule(tc: TrainConfig) -> Callable[[jax.Array], jax.Array]:
    warm, total = tc.warmup_steps, tc.total_steps

    def cosine(step):
        frac = jnp.clip((step - warm) / max(total - warm, 1), 0.0, 1.0)
        return tc.lr * jnp.where(
            step < warm,
            step / max(warm, 1),
            0.5 * (1.0 + jnp.cos(jnp.pi * frac)),
        )

    def const(step):
        return tc.lr * jnp.minimum(step / max(warm, 1), 1.0)

    def wsd(step):
        """Warmup-Stable-Decay (MiniCPM): flat until stable_frac, then a
        fast exponential-ish (cosine-tail) decay to 10% of peak."""
        stable_end = warm + (total - warm) * tc.stable_frac
        decay_frac = jnp.clip((step - stable_end) / max(total - stable_end, 1), 0.0, 1.0)
        decay = 0.1 + 0.9 * 0.5 * (1.0 + jnp.cos(jnp.pi * decay_frac))
        return tc.lr * jnp.where(
            step < warm,
            step / max(warm, 1),
            jnp.where(step < stable_end, 1.0, decay),
        )

    return {"cosine": cosine, "const": const, "wsd": wsd}[tc.schedule]


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AdamW:
    tc: TrainConfig
    moment_dtype: Any = jnp.float32

    def init(self, params: Pytree) -> Pytree:
        def zeros(p):
            return jnp.zeros(p.shape, self.moment_dtype)

        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, grads: Pytree, opt_state: Pytree, params: Pytree):
        tc = self.tc
        step = opt_state["step"] + 1
        lr = make_schedule(tc)(step.astype(jnp.float32))

        # global-norm clip in fp32
        gsq = sum(
            jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)
        )
        gnorm = jnp.sqrt(gsq)
        scale = jnp.minimum(1.0, tc.grad_clip / jnp.maximum(gnorm, 1e-12))

        b1, b2 = tc.b1, tc.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
            m_new = b1 * m32 + (1 - b1) * g
            v_new = b2 * v32 + (1 - b2) * jnp.square(g)
            mhat = m_new / bc1
            vhat = v_new / bc2
            delta = mhat / (jnp.sqrt(vhat) + tc.eps) + tc.weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr * delta
            return (
                p_new.astype(p.dtype),
                m_new.astype(self.moment_dtype),
                v_new.astype(self.moment_dtype),
            )

        out = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"], params)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        new_state = {"m": new_m, "v": new_v, "step": step}
        return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
