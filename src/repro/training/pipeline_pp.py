"""Pipeline parallelism (GPipe schedule) over a mesh axis.

Completes the framework's parallelism matrix (DP/TP/SP/EP/FSDP + PP): the
layer stack is split into ``n_stages`` contiguous stages whose parameters
live on different slices of a mesh axis (at scale: the `pod` axis — stage
boundaries cross the slow DCN link exactly once per microbatch, the
standard multi-pod layout).  Microbatches stream through with a GPipe
schedule inside ``shard_map``; boundary activations move by
``lax.ppermute`` and the bubble is the usual (n_stages-1)/(n_micro +
n_stages - 1).

Differentiable: ppermute has a transpose rule, so ``jax.grad`` through
``pipeline_forward`` yields exact gradients (verified against the
sequential reference in tests/test_pipeline_pp.py).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

Pytree = Any


def _pcast_varying(x: jax.Array, axis: str) -> jax.Array:
    """Mark ``x`` as stage-varying inside shard_map.  ``jax.lax.pcast``
    only exists on jax versions with varying-manual-axes checking; older
    versions treat every value as varying already, so identity is exact."""
    pcast = getattr(jax.lax, "pcast", None)
    if pcast is None:
        return x
    return pcast(x, (axis,), to="varying")


def split_stages(stacked_params: Pytree, n_stages: int) -> Pytree:
    """(L, ...) stacked layer params -> (n_stages, L/n_stages, ...)."""

    def reshape(x):
        L = x.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return x.reshape((n_stages, L // n_stages) + x.shape[1:])

    return jax.tree.map(reshape, stacked_params)


def pipeline_forward(
    block_fn: Callable[[Pytree, jax.Array], jax.Array],
    stage_params: Pytree,          # (n_stages, L/stages, ...) sharded on axis
    x: jax.Array,                  # (n_micro, micro_B, S, D) replicated
    mesh,
    axis: str = "stage",
) -> jax.Array:
    """GPipe forward.  Returns (n_micro, micro_B, S, D) final activations.

    ``block_fn(params_one_stage, h)`` applies one stage's layers.
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    T = n_micro + n_stages - 1

    def per_stage(params_local, x_local):
        # params_local: (1, L/stages, ...); x_local: full (n_micro, ...)
        params_local = jax.tree.map(lambda a: a[0], params_local)
        stage = jax.lax.axis_index(axis)
        micro = x_local  # only stage 0 actually consumes it
        # carries become stage-varying inside the loop; mark them up front
        buf = _pcast_varying(jnp.zeros_like(x_local[0]), axis)
        outs = _pcast_varying(jnp.zeros_like(x_local), axis)

        def step(t, carry):
            buf, outs = carry
            mb = t - stage  # microbatch index active on this stage
            active = (mb >= 0) & (mb < n_micro)
            # stage 0 ingests the microbatch; others use the permuted buf
            inject = jnp.where(
                stage == 0,
                micro[jnp.clip(mb, 0, n_micro - 1)],
                buf,
            )
            h = block_fn(params_local, inject)
            h = jnp.where(active, h, jnp.zeros_like(h))
            # last stage records its output; others forward it
            take = active & (stage == n_stages - 1)
            upd = outs.at[jnp.clip(mb, 0, n_micro - 1)].set(h)
            outs = jnp.where(take, upd, outs)
            buf = jax.lax.ppermute(
                h, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)]
            )
            return buf, outs

        buf, outs = jax.lax.fori_loop(0, T, step, (buf, outs))
        # every device returns its `outs`; only the last stage's is real —
        # psum after masking so the result is replicated across stages
        mask = (stage == n_stages - 1).astype(outs.dtype)
        return jax.lax.psum(outs * mask, axis)

    fn = shard_map(
        per_stage,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
    )
    return fn(stage_params, x)


def sequential_reference(block_fn, stage_params, x, n_stages):
    """Same math without the pipeline (for tests): apply stages in order."""
    out = []
    for m in range(x.shape[0]):
        h = x[m]
        for s in range(n_stages):
            p_s = jax.tree.map(lambda a: a[s], stage_params)
            h = block_fn(p_s, h)
        out.append(h)
    return jnp.stack(out)
