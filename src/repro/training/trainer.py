"""pjit train-step builder.

``make_train_step(model, run_cfg)`` returns:
  * ``init_state(rng)``  — TrainState pytree (params + AdamW moments + step)
  * ``train_step(state, batch) -> (state, metrics)``
  * ``state_specs()``    — PartitionSpec pytree (ZeRO: moments take the
                           params' FSDP/TP specs; with zero_stage>=1 the
                           moments' d_model axis is data-sharded even when
                           params are not, via param_rules(fsdp=True))

Grad accumulation scans over microbatches; remat policy is owned by the
model code (per-block ``jax.checkpoint``).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig
from repro.core.placement import param_rules
from repro.models import common as cm
from repro.models.registry import Model
from repro.training import compression
from repro.training.optimizer import AdamW

Pytree = Any


def make_train_step(model: Model, run: RunConfig):
    tc = run.train
    pc = run.parallel
    opt = AdamW(tc, moment_dtype=jnp.dtype(pc.optimizer_dtype))
    env = model.env
    zrules = param_rules(env.sequence_parallel, fsdp=(pc.zero_stage >= 1 or env.fsdp))
    zspecs = cm.specs_for(model.param_defs, zrules, env.axes, params=True)

    def constrain_grads(grads):
        """Pin the grad accumulator to the ZeRO layout: the per-microbatch
        cross-data reduction then lowers as a reduce-scatter into shards
        instead of a full fp32 all-reduce (§Perf: halves train wire bytes,
        16x smaller resident accumulator)."""
        if not env.axes:
            return grads
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s), grads, zspecs
        )

    def init_state(rng: jax.Array) -> Pytree:
        params = model.init(rng)
        state = {"params": params, "opt": opt.init(params)}
        if pc.grad_compression == "int8":
            state["err"] = compression.init_error(params)
        return state

    def state_shapes() -> Pytree:
        return jax.eval_shape(init_state, jax.ShapeDtypeStruct((2,), jnp.uint32))

    def state_specs() -> Pytree:
        pspecs = model.param_specs()
        # ZeRO-1: moments take FSDP-style specs (d_model over data) even if
        # params are TP-only replicated over data.
        specs = {
            "params": pspecs,
            "opt": {
                "m": zspecs,
                "v": zspecs,
                "step": jax.sharding.PartitionSpec(),
            },
        }
        if pc.grad_compression == "int8":
            specs["err"] = zspecs
        return specs

    def loss_for_grads(params, batch):
        loss, metrics = model.loss_fn(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_for_grads, has_aux=True)

    def compute_grads(params, batch):
        if pc.grad_accum <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        micro = jax.tree.map(
            lambda x: x.reshape((pc.grad_accum, x.shape[0] // pc.grad_accum) + x.shape[1:]),
            batch,
        )

        acc_dt = jnp.dtype(pc.grad_accum_dtype)

        def body(acc, mb):
            (loss, metrics), grads = grad_fn(params, mb)
            acc_g, acc_l = acc
            grads = constrain_grads(grads)
            acc_g = jax.tree.map(
                lambda a, g: a + (g / pc.grad_accum).astype(acc_dt), acc_g, grads
            )
            acc_g = constrain_grads(acc_g)
            return (acc_g, acc_l + loss / pc.grad_accum), metrics

        zero = constrain_grads(
            jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
        )
        (grads, loss), metrics = jax.lax.scan(body, (zero, jnp.float32(0.0)), micro)
        metrics = jax.tree.map(lambda x: x[-1], metrics)
        return loss, metrics, grads

    def train_step(state: Pytree, batch: Pytree):
        loss, metrics, grads = compute_grads(state["params"], batch)
        new_state = dict(state)
        if pc.grad_compression == "int8":
            grads, new_err = compression.compress_grads(grads, state["err"])
            new_state["err"] = new_err
        params, opt_state, opt_metrics = opt.update(grads, state["opt"], state["params"])
        new_state["params"] = params
        new_state["opt"] = opt_state
        metrics = {**metrics, **opt_metrics}
        return new_state, metrics

    return init_state, train_step, state_specs, state_shapes
