import os

# keep tests at 1 device — the 512-device override belongs ONLY to dryrun.py
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)
