"""Attention compute paths: chunked vs naive, decode vs naive, MLA algebra."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.ref import naive_attention, naive_decode_attention
from repro.models.attention import (
    chunked_attention,
    decode_attention,
    mla_decode_attention,
)


@pytest.mark.parametrize("chunk", [4, 8, 16, 64])
def test_chunked_matches_naive_causal(chunk):
    B, S, Hkv, G, D = 2, 32, 2, 3, 16
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hkv * G, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    out = chunked_attention(q, k, v, causal=True, chunk=chunk)
    exp = naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(out, exp, atol=2e-5, rtol=2e-5)


def test_chunked_respects_kv_lengths():
    B, S, H, D = 2, 24, 2, 8
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (B, 1, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    lengths = jnp.array([7, 15])
    out = chunked_attention(q, k, v, causal=False, kv_lengths=lengths, chunk=8)
    k2 = k.at[0, 7:].set(1e3).at[1, 15:].set(-1e3)
    out2 = chunked_attention(q, k2, v, causal=False, kv_lengths=lengths, chunk=8)
    np.testing.assert_allclose(out, out2, atol=1e-6)


def test_decode_dense_matches_naive():
    B, S, Hkv, G, D = 3, 40, 2, 4, 16
    ks = jax.random.split(jax.random.key(2), 4)
    q = jax.random.normal(ks[0], (B, Hkv * G, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    lengths = jnp.array([40, 17, 1])
    out = decode_attention(q, k, v, lengths)
    exp = naive_decode_attention(q, k, v, lengths)
    np.testing.assert_allclose(out, exp, atol=2e-5, rtol=2e-5)


def test_mla_absorbed_equals_expanded():
    """Absorbed-latent decode == expand-then-attend (the MLA identity)."""
    B, S, H, Dc, Dr, Dn = 2, 12, 3, 16, 4, 8
    ks = jax.random.split(jax.random.key(3), 6)
    ckv = jax.random.normal(ks[0], (B, S, Dc))
    krope = jax.random.normal(ks[1], (B, S, Dr))
    q_nope = jax.random.normal(ks[2], (B, H, Dn))
    q_rope = jax.random.normal(ks[3], (B, H, Dr))
    w_uk = jax.random.normal(ks[4], (Dc, H, Dn))
    w_uv = jax.random.normal(ks[5], (Dc, H, Dn))
    scale = 1.0 / math.sqrt(Dn + Dr)
    lengths = jnp.full((B,), S, jnp.int32)

    # expanded: k = ckv @ w_uk per head (+rope), v = ckv @ w_uv
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv, w_uk)
    kf = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope[:, :, None, :], (B, S, H, Dr))], -1
    )
    qf = jnp.concatenate([q_nope, q_rope], -1)
    vf = jnp.einsum("bsr,rhk->bshk", ckv, w_uv)
    s = jnp.einsum("bhk,bshk->bhs", qf, kf) * scale
    p = jax.nn.softmax(s, -1)
    expected = jnp.einsum("bhs,bshk->bhk", p, vf)

    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope, w_uk)
    lat = mla_decode_attention(q_lat, q_rope, ckv, krope, lengths, scale=scale)
    got = jnp.einsum("bhr,rhn->bhn", lat, w_uv)
    np.testing.assert_allclose(got, expected, atol=3e-5, rtol=3e-5)


@settings(max_examples=25, deadline=None)
@given(
    chunk=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**16),
    s=st.integers(4, 40),
)
def test_property_chunked_invariant_to_chunk_size(chunk, seed, s):
    """Online softmax must be exactly chunk-size invariant (fp32)."""
    B, H, D = 1, 2, 8
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (B, s, H, D))
    k = jax.random.normal(ks[1], (B, s, H, D))
    v = jax.random.normal(ks[2], (B, s, H, D))
    a = chunked_attention(q, k, v, causal=True, chunk=chunk)
    b = chunked_attention(q, k, v, causal=True, chunk=s)  # single chunk
    np.testing.assert_allclose(a, b, atol=3e-5, rtol=3e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_property_softmax_weights_sum_to_one(seed):
    """Attention output lies in the convex hull of V rows (per head)."""
    B, S, H, D = 2, 10, 2, 4
    ks = jax.random.split(jax.random.key(seed), 2)
    q = jax.random.normal(ks[0], (B, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jnp.ones((B, S, H, D))
    lengths = jnp.full((B,), S, jnp.int32)
    out = decode_attention(q, k, v, lengths)
    np.testing.assert_allclose(out, jnp.ones_like(out), atol=1e-5)
