"""Checkpointer: atomicity, keep-N GC, async, exact bf16 roundtrip,
restore-into-different-sharding (elastic path)."""
import os

import jax
from repro.launch.mesh import compat_mesh
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer


def _state(key=0):
    ks = jax.random.split(jax.random.key(key), 3)
    return {
        "params": {
            "w": jax.random.normal(ks[0], (8, 4)).astype(jnp.bfloat16),
            "b": jax.random.normal(ks[1], (4,)),
        },
        "opt": {"m": jax.random.normal(ks[2], (8, 4)), "step": jnp.int32(7)},
    }


def test_roundtrip_exact(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state = _state()
    ck.save(3, state)
    step, restored = ck.restore(jax.eval_shape(lambda: state))
    assert step == 3
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_wait(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state = _state()
    ck.save(1, state, blocking=False)
    ck.wait()
    assert ck.latest_step() == 1


def test_keep_n_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_n=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _state(s))
    assert ck.all_steps() == [3, 4]


def test_atomic_no_partial_visible(tmp_path):
    """A tmp dir from a crashed save must never be listed as a step."""
    ck = Checkpointer(str(tmp_path))
    ck.save(5, _state())
    os.makedirs(tmp_path / "tmp.6")  # simulated crash mid-save
    (tmp_path / "tmp.6" / "arrays.npz").write_bytes(b"garbage")
    assert ck.all_steps() == [5]
    assert ck.latest_step() == 5


def test_restore_latest_and_specific(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_n=5)
    s1, s2 = _state(1), _state(2)
    ck.save(1, s1)
    ck.save(2, s2)
    tmpl = jax.eval_shape(lambda: s1)
    step, r = ck.restore(tmpl)
    assert step == 2
    step, r = ck.restore(tmpl, step=1)
    assert step == 1
    np.testing.assert_array_equal(
        np.asarray(r["params"]["b"]), np.asarray(s1["params"]["b"])
    )


def test_restore_with_shardings(tmp_path):
    """Elastic path: restore placing leaves with explicit shardings."""
    mesh = compat_mesh((1,), ("data",))
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    ck = Checkpointer(str(tmp_path))
    state = _state()
    ck.save(1, state)
    shardings = jax.tree.map(lambda _: sh, state)
    _, restored = ck.restore(jax.eval_shape(lambda: state), shardings=shardings)
    assert restored["params"]["w"].sharding == sh


def test_resume_training_bit_exact(tmp_path):
    """Save at step k, keep training; restart from ckpt replays identically
    (deterministic data pipeline + pure train step)."""
    from repro.configs.base import ParallelConfig, RunConfig, TrainConfig
    from repro.configs.reduced import reduce_config
    from repro.core.placement import Env
    from repro.data.pipeline import DataConfig, host_batch
    from repro.models.registry import build_model
    from repro.training.trainer import make_train_step

    cfg = reduce_config("llama3.2-1b")
    model = build_model(cfg, Env())
    run = RunConfig(model=cfg, parallel=ParallelConfig(),
                    train=TrainConfig(lr=1e-3, warmup_steps=2, total_steps=20))
    init_state, train_step, _, _ = make_train_step(model, run)
    dc = DataConfig(vocab=cfg.vocab, seq_len=8, global_batch=4)
    step_fn = jax.jit(train_step)

    ck = Checkpointer(str(tmp_path))
    state = init_state(jax.random.key(0))
    for i in range(3):
        b = {k: jnp.asarray(v) for k, v in host_batch(dc, i, 0, 1).items()}
        state, _ = step_fn(state, b)
    ck.save(3, state)
    # continue to 6
    cont = state
    for i in range(3, 6):
        b = {k: jnp.asarray(v) for k, v in host_batch(dc, i, 0, 1).items()}
        cont, _ = step_fn(cont, b)
    # crash + restore + replay
    _, restored = ck.restore(jax.eval_shape(lambda: state))
    for i in range(3, 6):
        b = {k: jnp.asarray(v) for k, v in host_batch(dc, i, 0, 1).items()}
        restored, _ = step_fn(restored, b)
    for a, b_ in zip(jax.tree.leaves(cont["params"]), jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))
