"""Cluster serving tier: router invariants (exactly-once routing,
spill-over, least-loaded monotonicity), side-effect-free prefix probes,
N=2 cluster greedy equivalence with a single engine (dense and paged),
and the engine-level satellites that feed the router — boundary packing
and victim-only preemption drains."""
import copy

import jax
import numpy as np
import pytest

from repro.configs.reduced import reduce_config
from repro.core.placement import Env
from repro.models.registry import build_model
from repro.serving.cluster import ROUTE_POLICIES, Cluster, Router
from repro.serving.engine import Engine, EngineLoad, Request
from repro.serving.paged.block_pool import BlockPool
from repro.serving.paged.manager import PagedCacheManager


@pytest.fixture(scope="module")
def model_params():
    cfg = reduce_config("llama3.2-1b")
    model = build_model(cfg, Env())
    return model, model.init(jax.random.key(0))


def _requests(prompts, n_new=5):
    return [Request(uid=i, prompt=p, max_new_tokens=n_new)
            for i, p in enumerate(prompts)]


def _serve_engine(model, params, prompts, n_new=5, **kw):
    eng = Engine(model, params, n_slots=2, max_seq=32, **kw)
    reqs = _requests(prompts, n_new)
    for r in reqs:
        eng.submit(r)
    eng.run()
    return reqs


def _serve_cluster(model, params, prompts, n_replicas=2, route="round_robin",
                   n_new=5, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_seq", 32)
    cl = Cluster(model, params, n_replicas, route=route, **kw)
    reqs = _requests(prompts, n_new)
    for r in reqs:
        cl.submit(r)
    stats = cl.run()
    return reqs, stats, cl


PROMPTS = [np.arange(1, 6, dtype=np.int32),
           np.arange(7, 10, dtype=np.int32),
           np.arange(2, 13, dtype=np.int32),
           np.arange(2, 13, dtype=np.int32),      # shared prefix (paged)
           np.arange(4, 25, dtype=np.int32)]      # multi-chunk


# ------------------------------------------------------------ fake replicas
class FakeEngine:
    """Duck-typed replica for pure router tests."""

    def __init__(self, admit=True, inflight=0, free_blocks=None, free_slots=1,
                 prefix_hit=0):
        self.admit = admit
        self.inflight = inflight
        self.free_blocks = free_blocks
        self.free_slots = free_slots
        self.prefix_hit = prefix_hit
        self.submitted = []

    def can_admit(self, req):
        return self.admit

    def load(self):
        return EngineLoad(free_slots=self.free_slots, queued=0,
                          inflight_tokens=self.inflight,
                          free_blocks=self.free_blocks)

    def probe_prefix(self, prompt):
        return self.prefix_hit

    def submit(self, req):
        self.submitted.append(req)
        self.inflight += len(req.prompt)


def _req(n=4, uid=0):
    return Request(uid=uid, prompt=np.arange(1, n + 1, dtype=np.int32),
                   max_new_tokens=2)


# ------------------------------------------------------------------- router
def test_router_rejects_unknown_policy():
    with pytest.raises(ValueError):
        Router([FakeEngine()], "fastest")
    with pytest.raises(ValueError):
        Router([], "round_robin")


def test_router_round_robin_cycles():
    engines = [FakeEngine(), FakeEngine(), FakeEngine()]
    router = Router(engines, "round_robin")
    picks = [router.route(_req(uid=i)) for i in range(6)]
    assert picks == [0, 1, 2, 0, 1, 2]
    assert router.stats.routed == [2, 2, 2]
    assert router.stats.spills == 0
    assert router.stats.total_routed == 6


def test_router_spills_over_saturated_replica():
    engines = [FakeEngine(admit=False), FakeEngine()]
    router = Router(engines, "round_robin")
    assert router.route(_req(uid=0)) == 1          # 0 full -> spill to 1
    assert router.stats.spills == 1
    assert router.stats.routed == [0, 1]


def test_router_returns_none_when_all_saturated():
    engines = [FakeEngine(admit=False), FakeEngine(admit=False)]
    router = Router(engines, "least_loaded")
    assert router.route(_req()) is None
    assert router.stats.total_routed == 0          # nothing counted


def test_router_least_loaded_monotone():
    """Each placement goes to the currently lightest replica, so loads
    level out instead of piling up."""
    engines = [FakeEngine(inflight=9), FakeEngine(inflight=0),
               FakeEngine(inflight=5)]
    router = Router(engines, "least_loaded")
    for i in range(8):
        idx = router.route(_req(n=4, uid=i))
        assert engines[idx].inflight == min(e.inflight for e in engines)
        engines[idx].submit(_req(n=4, uid=100 + i))
    spread = max(e.inflight for e in engines) - min(e.inflight for e in engines)
    assert spread <= 4                              # leveled within one request


def test_router_least_loaded_tiebreak_free_blocks():
    engines = [FakeEngine(inflight=4, free_blocks=1),
               FakeEngine(inflight=4, free_blocks=7)]
    router = Router(engines, "least_loaded")
    assert router.rank(_req()) == [1, 0]


def test_router_prefix_affinity_prefers_hit_then_load():
    engines = [FakeEngine(inflight=0, prefix_hit=0),
               FakeEngine(inflight=99, prefix_hit=16),
               FakeEngine(inflight=1, prefix_hit=16)]
    router = Router(engines, "prefix_affinity")
    # best hit wins; among equal hits the lighter replica goes first
    assert router.rank(_req()) == [2, 1, 0]
    assert router.route(_req(n=4)) == 2
    assert router.stats.prefix_hit_tokens == 16
    assert router.stats.probed_tokens == 4


# ----------------------------------------------------------- probe_prefix
def dataclass_snapshot(pool):
    return tuple(vars(pool.stats).items())


def test_probe_prefix_is_side_effect_free():
    pool = BlockPool(n_blocks=16, block_size=4)
    mgr = PagedCacheManager(pool, n_slots=2, max_blocks=4)
    prompt = np.arange(1, 11, dtype=np.int32)      # 10 tokens = 2.5 blocks
    res = mgr.try_admit(0, prompt)
    assert res is not None

    before = (copy.deepcopy(pool._ref), copy.deepcopy(pool._key_to_block),
              pool.free_count, dataclass_snapshot(pool))
    hit = mgr.probe_prefix(prompt)
    assert hit == 10                                # whole prompt resident
    assert mgr.probe_prefix(prompt[:8]) == 8        # full-block prefix
    assert mgr.probe_prefix(np.arange(50, 60, dtype=np.int32)) == 0
    # a probe must not incref, allocate, register, or bump stats
    after = (pool._ref, pool._key_to_block, pool.free_count,
             dataclass_snapshot(pool))
    assert before == after


def test_admit_shortfall_matches_try_admit():
    pool = BlockPool(n_blocks=16, block_size=4)
    mgr = PagedCacheManager(pool, n_slots=2, max_blocks=4)
    first = np.arange(1, 9, dtype=np.int32)        # 2 blocks exactly
    # exact multiple: needs 2 blocks + 1 decode headroom
    assert mgr.admit_shortfall(first) == 3
    mgr.try_admit(0, first)
    # same prompt again: prefix fully resident, only headroom is fresh
    assert mgr.admit_shortfall(first) == 1
    # shares one block, needs one fresh + no headroom (partial tail)
    second = np.concatenate([first[:4], np.arange(90, 93, dtype=np.int32)])
    assert mgr.admit_shortfall(second) == 1


# ------------------------------------------------------ cluster equivalence
@pytest.mark.parametrize("kw", [
    dict(),
    dict(cache_kind="paged", block_size=8, schedule="hybrid", prefill_chunk=8),
], ids=["dense/decode-only", "paged/hybrid"])
def test_cluster_matches_single_engine(model_params, kw):
    """Routing moves requests, never changes them: every request's greedy
    output in a 2-replica cluster is token-identical to a single engine
    serving the same prompts."""
    model, params = model_params
    single = _serve_engine(model, params, PROMPTS, **kw)
    for route in ROUTE_POLICIES:
        reqs, stats, cl = _serve_cluster(model, params, PROMPTS, route=route, **kw)
        for s, c in zip(single, reqs):
            assert c.done
            assert s.out_tokens == c.out_tokens, (route, s.uid, c.out_tokens)
        # router invariants on a live cluster
        assert stats.generated == sum(len(r.out_tokens) for r in reqs)
        assert sum(s.routed for s in stats.replicas) == len(PROMPTS)
        assert sorted(cl.placement) == [r.uid for r in reqs]
        assert not cl.queue


def test_cluster_rejects_oversized_prompt_and_duplicate_uid(model_params):
    model, params = model_params
    cl = Cluster(model, params, 2, n_slots=2, max_seq=32)
    with pytest.raises(ValueError):
        cl.submit(Request(uid=0, prompt=np.arange(40, dtype=np.int32),
                          max_new_tokens=2))
    cl.submit(_req(uid=7))
    with pytest.raises(ValueError):
        cl.submit(_req(uid=7))


def test_cluster_prefix_affinity_beats_round_robin(model_params):
    """Interleaved shared-prefix groups: affinity routing must land group
    members where their blocks live, round-robin must not."""
    model, params = model_params
    rng = np.random.default_rng(2)
    prefixes = [rng.integers(1, model.cfg.vocab, size=16).astype(np.int32)
                for _ in range(3)]
    prompts = [np.concatenate([prefixes[g],
                               rng.integers(1, model.cfg.vocab, size=3
                                            ).astype(np.int32)])
               for _ in range(3) for g in range(3)]
    # enough slots that group members co-reside: placement, not capacity,
    # decides whether a member lands on its prefix blocks
    kw = dict(cache_kind="paged", block_size=8, schedule="hybrid",
              prefill_chunk=8, n_slots=4, n_new=10)
    _, rr, _ = _serve_cluster(model, params, prompts, route="round_robin", **kw)
    _, aff, _ = _serve_cluster(model, params, prompts, route="prefix_affinity",
                               **kw)
    assert aff.prefix_hit_rate > rr.prefix_hit_rate


# --------------------------------------------- engine satellites (cluster PR)
def test_boundary_packing_keeps_budget_full(model_params):
    """Sarathi-SC: the final partial chunk of one prompt and the head of
    the next ride the same iteration; outputs stay greedy-exact."""
    model, params = model_params
    ref = _serve_engine(model, params, PROMPTS)
    for async_mode in (False, True):
        eng = Engine(model, params, n_slots=2, max_seq=32,
                     schedule="hybrid", prefill_chunk=8,
                     async_mode=async_mode)
        reqs = _requests(PROMPTS)
        for r in reqs:
            eng.submit(r)
        stats = eng.run()
        assert stats.boundary_packs >= 1, "no boundary pack happened"
        for a, b in zip(ref, reqs):
            assert a.out_tokens == b.out_tokens, (async_mode, a.uid)


def test_scheduler_pack_boundary_respects_budget():
    from repro.serving.scheduler import Scheduler
    sched = Scheduler(n_slots=2, max_seq=64, mode="hybrid", prefill_chunk=16)
    sched.begin("req", slot=1, start=0, total=40)
    w = sched.pack_boundary(5)
    assert w is not None and w.n_valid == 5 and w.bucket == 8
    sched.advance(w)
    assert sched.pack_boundary(0) is None
    # paged: a sub-block leftover cannot start a non-final chunk
    sched2 = Scheduler(n_slots=2, max_seq=64, mode="hybrid",
                       prefill_chunk=16, block_size=8)
    sched2.begin("req", slot=0, start=0, total=40)
    assert sched2.pack_boundary(5) is None
    assert sched2.pack_boundary(9).n_valid == 8    # rounds down to the block


def test_preemption_drains_only_the_victim(model_params):
    """Async preemption observes just the victim's in-flight tokens
    (victim_drains counts it); greedy outputs stay exact and the pool
    empties cleanly."""
    model, params = model_params
    prompts = [np.arange(1, 10, dtype=np.int32),
               np.arange(3, 8, dtype=np.int32)]
    kw = dict(cache_kind="paged", block_size=4, n_blocks=9,
              schedule="hybrid", prefill_chunk=8)
    sync = _serve_engine(model, params, prompts, n_new=10,
                         async_mode=False, **kw)
    eng = Engine(model, params, n_slots=2, max_seq=32, async_mode=True, **kw)
    reqs = _requests(prompts, n_new=10)
    for r in reqs:
        eng.submit(r)
    stats = eng.run()
    assert stats.preemptions >= 1
    assert stats.victim_drains >= 1
    for s, a in zip(sync, reqs):
        assert s.out_tokens == a.out_tokens, (s.uid, s.out_tokens, a.out_tokens)
    assert eng.pool.in_use == 0
