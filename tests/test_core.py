"""core/: placement spec invariants (hypothesis), pipeline, balancer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import SHAPES, all_arch_ids, get_config
from repro.core import balance
from repro.core.pipeline import (
    default_batch_axes,
    merge_cache,
    pipelined_step,
    split_cache,
)
from repro.core.placement import POLICIES, Env, kv_rules
from repro.models.common import resolve_spec

AXES_SINGLE = {"data": 16, "model": 16}
AXES_MULTI = {"pod": 2, "data": 16, "model": 16}


@settings(max_examples=200, deadline=None)
@given(
    policy=st.sampled_from(["batch", "head", "sequence", "none"]),
    multi=st.booleans(),
    b=st.sampled_from([1, 2, 8, 32, 128, 256]),
    s=st.sampled_from([128, 4096, 32768, 524288]),
    hkv=st.sampled_from([1, 2, 8, 16, 36, 128]),
    d=st.sampled_from([64, 128]),
)
def test_property_kv_spec_always_valid(policy, multi, b, s, hkv, d):
    """Every resolved spec must divide dims exactly and never reuse a mesh
    axis — the two conditions pjit enforces on in/out shardings."""
    axes = AXES_MULTI if multi else AXES_SINGLE
    shape = (b, s, hkv, d)
    spec = resolve_spec(("kv_batch", "kv_seq", "kv_heads", "head_dim"),
                        kv_rules(policy), axes, shape)
    used = []
    for i, part in enumerate(spec):
        if part is None:
            continue
        names = part if isinstance(part, tuple) else (part,)
        prod = 1
        for a in names:
            assert a in axes
            used.append(a)
            prod *= axes[a]
        assert shape[i] % prod == 0, (spec, shape)
    assert len(used) == len(set(used)), f"axis reused: {spec}"


@settings(max_examples=100, deadline=None)
@given(
    heads=st.sampled_from([8, 16, 24, 32, 36, 56, 64, 128]),
    dim=st.sampled_from([1024, 2048, 7168]),
)
def test_property_param_spec_divides(heads, dim):
    from repro.core.placement import param_rules
    from repro.models.common import ParamDef, resolve_param_spec

    d = ParamDef((4, dim, heads, 128), ("layers", "embed", "heads", "head_dim"))
    spec = resolve_param_spec(d, param_rules(False, True), AXES_SINGLE)
    for i, part in enumerate(spec):
        if part is None:
            continue
        names = part if isinstance(part, tuple) else (part,)
        prod = 1
        for a in names:
            prod *= AXES_SINGLE[a]
        assert d.shape[i] % prod == 0


def test_pipeline_split_merge_roundtrip():
    cache = {
        "k": jnp.arange(2 * 4 * 3).reshape(2, 4, 3).astype(jnp.float32),
        "lengths": jnp.arange(4),
    }
    axes = default_batch_axes(cache)
    subs = split_cache(cache, 2, axes)
    assert subs[0]["k"].shape == (2, 2, 3)
    merged = merge_cache(subs, axes)
    for k in cache:
        np.testing.assert_array_equal(cache[k], merged[k])


def test_pipelined_step_equals_plain_step():
    """Sub-batch pipelining must be a pure reorganization (same numbers)."""
    from repro.configs.reduced import reduce_config
    from repro.models.registry import build_model

    cfg = reduce_config("llama3.2-1b")
    model = build_model(cfg, Env())
    params = model.init(jax.random.key(0))
    B = 4
    toks = jax.random.randint(jax.random.key(1), (B, 8), 0, cfg.vocab)
    cache = model.init_cache(B, 16)
    _, cache = jax.jit(model.prefill)(params, toks, cache)
    nxt = jnp.array([1, 2, 3, 4], jnp.int32)

    log1, c1 = jax.jit(model.decode_step)(params, cache, nxt)
    step2 = pipelined_step(model.decode_step, 2)
    log2, c2 = jax.jit(step2)(params, cache, nxt)
    np.testing.assert_allclose(
        log1.astype(jnp.float32), log2.astype(jnp.float32), atol=1e-6
    )
    for k in c1:
        np.testing.assert_array_equal(np.asarray(c1[k]), np.asarray(c2[k]))


@pytest.mark.parametrize("arch", all_arch_ids())
def test_balance_plan_every_arch(arch):
    cfg = get_config(arch)
    p = balance.plan(cfg, SHAPES["decode_32k"], AXES_MULTI)
    assert p.kv_policy in POLICIES
    assert p.t_attention > 0 and p.t_linear > 0
    assert p.kv_shards >= 1
    # boundary transfer must be tiny relative to the cache read (the
    # paper's core premise, §IV-B)
    assert p.t_boundary < 0.5 * max(p.t_attention, p.t_linear)


def test_env_no_axes_is_noop():
    env = Env()
    assert env.kv_spec(("kv_batch", "kv_seq"), (4, 128)) == jax.sharding.PartitionSpec()
