"""Data pipeline: determinism + rescale-invariance of the global stream."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.data.pipeline import DataConfig, global_batch, host_batch


def test_deterministic_across_calls():
    dc = DataConfig(vocab=1000, seq_len=16, global_batch=8, seed=7)
    a = global_batch(dc, 3)
    b = global_batch(dc, 3)
    np.testing.assert_array_equal(a["inputs"], b["inputs"])


def test_steps_differ():
    dc = DataConfig(vocab=1000, seq_len=16, global_batch=8)
    a = global_batch(dc, 0)
    b = global_batch(dc, 1)
    assert not np.array_equal(a["inputs"], b["inputs"])


def test_targets_are_shifted_inputs():
    dc = DataConfig(vocab=1000, seq_len=16, global_batch=4)
    g = global_batch(dc, 0)
    np.testing.assert_array_equal(g["inputs"][:, 1:], g["targets"][:, :-1])


@settings(max_examples=25, deadline=None)
@given(
    n_hosts=st.sampled_from([1, 2, 4, 8]),
    step=st.integers(0, 1000),
)
def test_property_rescale_invariant_global_stream(n_hosts, step):
    """Concatenating host slices reproduces the global batch regardless of
    host count — the elastic-restart data-order guarantee."""
    dc = DataConfig(vocab=512, seq_len=8, global_batch=16, seed=3)
    g = global_batch(dc, step)
    parts = [host_batch(dc, step, h, n_hosts) for h in range(n_hosts)]
    got = np.concatenate([p["inputs"] for p in parts], axis=0)
    np.testing.assert_array_equal(g["inputs"], got)


def test_tokens_in_vocab():
    dc = DataConfig(vocab=512, seq_len=64, global_batch=8)
    g = global_batch(dc, 5)
    assert g["inputs"].min() >= 0 and g["inputs"].max() < 512
