"""Disaggregated prefill/decode serving: role parsing and router role
filtering, 1P+1D greedy equivalence with a single mixed engine across
cache/schedule/async combos, and the KV block-migration edge cases —
shared-prefix export leaves the source's refcounts and hash entries
intact, importing into a full pool spills to the host tier instead of
preempting, fp8 migration moves quantized blocks and their scale pools
bit-exactly, and router-driven refold moves reproduce the preempted
request's decode exactly on its new replica."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.reduced import reduce_config
from repro.core.placement import Env
from repro.models.registry import build_model
from repro.serving.cluster import Cluster, Router, parse_roles
from repro.serving.engine import Engine, EngineLoad, Request
from repro.serving.paged import device as paged_dev
from repro.serving.telemetry import Tracer
from repro.serving.telemetry.export import build_request_trees


@pytest.fixture(scope="module")
def model_params():
    cfg = reduce_config("llama3.2-1b")
    model = build_model(cfg, Env())
    return model, model.init(jax.random.key(0))


PROMPTS = [np.arange(1, 6, dtype=np.int32),
           np.arange(7, 10, dtype=np.int32),
           np.arange(2, 13, dtype=np.int32),
           np.arange(2, 13, dtype=np.int32),      # shared prefix (paged)
           np.arange(4, 25, dtype=np.int32)]      # multi-chunk


def _run_single(model, params, prompts, n_new=5, **kw):
    eng = Engine(model, params, n_slots=4, max_seq=32, **kw)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=n_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return [list(r.out_tokens) for r in reqs]


def _run_disagg(model, params, prompts, roles="1p+1d", n_new=5, tracer=None,
                **kw):
    cl = Cluster(model, params, 2, roles=roles, tracer=tracer,
                 n_slots=4, max_seq=32, **kw)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=n_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        cl.submit(r)
    stats = cl.run()
    return [list(r.out_tokens) for r in reqs], stats, cl


# -------------------------------------------------------------- role parsing
def test_parse_roles():
    assert parse_roles(None, 3) == ["mixed"] * 3
    assert parse_roles("1p+1d", 2) == ["prefill", "decode"]
    assert parse_roles("2P+1D+1M", 4) == ["prefill", "prefill", "decode",
                                          "mixed"]
    assert parse_roles("prefill, decode", 2) == ["prefill", "decode"]
    assert parse_roles(["mixed", "mixed"], 2) == ["mixed", "mixed"]


@pytest.mark.parametrize("spec,n", [
    ("1p+1d", 3),                   # wrong length
    ("prefill,banana", 2),          # unknown role
    ("decode,decode", 2),           # nothing can admit
    ("prefill,prefill", 2),         # nowhere to migrate
    ("mixed,decode", 2),            # decode with no prefill source
])
def test_parse_roles_rejects(spec, n):
    with pytest.raises(ValueError):
        parse_roles(spec, n)


# ---------------------------------------------------------- router filtering
class _FakeEngine:
    def __init__(self, inflight=0):
        self.inflight = inflight

    def can_admit(self, req):
        return True

    def probe_prefix(self, prompt):
        return 0

    def load(self):
        return EngineLoad(free_slots=1, queued=0,
                          inflight_tokens=self.inflight, free_blocks=None)


def test_router_role_filtering():
    engines = [_FakeEngine(10), _FakeEngine(0), _FakeEngine(5)]
    r = Router(engines, "least_loaded", roles=["prefill", "decode", "mixed"])
    req = Request(uid=0, prompt=np.arange(1, 4, dtype=np.int32),
                  max_new_tokens=2)
    # admission never ranks the decode replica; decode ranking never
    # includes the prefill replica; both orders are least-loaded first
    assert r.rank(req) == [2, 0]
    assert r.rank_decode() == [1, 2]
    assert r.rank_decode(exclude=1) == [2]
    assert r.rank_refold() == [2, 0]
    assert r.route(req) == 2

    with pytest.raises(ValueError):
        Router(engines, "round_robin", roles=["decode", "decode", "decode"])


# ------------------------------------------------------------- equivalence
@pytest.mark.parametrize("kw", [
    dict(cache_kind="dense", async_mode=True),
    dict(cache_kind="paged", block_size=4, async_mode=False),
    dict(cache_kind="paged", block_size=4, async_mode=True),
    dict(cache_kind="paged", block_size=4, schedule="hybrid",
         prefill_chunk=4, async_mode=False),
    dict(cache_kind="paged", block_size=4, schedule="hybrid",
         prefill_chunk=4, async_mode=True),
], ids=["dense-async", "paged-sync", "paged-async", "hybrid-sync",
        "hybrid-async"])
def test_disagg_greedy_equivalence(model_params, kw):
    """1P+1D greedy outputs are token-identical to a single mixed engine:
    migration moves work, never changes it."""
    model, params = model_params
    ref = _run_single(model, params, PROMPTS, **kw)
    got, stats, _ = _run_disagg(model, params, PROMPTS, **kw)
    assert got == ref
    assert stats.migrations > 0
    # every request prefilled on the prefill replica, decoded on decode
    assert stats.replicas[0].routed == len(PROMPTS)
    assert stats.replicas[1].routed == 0


def test_disagg_trace_marks(model_params):
    """A traced disaggregated run emits cluster-row kv_migrate marks and
    every folded request tree stays well-formed (migrated-in histories
    legitimately start mid-decode)."""
    model, params = model_params
    tracer = Tracer()
    _, stats, _ = _run_disagg(model, params, PROMPTS, tracer=tracer,
                              cache_kind="paged", block_size=4)
    marks = [e for e in tracer.events if e.name == "kv_migrate"]
    assert len(marks) == stats.migrations > 0
    problems = [p for t in build_request_trees(tracer).values()
                for p in t.well_formed()]
    assert problems == []


# --------------------------------------------------------- migration edges
def test_shared_prefix_export_keeps_source_intact(model_params):
    """Copy-on-export: exporting one of two prefix-sharing requests
    decrefs the shared blocks but leaves the other owner's blocks and
    their hash registrations untouched — its decode continues exactly."""
    model, params = model_params
    prompt = np.arange(2, 14, dtype=np.int32)      # 12 tokens = 3 blocks
    solo = _run_single(model, params, [prompt], n_new=6,
                       cache_kind="paged", block_size=4, async_mode=False)

    eng = Engine(model, params, n_slots=2, max_seq=32, cache_kind="paged",
                 block_size=4, async_mode=False)
    reqs = [Request(uid=i, prompt=prompt, max_new_tokens=6) for i in range(2)]
    for r in reqs:
        eng.submit(r)
    eng.step()                                     # both admitted + 1 token
    blocks0 = [b for b in eng.manager.blocks[0] if b]
    shared = [b for b in blocks0 if eng.pool.refcount(b) > 1]
    assert shared, "prefix blocks were not shared before export"

    exported = eng.export_request(1)
    assert exported is not None
    req1, ticket, _ = exported
    assert ticket.n_blocks > 0
    assert eng.stats.migrations_out == 1
    # the remaining owner's blocks survive with their hash entries intact
    for b, k in zip(eng.manager.blocks[0], eng.manager.keys[0]):
        if b and k is not None:
            assert eng.pool.refcount(b) >= 1
            assert eng.pool.peek(k) == b
    eng.run()
    assert list(reqs[0].out_tokens) == solo[0]


def test_import_into_full_pool_spills_not_preempts(model_params):
    """Import under block pressure with a host tier: the destination
    spills resident cold-prefix blocks host-ward to make room — nobody
    is preempted, and both the resident and the migrated request finish
    with unchanged greedy outputs."""
    model, params = model_params
    p_res = np.arange(3, 19, dtype=np.int32)       # 16 tokens = 4 blocks
    p_mig = np.arange(5, 21, dtype=np.int32)
    kw = dict(cache_kind="paged", block_size=4, async_mode=False)
    solo_res = _run_single(model, params, [p_res], n_new=6, **kw)
    solo_mig = _run_single(model, params, [p_mig], n_new=6, **kw)

    src = Engine(model, params, n_slots=1, max_seq=32, **kw)
    mig = Request(uid=1, prompt=p_mig, max_new_tokens=6)
    src.submit(mig)
    src.step()
    exported = src.export_request(0)
    assert exported is not None
    req, ticket, payload = exported

    # 8 usable blocks: the resident sequence holds 5 after one decode
    # append, so the 5-block import cannot fit without the host tier
    dst = Engine(model, params, n_slots=2, max_seq=32, n_blocks=9,
                 host_blocks=8, **kw)
    res = Request(uid=0, prompt=p_res, max_new_tokens=6)
    dst.submit(res)
    dst.step()
    fresh = dst.manager.import_shortfall(ticket.keys, ticket.length)
    assert fresh > dst.pool.free_count, "setup: pool is not actually full"

    slot = dst.import_request(req, ticket, payload)
    assert slot is not None
    assert dst.stats.preemptions == 0
    assert dst.pool.stats.spills > 0
    dst.run()
    assert dst.stats.preemptions == 0
    assert list(res.out_tokens) == solo_res[0]
    assert list(mig.out_tokens) == solo_mig[0]


def test_fp8_migration_bit_exact(model_params):
    """Same-tier fp8 migration is a raw storage-dtype copy: quantized
    payload blocks and their scale tiles land bit-identical on the
    destination (no dequant/requant round trip)."""
    model, params = model_params
    prompt = np.arange(2, 14, dtype=np.int32)
    kw = dict(cache_kind="paged", block_size=4, kv_dtype="fp8",
              async_mode=False)
    solo = _run_single(model, params, [prompt], n_new=6, **kw)

    src = Engine(model, params, n_slots=1, max_seq=32, **kw)
    req = Request(uid=0, prompt=prompt, max_new_tokens=6)
    src.submit(req)
    src.step()
    exported = src.export_request(0)
    assert exported is not None
    req, ticket, payload = exported

    dst = Engine(model, params, n_slots=1, max_seq=32, **kw)
    slot = dst.import_request(req, ticket, payload)
    assert slot is not None
    ids = [b for b in dst.manager.blocks[slot] if b][:ticket.n_blocks]
    landed = paged_dev.copy_blocks_out(dst.cache, ids)
    for name in ("k", "v", "k_scale", "v_scale"):
        a, b = payload[name], landed[name]
        assert a.dtype == b.dtype
        # fp8 bit pattern compare (== on fp8 NaNs would mask a mismatch)
        assert jnp.array_equal(
            jax.lax.bitcast_convert_type(a, jnp.uint8),
            jax.lax.bitcast_convert_type(b, jnp.uint8),
        ), f"{name} pool changed across migration"
    dst.run()
    assert list(req.out_tokens) == solo[0]


def test_dtype_mismatch_refuses_migration(model_params):
    """can_import refuses a ticket whose kv_dtype differs — migration is
    a storage-dtype copy, never a requantization."""
    model, params = model_params
    src = Engine(model, params, n_slots=1, max_seq=32, cache_kind="paged",
                 block_size=4, kv_dtype="fp8", async_mode=False)
    req = Request(uid=0, prompt=PROMPTS[0], max_new_tokens=4)
    src.submit(req)
    src.step()
    ticket = src.preview_export(0)
    assert ticket is not None
    dst = Engine(model, params, n_slots=1, max_seq=32, cache_kind="paged",
                 block_size=4, kv_dtype="bf16", async_mode=False)
    assert not dst.can_import(ticket)


def test_refold_move_reproduces_decode(model_params):
    """Router-driven refold placement: a preempted request stranded at a
    busy replica's queue front refolds on the least-loaded replica and
    continues its greedy decode exactly."""
    model, params = model_params
    kw = dict(cache_kind="paged", block_size=4, async_mode=False)
    prompt = np.arange(2, 14, dtype=np.int32)
    solo = _run_single(model, params, [prompt], n_new=6, **kw)

    cl = Cluster(model, params, 2, n_slots=1, max_seq=32, **kw)
    blocker = Request(uid=0, prompt=PROMPTS[4], max_new_tokens=8)
    cl.submit(blocker)
    cl.step()                                      # occupies r0's only slot
    assert cl.engines[0].slots[0] is blocker

    # a preempted request: one token already generated, waiting at r0
    refold = Request(uid=1, prompt=prompt, max_new_tokens=6)
    refold.out_tokens.append(solo[0][0])
    refold.first_token_step = 1
    cl.engines[0].sched.push_front(refold)
    assert not cl.engines[0].can_admit_next()

    moved = cl._rebalance_refolds()
    assert moved == 1
    assert cl.refold_moves == 1
    assert cl.placement[1] == 1
    assert cl.engines[1].sched.peek() is refold
    cl.run()
    assert list(refold.out_tokens) == solo[0]
    assert list(blocker.out_tokens) == _run_single(
        model, params, [PROMPTS[4]], n_new=8, **kw)[0]
