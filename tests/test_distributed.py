"""Fault tolerance: straggler detection, heartbeat, elastic rescale
(hypothesis), supervisor restart-from-checkpoint."""
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.distributed.fault_tolerance import (
    Heartbeat,
    StragglerMonitor,
    Supervisor,
    plan_rescale,
)


def test_straggler_flagged_after_patience():
    mon = StragglerMonitor(n_workers=4, window=4, threshold=1.5, patience=2)
    for step in range(6):
        for w in range(4):
            mon.record(w, 1.0 if w != 2 else 3.0)
        flagged = mon.check()
    assert flagged == [2]


def test_straggler_recovers():
    mon = StragglerMonitor(n_workers=2, window=4, threshold=1.5, patience=2)
    for _ in range(4):
        mon.record(0, 1.0)
        mon.record(1, 5.0)
        mon.check()
    for _ in range(6):
        mon.record(0, 1.0)
        mon.record(1, 1.0)
        flagged = mon.check()
    assert flagged == []


def test_heartbeat_dead_detection():
    hb = Heartbeat(3, timeout=10.0)
    now = 100.0
    for w in range(3):
        hb.beat(w, now=now)
    assert hb.dead(now=105.0) == []
    hb.beat(0, now=115.0)
    hb.beat(2, now=115.0)
    assert hb.dead(now=115.0) == [1]


@settings(max_examples=200, deadline=None)
@given(
    n=st.integers(1, 4096),
    mp=st.sampled_from([1, 2, 4, 8, 16]),
    gb=st.sampled_from([32, 64, 128, 256, 512]),
)
def test_property_plan_rescale_valid(n, mp, gb):
    try:
        plan = plan_rescale(n, mp, gb)
    except ValueError:
        return  # legitimately impossible (e.g. capacity > batch)
    capacity = 1
    for s, a in zip(plan.shape, plan.axes):
        if a in ("pod", "data"):
            capacity *= s
        else:
            assert s == mp
    # the invariants the trainer relies on:
    assert plan.global_batch == gb                       # batch preserved
    assert gb % plan.grad_accum == 0
    assert (gb // plan.grad_accum) % capacity == 0       # micro divides shards


def test_plan_rescale_drops_spares():
    plan = plan_rescale(35, 4, 64)  # 3 spare devices dropped -> 32 usable
    assert plan.shape == (8, 4)


def test_supervisor_restarts_from_checkpoint():
    calls = []
    saved = {"latest": None}

    def run_fn(start_step):
        calls.append(start_step)
        for s in range(start_step, 10):
            if s == 4 and len(calls) == 1:
                saved["latest"] = 3
                raise RuntimeError("node died")
        return 9

    sup = Supervisor(run_fn, lambda: saved["latest"], max_restarts=2)
    last = sup.run(0)
    assert last == 9
    assert calls == [0, 3]  # resumed from the checkpointed step
    assert sup.restarts == 1


def test_supervisor_gives_up():
    def run_fn(start_step):
        raise RuntimeError("always dies")

    sup = Supervisor(run_fn, lambda: None, max_restarts=2)
    with pytest.raises(RuntimeError):
        sup.run(0)
    assert sup.restarts == 3
