"""HLO cost walker: exact on loop-free graphs, trip-count-correct on scans."""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis.hlo_cost import analyze


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_loopfree_matches_xla_cost_analysis():
    def f(x, w):
        return jnp.tanh(x @ w) @ w

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = _compile(f, x, x)
    got = analyze(c.as_text())
    xla = c.cost_analysis()
    if isinstance(xla, list):   # jax < 0.5 returns one dict per device
        xla = xla[0]
    assert got.flops == pytest.approx(xla["flops"], rel=0.01)


def test_scan_flops_scale_with_trip_count():
    def f(x, w):
        def body(c, wl):
            return c @ wl, None
        out, _ = jax.lax.scan(body, x, w)
        return out

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    per_layer = 2 * 64**3
    for L in (1, 4, 16):
        w = jax.ShapeDtypeStruct((L, 64, 64), jnp.float32)
        got = analyze(_compile(f, x, w).as_text())
        assert got.flops == pytest.approx(L * per_layer, rel=0.02), L


def test_nested_scan_trips_multiply():
    def f(x, w):
        def outer(c, wl):
            def inner(ci, _):
                return ci @ wl, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        out, _ = jax.lax.scan(outer, x, w)
        return out

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((5, 32, 32), jnp.float32)
    got = analyze(_compile(f, x, w).as_text())
    assert got.flops == pytest.approx(5 * 3 * 2 * 32**3, rel=0.05)


def test_dot_flops_with_batch_dims():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    a = jax.ShapeDtypeStruct((4, 32, 16), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 16, 8), jnp.float32)
    got = analyze(_compile(f, a, b).as_text())
    assert got.flops == pytest.approx(2 * 4 * 32 * 16 * 8, rel=0.01)


def test_bytes_reasonable_for_copy_free_graph():
    def f(x):
        return x * 2.0 + 1.0

    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    got = analyze(_compile(f, x).as_text())
    # in+out = 8 MB; allow generous slack for fusion accounting
    assert 4e6 <= got.bytes <= 2.5e7
