"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


DECODE_CASES = [
    # (B, S, Hkv, G, D, block_s)
    (1, 16, 1, 1, 8, 8),       # MHA degenerate (the paper's prototype, OI~1)
    (2, 64, 2, 4, 32, 16),     # GQA group 4
    (3, 128, 4, 8, 64, 32),    # GQA group 8 (the HPU design point, OI~8)
    (2, 96, 2, 7, 16, 32),     # non-pow2 group (yi-34b style), padded blocks
    (1, 33, 1, 2, 128, 16),    # ragged S -> padding path
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", DECODE_CASES)
def test_decode_attention_matches_oracle(case, dtype):
    B, S, Hkv, G, D, block = case
    Hq = Hkv * G
    ks = jax.random.split(jax.random.key(hash(case) % 2**31), 4)
    q = _rand(ks[0], (B, Hq, D), dtype)
    kc = _rand(ks[1], (B, S, Hkv, D), dtype)
    vc = _rand(ks[2], (B, S, Hkv, D), dtype)
    lengths = jax.random.randint(ks[3], (B,), 1, S + 1)
    out = ops.decode_attention(q, kc, vc, lengths, block_s=block)
    exp = ref.naive_decode_attention(q, kc, vc, lengths)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        out.astype(jnp.float32), exp.astype(jnp.float32), atol=tol, rtol=tol
    )


PREFILL_CASES = [
    # (B, Sq, Sk, Hkv, G, D, bq, bk, causal)
    (1, 16, 16, 1, 1, 8, 8, 8, True),
    (2, 32, 32, 2, 4, 16, 16, 16, True),
    (2, 64, 64, 2, 2, 32, 16, 32, True),
    (1, 32, 32, 4, 1, 64, 16, 16, False),
    (2, 48, 48, 2, 3, 16, 16, 16, True),   # non-pow2 group
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", PREFILL_CASES)
def test_flash_attention_matches_oracle(case, dtype):
    B, Sq, Sk, Hkv, G, D, bq, bk, causal = case
    Hq = Hkv * G
    ks = jax.random.split(jax.random.key(hash(case) % 2**31), 3)
    q = _rand(ks[0], (B, Sq, Hq, D), dtype)
    k = _rand(ks[1], (B, Sk, Hkv, D), dtype)
    v = _rand(ks[2], (B, Sk, Hkv, D), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    exp = ref.naive_attention(q, k, v, causal=causal)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        out.astype(jnp.float32), exp.astype(jnp.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("off", [0, 5, 17, 32])
def test_flash_attention_q_offset_matches_oracle(off):
    """Chunked-prefill continuation: a (Sq=chunk) query block at absolute
    position `off` against a (Sk=cache) window, causal at the offset."""
    B, Sq, Sk, Hkv, G, D = 2, 16, 48, 2, 2, 16
    ks = jax.random.split(jax.random.key(off), 3)
    q = _rand(ks[0], (B, Sq, Hkv * G, D), jnp.float32)
    k = _rand(ks[1], (B, Sk, Hkv, D), jnp.float32)
    v = _rand(ks[2], (B, Sk, Hkv, D), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, q_offset=off,
                              block_q=8, block_k=16)
    exp = ref.naive_attention(q, k, v, causal=True, q_offset=off)
    np.testing.assert_allclose(out, exp, atol=2e-6, rtol=2e-6)


def test_decode_attention_respects_lengths():
    """Tokens beyond `lengths` must not influence the output."""
    B, S, Hkv, G, D = 2, 32, 2, 2, 16
    ks = jax.random.split(jax.random.key(0), 4)
    q = _rand(ks[0], (B, Hkv * G, D), jnp.float32)
    kc = _rand(ks[1], (B, S, Hkv, D), jnp.float32)
    vc = _rand(ks[2], (B, S, Hkv, D), jnp.float32)
    lengths = jnp.array([10, 20])
    out1 = ops.decode_attention(q, kc, vc, lengths, block_s=8)
    # trash the masked tail
    kc2 = kc.at[0, 10:].set(99.0).at[1, 20:].set(-99.0)
    vc2 = vc.at[0, 10:].set(7.0).at[1, 20:].set(-7.0)
    out2 = ops.decode_attention(q, kc2, vc2, lengths, block_s=8)
    np.testing.assert_allclose(out1, out2, atol=1e-6)
