"""Direct invariants of serving/kv_cache.py (dense slot cache)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.reduced import reduce_config
from repro.core.placement import Env
from repro.models.registry import build_model
from repro.serving import kv_cache


def _model():
    return build_model(reduce_config("llama3.2-1b"), Env())


def test_insert_then_reset_roundtrips():
    model = _model()
    cache = model.init_cache(3, 16)
    before = jax.tree.map(lambda v: np.asarray(v), cache)

    sub = model.init_cache(1, 16)
    sub = {k: jnp.full_like(v, 2 if k != "lengths" else 7) for k, v in sub.items()}
    c2 = kv_cache.insert(cache, sub, 1)

    # slot 1 took the sub-cache, neighbours untouched
    assert float(c2["k"][:, 1].min()) == 2.0
    assert int(c2["lengths"][1]) == 7
    for slot in (0, 2):
        np.testing.assert_array_equal(np.asarray(c2["k"][:, slot]), before["k"][:, slot])
        assert int(c2["lengths"][slot]) == 0

    c3 = kv_cache.reset_slot(c2, 1)
    for k in cache:
        np.testing.assert_array_equal(np.asarray(c3[k]), before[k])


def test_insert_slots_independent():
    model = _model()
    cache = model.init_cache(2, 8)
    sub_a = {k: jnp.full_like(v, 1) for k, v in model.init_cache(1, 8).items()}
    sub_b = {k: jnp.full_like(v, 3) for k, v in model.init_cache(1, 8).items()}
    c = kv_cache.insert(kv_cache.insert(cache, sub_a, 0), sub_b, 1)
    assert float(c["v"][:, 0].max()) == 1.0
    assert float(c["v"][:, 1].min()) == 3.0


def test_kv_bytes_accounting():
    model = _model()
    cache = model.init_cache(2, 16)
    expect = sum(v.size * v.dtype.itemsize for v in jax.tree.leaves(cache))
    assert kv_cache.kv_bytes(cache) == expect
    # doubling slots doubles every batch-carrying leaf
    assert kv_cache.kv_bytes(model.init_cache(4, 16)) == 2 * expect

    cfg = reduce_config("llama3.2-1b")
    L, Hkv, Dh = cfg.n_layers, cfg.n_kv_heads, cfg.resolved_head_dim()
    kv_leaf_bytes = 2 * L * 2 * 16 * Hkv * Dh * 2   # k+v, B=2, S=16, bf16
    assert kv_leaf_bytes <= expect < kv_leaf_bytes + 1024


def test_n_slots_and_batch_axis():
    model = _model()
    cache = model.init_cache(5, 8)
    assert kv_cache.n_slots(cache) == 5
    assert kv_cache.batch_axis("lengths") == 0
    assert kv_cache.batch_axis("k") == 1
