"""Tiered KV memory: quantized block pools and the host-offloaded cold
tier must not change what the engine serves.

Three layers of guarantee, mirroring how the tiers compose:

* block quantization round-trips within the format's step size, and the
  quantized paged kernel matches the dequantize-then-attend oracle;
* hybrid attention over a hot/cold split — device kernel over the hot
  window, oracle over the cold prefix, combined by log-sum-exp — is
  exactly full attention over the whole sequence;
* end-to-end, a host-tier run that spilled live blocks decodes the same
  greedy tokens as an unspilled run, with zero preemptions, and
  quantized pools stay greedy-faithful across every schedule combo.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.reduced import reduce_config
from repro.core.placement import Env
from repro.kernels import ops, ref
from repro.models.registry import build_model
from repro.serving.engine import Engine, Request
from repro.serving.paged import device as paged_dev
from repro.serving.paged.block_pool import BlockPool, chain_key
from repro.serving.paged.manager import PagedCacheManager


# ------------------------------------------------------------ quantization
@pytest.mark.parametrize("kv_dtype,tol", [("fp8", 0.07), ("int8", 0.005)])
def test_kv_quantize_roundtrip_bounded(kv_dtype, tol):
    """Dequantized blocks sit within the format's per-vector step size of
    the original (absmax scaling: error scales with the vector's amax)."""
    x = jax.random.normal(jax.random.key(0), (4, 8, 16, 64), jnp.float32) * 3
    payload, scale = ref.kv_quantize(x, kv_dtype)
    back = ref.kv_dequantize(payload, scale, jnp.float32)
    amax = np.max(np.abs(np.asarray(x)), axis=-1, keepdims=True)
    err = np.abs(np.asarray(back) - np.asarray(x))
    assert np.all(err <= tol * amax + 1e-7)


@pytest.mark.parametrize("kv_dtype", ["fp8", "int8"])
def test_kv_quantize_zero_vector_is_exact(kv_dtype):
    payload, scale = ref.kv_quantize(jnp.zeros((2, 4, 8)), kv_dtype)
    assert np.all(np.asarray(scale) == 0.0)
    back = ref.kv_dequantize(payload, scale, jnp.float32)
    assert np.all(np.asarray(back) == 0.0)


@pytest.mark.parametrize("kv_dtype", ["fp8", "int8"])
def test_kv_quantize_roundtrip_property(kv_dtype):
    """Property test over adversarial vectors (huge dynamic range, exact
    zeros, single-element spikes) — hypothesis-gated."""
    hyp = pytest.importorskip("hypothesis")
    hnp = pytest.importorskip("hypothesis.extra.numpy")
    st = hyp.strategies

    @hyp.given(
        hnp.arrays(
            np.float32, (3, 8),
            elements=st.floats(-1e4, 1e4, width=32, allow_nan=False),
        )
    )
    @hyp.settings(max_examples=200, deadline=None)
    def run(x):
        payload, scale = ref.kv_quantize(jnp.asarray(x), kv_dtype)
        back = np.asarray(ref.kv_dequantize(payload, scale, jnp.float32))
        amax = np.max(np.abs(x), axis=-1, keepdims=True)
        tol = 0.07 if kv_dtype == "fp8" else 0.005
        assert np.all(np.abs(back - x) <= tol * amax + 1e-7)

    run()


@pytest.mark.parametrize("kv_dtype", ["fp8", "int8"])
def test_paged_kernel_quantized_matches_oracle(kv_dtype):
    """The in-kernel dequantize path == gather + dequantize + dense
    oracle, and both sit close to the unquantized attention."""
    B, Hkv, G, D, bs, MB = 3, 2, 4, 16, 8, 4
    N = 1 + B * MB
    ks = jax.random.split(jax.random.key(7), 3)
    q = jax.random.normal(ks[0], (B, Hkv * G, D), jnp.float32)
    kp = jax.random.normal(ks[1], (N, Hkv, bs, D), jnp.float32)
    vp = jax.random.normal(ks[2], (N, Hkv, bs, D), jnp.float32)
    rng = np.random.default_rng(0)
    perm = iter(rng.permutation(np.arange(1, N)))
    lens = (5, 17, 32)
    tables = np.zeros((B, MB), np.int32)
    for b in range(B):
        for j in range(-(-int(lens[b]) // bs)):
            tables[b, j] = next(perm)
    tables = jnp.asarray(tables)
    lengths = jnp.asarray(lens, jnp.int32)

    kq, k_scale = ref.kv_quantize(kp, kv_dtype)
    vq, v_scale = ref.kv_quantize(vp, kv_dtype)
    out = ops.paged_decode_attention(q, kq, vq, tables, lengths,
                                     k_scale=k_scale, v_scale=v_scale)
    exp = ref.paged_decode_attention(q, kq, vq, tables, lengths,
                                     k_scale=k_scale, v_scale=v_scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-2, rtol=2e-2)
    full = ref.paged_decode_attention(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full),
                               atol=0.15, rtol=0.15)


# --------------------------------------------------------------- LSE merge
def test_lse_merge_matches_full_attention_oracle():
    """Hot-window attention + cold-prefix attention, LSE-merged, must
    equal one full-sequence attention — including empty cold windows."""
    B, Hkv, G, D, S = 4, 2, 3, 16, 24
    ks = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(ks[0], (B, Hkv * G, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    lengths = jnp.asarray([5, 24, 17, 9], jnp.int32)
    starts = jnp.asarray([0, 8, 16, 8], jnp.int32)   # 0 = nothing cold

    hot = ref.naive_decode_attention(q, k, v, lengths, starts=starts,
                                     return_lse=True)
    cold = ref.naive_decode_attention(q, k, v, starts, return_lse=True)
    merged = ref.lse_merge([hot, cold])
    full = ref.naive_decode_attention(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(full),
                               atol=1e-5, rtol=1e-5)


def test_lse_merge_kernel_hot_window_matches_oracle():
    """The Pallas kernel's (out, lse) over a ``starts``-restricted hot
    window merges with a cold-prefix oracle part into full attention."""
    B, Hkv, G, D, bs, MB = 2, 2, 4, 16, 8, 4
    N = 1 + B * MB
    ks = jax.random.split(jax.random.key(11), 3)
    q = jax.random.normal(ks[0], (B, Hkv * G, D), jnp.float32)
    kp = jax.random.normal(ks[1], (N, Hkv, bs, D), jnp.float32)
    vp = jax.random.normal(ks[2], (N, Hkv, bs, D), jnp.float32)
    tables = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 8]], jnp.int32)
    lengths = jnp.asarray([29, 32], jnp.int32)
    starts = jnp.asarray([8, 16], jnp.int32)         # cold: 1 resp. 2 blocks

    hot = ops.paged_decode_attention(q, kp, vp, tables, lengths,
                                     starts=starts, return_lse=True)
    cold = ref.paged_decode_attention(q, kp, vp, tables, starts,
                                      return_lse=True)
    merged = ref.lse_merge([hot, cold])
    full = ref.paged_decode_attention(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(full),
                               atol=2e-4, rtol=2e-4)


# ----------------------------------------------------- pool/manager host tier
def test_pool_free_time_spill_and_host_lru():
    """A registered block's last decref spills it to the host tier; the
    host tier evicts its LRU unreferenced block when full."""
    pool = BlockPool(n_blocks=8, block_size=4, host_blocks=2)
    keys = []
    for i in range(3):
        b = pool.alloc()
        k = chain_key(keys[-1] if keys else None, (i, i, i, i))
        pool.register(k, b)
        keys.append(k)
        pool.decref(b)                       # -> free-time spill
    # 3 spills through a 2-block host tier: one LRU eviction
    assert pool.stats.spills == 3
    assert pool.stats.host_evictions == 1
    assert pool.host_in_use == 2
    assert pool.host_peek(keys[0]) is None   # the evicted one
    assert pool.host_peek(keys[2]) is not None
    directives = pool.drain_directives()
    assert [d[0] for d in directives] == ["spill"] * 3


def test_manager_rehydrates_host_prefix_on_admission():
    """Host-tier prefix hits admit as cached (no recompute) by copying
    the block back into a fresh device block."""
    pool = BlockPool(n_blocks=8, block_size=4, host_blocks=4)
    mgr = PagedCacheManager(pool, n_slots=2, max_blocks=4)
    toks = np.arange(100, 110, dtype=np.int32)      # 3 blocks (1 partial)
    ids = mgr.try_admit(0, toks)
    assert ids is not None
    mgr.free_slot(0)                                 # registered blocks spill
    assert pool.stats.spills == 3 and pool.in_use == 0
    pool.drain_directives()

    assert mgr.probe_prefix(toks) == 10              # host hits count
    ids2, n_cached = mgr.try_admit(1, toks)
    assert n_cached == 3                             # all three blocks cached
    assert pool.stats.rehydrates == 3
    rehydrates = [d for d in pool.drain_directives() if d[0] == "rehydrate"]
    assert len(rehydrates) == 3


def test_manager_live_spill_bookkeeping():
    """spill_live_prefix moves the oldest resident block of a live slot
    to the host tier, zeroes its device table entry, and refuses to
    touch the block holding the current append position."""
    pool = BlockPool(n_blocks=4, block_size=4, host_blocks=4)   # 3 usable
    mgr = PagedCacheManager(pool, n_slots=1, max_blocks=3)
    toks = np.arange(200, 210, dtype=np.int32)      # 10 toks = 3 blocks
    assert mgr.try_admit(0, toks) is not None
    assert pool.free_count == 0

    assert mgr.spill_live_prefix(0, 10)
    assert mgr.cold_len(0) == 4 and pool.free_count == 1
    assert mgr.tables[0, 0] == 0 and mgr.host_tables[0, 0] != 0
    assert mgr.spill_live_prefix(0, 10)
    assert mgr.cold_len(0) == 8
    # the last block holds position 10: never spilled out from under it
    assert not mgr.spill_live_prefix(0, 10)
    assert pool.stats.spills == 2
    mgr.free_slot(0)
    assert pool.in_use == 0 and pool.host_in_use <= 4


def test_spill_rehydrate_device_roundtrip_exact():
    """spill_block -> rehydrate_block is bit-exact (payloads move in
    storage dtype, host tier included)."""
    L, N, Hkv, bs, D, HN = 2, 4, 2, 8, 16, 3
    ks = jax.random.split(jax.random.key(5), 2)
    cache = {
        "k": jax.random.normal(ks[0], (L, N, Hkv, bs, D), jnp.bfloat16),
        "v": jax.random.normal(ks[1], (L, N, Hkv, bs, D), jnp.bfloat16),
        "host_k": jnp.zeros((L, HN, Hkv, bs, D), jnp.bfloat16),
        "host_v": jnp.zeros((L, HN, Hkv, bs, D), jnp.bfloat16),
    }
    want_k = np.asarray(cache["k"][:, 2].astype(jnp.float32))
    cache = paged_dev.spill_block(cache, dev=2, host=1)
    # clobber the device copy, then bring it back
    cache["k"] = cache["k"].at[:, 2].set(0)
    cache["v"] = cache["v"].at[:, 2].set(0)
    cache = paged_dev.rehydrate_block(cache, host=1, dev=2)
    np.testing.assert_array_equal(
        np.asarray(cache["k"][:, 2].astype(jnp.float32)), want_k
    )


# ------------------------------------------------------------- end to end
def _setup():
    cfg = reduce_config("llama3.2-1b")
    model = build_model(cfg, Env())
    params = model.init(jax.random.key(0))
    return model, params


def _serve(model, params, prompts, n_new, **kw):
    eng = Engine(model, params, n_slots=2, max_seq=32, **kw)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=n_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    stats = eng.run()
    return reqs, stats, eng


SCHEDULES = [("decode-only", False), ("decode-only", True),
             ("hybrid", False), ("hybrid", True)]


@pytest.mark.parametrize("kv_dtype", ["fp8", "int8"])
def test_quantized_engine_greedy_equivalence(kv_dtype):
    """Quantized pools across every schedule combo: everything finishes,
    pools drain, and greedy outputs track the bf16 run within tolerance
    (first token exact — prefill runs on the bf16 staging cache — and a
    clear majority of all tokens identical)."""
    model, params = _setup()
    prompts = [np.arange(1, 6, dtype=np.int32),
               np.arange(7, 10, dtype=np.int32),
               np.arange(2, 13, dtype=np.int32)]
    base, _, _ = _serve(model, params, prompts, 5,
                        cache_kind="paged", block_size=8)
    for sched, amode in SCHEDULES:
        q, _, eng = _serve(model, params, prompts, 5,
                           cache_kind="paged", block_size=8,
                           kv_dtype=kv_dtype, schedule=sched,
                           async_mode=amode)
        assert all(r.done for r in q)
        assert eng.pool.in_use == 0
        total = match = 0
        for a, b in zip(base, q):
            assert b.out_tokens[0] == a.out_tokens[0], (sched, amode, b.uid)
            total += len(a.out_tokens)
            match += sum(x == y for x, y in zip(a.out_tokens, b.out_tokens))
        assert match / total >= 0.6, (sched, amode, match, total)


def test_host_tier_spills_instead_of_preempting():
    """Under block pressure a host tier absorbs the pressure: the run
    spills live prefix blocks, never preempts, and decodes exactly the
    unspilled run's greedy tokens (hybrid attention is LSE-exact)."""
    model, params = _setup()
    prompts = [np.arange(1, 10, dtype=np.int32),
               np.arange(3, 8, dtype=np.int32)]
    ref_reqs, _, _ = _serve(model, params, prompts, 10,
                            cache_kind="paged", block_size=4)
    for sched, amode in SCHEDULES:
        sp, ss, se = _serve(model, params, prompts, 10,
                            cache_kind="paged", block_size=4, n_blocks=9,
                            host_blocks=8, schedule=sched, async_mode=amode)
        assert ss.spills >= 1, (sched, amode)
        assert ss.preemptions == 0, (sched, amode)
        for a, b in zip(ref_reqs, sp):
            assert a.out_tokens == b.out_tokens, (sched, amode, b.uid)
        assert se.pool.in_use == 0


def test_host_tier_rehydrates_freed_prefix():
    """A finished request's prefix blocks spill at free time; a later
    identical prompt admits them as cached straight from the host tier
    and reproduces the same greedy continuation."""
    model, params = _setup()
    prompt = np.arange(1, 10, dtype=np.int32)        # 2 full blocks of 4
    eng = Engine(model, params, n_slots=1, max_seq=32,
                 cache_kind="paged", block_size=4, host_blocks=8)
    a = Request(uid=0, prompt=prompt, max_new_tokens=5)
    eng.submit(a)
    eng.run()
    assert eng.pool.stats.spills >= 2                # prefix went cold->host
    b = Request(uid=1, prompt=prompt, max_new_tokens=5)
    eng.submit(b)
    eng.run()
    assert eng.stats.rehydrations >= 2               # came back from host
    assert b.out_tokens == a.out_tokens
