"""Per-architecture smoke tests (reduced configs, real compute on CPU).

For every assigned arch: one train step (loss finite, grads finite, shapes
right) and prefill->decode consistency against a longer prefill.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_ids
from repro.configs.reduced import reduce_config
from repro.core.placement import Env
from repro.models.registry import build_model

ARCHS = all_arch_ids()


def _batch(cfg, key, B=2, S=16):
    ks = jax.random.split(key, 3)
    batch = {
        "inputs": jax.random.randint(ks[0], (B, S), 0, cfg.vocab),
        "targets": jax.random.randint(ks[1], (B, S), 0, cfg.vocab),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.frontend == "patches":
        batch["embeds"] = jax.random.normal(ks[2], (B, cfg.frontend_len, cfg.d_model), jnp.float32).astype(jnp.bfloat16)
    if cfg.family == "encdec":
        batch["src_embeds"] = jax.random.normal(ks[2], (B, cfg.frontend_len, cfg.d_model), jnp.float32).astype(jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = reduce_config(arch)
    model = build_model(cfg, Env())
    params = model.init(jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    (loss, metrics), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(params, batch)
    assert jnp.isfinite(loss), (arch, loss)
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads)), arch
    # gradients actually flow to (almost) all parameters
    nz = sum(bool(jnp.any(g != 0)) for g in jax.tree.leaves(grads))
    total = len(jax.tree.leaves(grads))
    assert nz >= total * 0.8, f"{arch}: only {nz}/{total} params got gradient"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    cfg = reduce_config(arch)
    model = build_model(cfg, Env())
    params = model.init(jax.random.key(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.key(1), (B, S + 1), 0, cfg.vocab)
    kwargs = {}
    if cfg.family == "encdec":
        kwargs["embeds"] = jax.random.normal(
            jax.random.key(2), (B, cfg.frontend_len, cfg.d_model), jnp.float32
        ).astype(jnp.bfloat16)

    cA = model.init_cache(B, 32)
    logA, _ = jax.jit(model.prefill)(params, toks, cA, **kwargs)
    cB = model.init_cache(B, 32)
    _, cB = jax.jit(model.prefill)(params, toks[:, :S], cB, **kwargs)
    logB, cB2 = jax.jit(model.decode_step)(params, cB, toks[:, S])

    assert logA.shape == (B, cfg.vocab)
    assert not bool(jnp.isnan(logB).any()), arch
    diff = float(jnp.max(jnp.abs(logA.astype(jnp.float32) - logB.astype(jnp.float32))))
    scale = float(jnp.max(jnp.abs(logA.astype(jnp.float32)))) + 1e-6
    # MoE dropping is order-dependent; elsewhere the decode path keeps the
    # softmax weights in bf16 for the cache dot (no f32 cache copy), so
    # bf16-level divergence from the f32 prefill path is expected
    tol = 0.12 * scale if cfg.moe is not None else 2.5e-2 * scale + 1e-5
    assert diff <= tol, f"{arch}: prefill/decode diff {diff} (scale {scale})"
    assert int(cB2["lengths"][0]) == S + 1


@pytest.mark.parametrize("arch", ["llama3.2-1b", "rwkv6-7b", "zamba2-1.2b"])
def test_greedy_decode_is_deterministic(arch):
    cfg = reduce_config(arch)
    model = build_model(cfg, Env())
    params = model.init(jax.random.key(0))
    B = 2
    toks = jax.random.randint(jax.random.key(1), (B, 8), 0, cfg.vocab)
    outs = []
    for _ in range(2):
        cache = model.init_cache(B, 32)
        logits, cache = jax.jit(model.prefill)(params, toks, cache)
        seq = []
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for _ in range(5):
            seq.append(np.asarray(tok))
            logits, cache = jax.jit(model.decode_step)(params, cache, tok)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        outs.append(np.stack(seq))
    np.testing.assert_array_equal(outs[0], outs[1])


def test_int8_kv_cache_close_and_half_size():
    """Beyond-paper: int8 KV cache ~2x capacity at small logit error."""
    cfg = reduce_config("llama3.2-1b")
    m = build_model(cfg, Env())
    mq = build_model(cfg.with_overrides(kv_quant=True), Env())
    params = m.init(jax.random.key(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.key(1), (B, S + 1), 0, cfg.vocab)
    c = m.init_cache(B, 32)
    _, c = jax.jit(m.prefill)(params, toks[:, :S], c)
    ref_log, _ = jax.jit(m.decode_step)(params, c, toks[:, S])
    cq = mq.init_cache(B, 32)
    assert cq["k"].dtype == jnp.int8
    _, cq = jax.jit(mq.prefill)(params, toks[:, :S], cq)
    q_log, _ = jax.jit(mq.decode_step)(params, cq, toks[:, S])
    scale = float(jnp.max(jnp.abs(ref_log.astype(jnp.float32)))) + 1e-9
    rel = float(jnp.max(jnp.abs(q_log.astype(jnp.float32) - ref_log.astype(jnp.float32)))) / scale
    assert rel < 0.08, rel
    b_full = sum(v.size * v.dtype.itemsize for k, v in c.items() if k != "lengths")
    b_q = sum(v.size * v.dtype.itemsize for k, v in cq.items() if k != "lengths")
    assert b_q < 0.65 * b_full
