"""Serving observatory: sampled dispatch profiler (measured MFU/MBU
joins, zero-cost NULL profiler, token identity across every combo),
SLO attainment arithmetic (hand-built span replay, breach marks),
workload generator determinism and shapes, percentile edge cases, the
terminal dashboard, and the bench trend report."""
import json

import jax
import numpy as np
import pytest

from repro.configs.reduced import reduce_config
from repro.core.oi import DEVICES
from repro.core.placement import Env
from repro.models.registry import build_model
from repro.serving.cluster import Cluster
from repro.serving.cluster.stats import ClusterStats, ReplicaStats
from repro.serving.engine import Engine, EngineStats, Request
from repro.serving.telemetry import (
    NULL_PROFILER,
    DispatchProfiler,
    MetricsRegistry,
    SLOMonitor,
    Span,
    Tracer,
    cluster_registry,
    make_profiler,
    percentile,
    render_dashboard,
    to_chrome_trace,
    validate_trace,
)
from repro.serving.workload import (
    WORKLOADS,
    WorkloadDriver,
    build_workload,
    grow_prompt,
)


@pytest.fixture(scope="module")
def model_params():
    cfg = reduce_config("llama3.2-1b")
    model = build_model(cfg, Env())
    return model, model.init(jax.random.key(0))


VOCAB = reduce_config("llama3.2-1b").vocab

PROMPTS = [np.arange(1, 6, dtype=np.int32),
           np.arange(7, 10, dtype=np.int32),
           np.arange(2, 13, dtype=np.int32),
           np.arange(4, 25, dtype=np.int32)]      # multi-chunk

COMBOS = [
    dict(),                                                   # dense/decode-only
    dict(schedule="hybrid", prefill_chunk=8),                 # dense/hybrid
    dict(cache_kind="paged", block_size=8),                   # paged/decode-only
    dict(cache_kind="paged", block_size=8,
         schedule="hybrid", prefill_chunk=8),                 # paged/hybrid
]
COMBO_IDS = ["dense-decode", "dense-hybrid", "paged-decode", "paged-hybrid"]


def _serve(model, params, prompts, n_new=5, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_seq", 32)
    eng = Engine(model, params, **kw)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=n_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return reqs, eng


# ---------------------------------------------------------------- percentiles
def test_percentile_empty_is_zero():
    assert percentile([], 50) == 0.0
    assert percentile([], 99) == 0.0


def test_percentile_single_sample_every_p():
    for p in (0, 1, 50, 90, 99, 100):
        assert percentile([7.0], p) == 7.0


def test_percentile_exact_nearest_rank():
    s = list(range(1, 11))                      # 1..10
    assert percentile(s, 50) == 5
    assert percentile(s, 90) == 9
    assert percentile(s, 99) == 10
    assert percentile(s, 100) == 10
    assert percentile(s, 10) == 1


def test_percentile_clamps_out_of_range_p():
    s = [1.0, 2.0, 3.0]
    assert percentile(s, -5) == 1.0
    assert percentile(s, 150) == 3.0


def test_empty_histogram_snapshot():
    reg = MetricsRegistry()
    reg.histogram("ttft_steps")                 # zero samples
    snap = reg.snapshot()
    assert snap["ttft_steps_count"] == 0.0
    assert snap["ttft_steps_p99"] == 0.0
    reg.histogram("one").observe(4.0)           # single sample
    snap = reg.snapshot()
    assert snap["one_p50"] == 4.0 and snap["one_p99"] == 4.0


def test_cluster_registry_zero_finished_requests():
    """Pooled cluster percentiles must snapshot with zero finished
    requests on every replica (empty sample lists everywhere)."""
    stats = ClusterStats(
        rounds=0,
        replicas=[ReplicaStats(replica=0, routed=0, n_slots=2,
                               engine=EngineStats(), role="mixed")],
        spills=0, prefix_hit_tokens=0, probed_tokens=0,
        queue_wait_sum=0, queue_wait_count=0,
    )
    snap = cluster_registry(stats).snapshot()
    assert snap["ttft_steps_count"] == 0.0
    assert snap["ttft_steps_p99"] == 0.0
    assert stats.ttft_percentile(99) == 0.0


# ------------------------------------------------------------------ workloads
@pytest.mark.parametrize("kind", WORKLOADS)
def test_workload_deterministic_by_seed(kind):
    a = build_workload(kind, 12, vocab=VOCAB, max_seq=32, max_new=4, seed=3)
    b = build_workload(kind, 12, vocab=VOCAB, max_seq=32, max_new=4, seed=3)
    c = build_workload(kind, 12, vocab=VOCAB, max_seq=32, max_new=4, seed=4)
    assert len(a) == len(b) == 12
    for x, y in zip(a, b):
        assert x.round == y.round
        assert np.array_equal(x.prompt, y.prompt)
    assert any(not np.array_equal(x.prompt, y.prompt)
               for x, y in zip(a, c))


@pytest.mark.parametrize("kind", WORKLOADS)
def test_workload_admissible_and_sorted(kind):
    arr = build_workload(kind, 12, vocab=VOCAB, max_seq=32, max_new=4, seed=0)
    rounds = [a.round for a in arr]
    assert rounds == sorted(rounds)
    for a in arr:
        assert len(a.prompt) + a.max_new_tokens <= 30     # max_seq - 2
        assert a.prompt.dtype == np.int32
        assert (a.prompt >= 1).all() and (a.prompt < VOCAB).all()
    if kind == "random":
        assert all(r == 0 for r in rounds)                # legacy shape


def test_chat_fan_shares_prefixes():
    arr = build_workload("chat-fan", 8, vocab=VOCAB, max_seq=32, max_new=4,
                         seed=0, fan=4)
    # at least one pair shares a long common prefix
    shared = 0
    for i in range(len(arr)):
        for j in range(i + 1, len(arr)):
            a, b = arr[i].prompt, arr[j].prompt
            n = min(len(a), len(b))
            if n >= 4 and np.array_equal(a[:4], b[:4]):
                shared += 1
    assert shared >= 3


def test_grow_prompt_tail_clips():
    prompt = np.arange(1, 20, dtype=np.int32)
    grown = grow_prompt(prompt, [100, 101, 102], np.array([7, 8], np.int32),
                        max_seq=24, max_new=4)
    assert len(grown) == 18                    # max_seq - max_new - 2
    # tail window: the newest tokens survive the clip
    assert grown[-1] == 8 and grown[-2] == 7 and 102 in grown


def test_workload_driver_agentic_resubmits(model_params):
    model, params = model_params
    eng = Engine(model, params, n_slots=2, max_seq=32,
                 schedule="hybrid", prefill_chunk=8)
    arr = build_workload("agentic", 2, vocab=VOCAB, max_seq=32, max_new=4,
                         seed=0, turns=3)
    drv = WorkloadDriver(eng, arr, vocab=VOCAB, max_seq=32, seed=0)
    rounds = drv.run()
    assert rounds > 0
    assert drv.resubmits == 4                  # 2 sessions x (3 - 1) turns
    assert len(drv.submitted) == 6
    assert all(r.done for r in drv.submitted)
    assert eng.stats.generated == 6 * 4


def test_workload_driver_arrivals_respect_rounds(model_params):
    model, params = model_params
    eng = Engine(model, params, n_slots=2, max_seq=32,
                 schedule="hybrid", prefill_chunk=8)
    arr = build_workload("poisson", 4, vocab=VOCAB, max_seq=32, max_new=3,
                         seed=1, rate=0.25)
    drv = WorkloadDriver(eng, arr, vocab=VOCAB, max_seq=32, seed=1)
    rounds = drv.run()
    assert rounds >= max(a.round for a in arr)
    assert all(r.done for r in drv.submitted)


# ------------------------------------------------------------------- profiler
def test_null_profiler_zero_cost(model_params):
    model, params = model_params
    eng = Engine(model, params, n_slots=2, max_seq=32)
    assert eng.profiler is NULL_PROFILER
    assert eng._telemetry is False
    assert eng._cost_model is None
    assert make_profiler(0) is NULL_PROFILER
    assert NULL_PROFILER.tick() is False
    assert NULL_PROFILER.samples == ()


def test_profiler_validates_sample_every():
    with pytest.raises(ValueError):
        DispatchProfiler(sample_every=0)
    assert DispatchProfiler(sample_every=1).sync
    assert not DispatchProfiler(sample_every=4).sync


@pytest.mark.parametrize("combo", COMBOS, ids=COMBO_IDS)
@pytest.mark.parametrize("async_mode", [False, True], ids=["sync", "async"])
def test_profiler_token_identity(model_params, combo, async_mode):
    """Greedy outputs are bit-identical with the profiler on: fencing
    changes timing, never tokens."""
    model, params = model_params
    base, _ = _serve(model, params, PROMPTS, async_mode=async_mode, **combo)
    prof = DispatchProfiler(sample_every=2)
    with_prof, eng = _serve(model, params, PROMPTS, async_mode=async_mode,
                            profiler=prof, **combo)
    for b, w in zip(base, with_prof):
        assert b.out_tokens == w.out_tokens
    assert len(prof.samples) > 0
    assert eng._telemetry and eng._cost_model is not None


def test_profiler_joins_measured_with_analytic(model_params):
    model, params = model_params
    prof = DispatchProfiler(sample_every=1, device="TPU-V5E")
    _, eng = _serve(model, params, PROMPTS[:2], schedule="hybrid",
                    prefill_chunk=8, profiler=prof)
    assert len(prof.samples) == eng.stats.engine_steps   # sync: every step
    dev = DEVICES["TPU-V5E"]
    for s in prof.samples:
        assert s.seconds > 0
        assert s.measured_mfu == pytest.approx(
            s.flops / (s.seconds * dev.flops))
        assert s.measured_mbu == pytest.approx(
            s.bytes / (s.seconds * dev.bw))
        assert s.achieved_gbps == pytest.approx(s.bytes / s.seconds / 1e9)
    summary = prof.summary()
    assert summary and all(row["n"] >= 1 for row in summary.values())
    reg = MetricsRegistry()
    prof.register(reg)
    snap = reg.snapshot()
    assert snap["profiled_dispatches"] == len(prof.samples)
    assert snap["measured_mbu"] > 0
    assert snap["dispatch_seconds_count"] == len(prof.samples)


def test_profiler_sampling_rate(model_params):
    """sample_every=N fences ~1/N of dispatches, and unsampled steps
    carry no measured fields."""
    model, params = model_params
    prof = DispatchProfiler(sample_every=3)
    tracer = Tracer()
    _, eng = _serve(model, params, PROMPTS, schedule="hybrid",
                    prefill_chunk=8, profiler=prof, tracer=tracer)
    n_steps = eng.stats.engine_steps
    assert len(prof.samples) == sum(
        1 for rec in tracer.steps
        if rec.kind != "prefill" and rec.measured_s is not None
    )
    assert 0 < len(prof.samples) <= n_steps // 3 + 1
    unmeasured = [r for r in tracer.steps if r.measured_s is None]
    assert all(r.measured_mfu is None for r in unmeasured)


def test_measured_counter_tracks_in_trace(model_params):
    model, params = model_params
    tracer = Tracer(wall=True)
    prof = DispatchProfiler(sample_every=2)
    _, _ = _serve(model, params, PROMPTS, schedule="hybrid",
                  prefill_chunk=8, tracer=tracer, profiler=prof)
    obj = to_chrome_trace(tracer)
    assert validate_trace(obj) == []
    counters = {}
    last_ts = {}
    for e in obj["traceEvents"]:
        if e["ph"] != "C":
            continue
        counters[e["name"]] = counters.get(e["name"], 0) + 1
        key = (e["pid"], e["name"])
        assert e["ts"] >= last_ts.get(key, -1)      # monotone per series
        last_ts[key] = e["ts"]
    for name in ("measured_mfu", "measured_mbu", "achieved_gbps"):
        assert counters.get(name, 0) == len(prof.samples)
    # sampled only: fewer measured points than oi points
    assert counters["measured_mfu"] < counters["oi"]


def test_profiler_through_cluster(model_params):
    model, params = model_params
    prof = DispatchProfiler(sample_every=2)
    cl = Cluster(model, params, 2, profiler=prof, n_slots=2, max_seq=32,
                 schedule="hybrid", prefill_chunk=8)
    for i, p in enumerate(PROMPTS):
        cl.submit(Request(uid=i, prompt=p, max_new_tokens=4))
    cl.run()
    assert all(e.profiler is prof for e in cl.engines)
    assert len(prof.samples) > 0
    assert {s.replica for s in prof.samples} <= {0, 1}


# ------------------------------------------------------------------------ slo
def _span(uid, name, start, end, generated=None, track=0):
    attrs = {} if generated is None else {"generated": generated}
    return Span(replica=0, track=track, uid=uid, name=name,
                start=start, end=end, attrs=attrs)


def test_slo_from_spans_exact_arithmetic():
    """Hand-built span set with known TTFT/TPOT values: u0 attains both,
    u1 breaches TTFT, u2 breaches TPOT, u3 never finished (skipped)."""
    spans = [
        _span(0, "queued", 0, 1), _span(0, "decode", 2, 10, generated=5),
        _span(1, "queued", 0, 6), _span(1, "decode", 8, 12, generated=5),
        _span(2, "queued", 1, 2), _span(2, "decode", 3, 23, generated=5),
        _span(3, "queued", 4, None),
    ]
    mon = SLOMonitor.from_spans(spans, ttft_target=4, tpot_target=3)
    assert mon.finished == 3
    # u0: ttft 2, tpot 8/4=2 -> attains; u1: ttft 8 breach, tpot 1 ok;
    # u2: ttft 2 ok, tpot 20/4=5 breach
    assert mon.attained_count == 1
    assert mon.attainment == pytest.approx(1 / 3)
    assert mon.window_attainment == pytest.approx(1 / 3)
    assert mon.breaches == 2
    assert mon.good_tokens == 5 and mon.total_tokens == 15
    assert mon.goodput(10) == pytest.approx(0.5)
    assert sorted(mon.ttft_samples) == [2, 2, 8]
    assert sorted(mon.tpot_samples) == [1.0, 2.0, 5.0]
    assert mon.ttft_percentile(50) == 2 and mon.ttft_percentile(99) == 8


def test_slo_from_spans_preemption_uses_first_decode():
    """A preempted request re-opens its decode span; TTFT must come from
    the *earliest* decode start, TPOT from the final end."""
    spans = [
        _span(0, "queued", 0, 1),
        _span(0, "decode", 2, 5, generated=2),     # before preemption
        _span(0, "decode", 9, 15, generated=6),    # re-admitted
    ]
    mon = SLOMonitor.from_spans(spans, ttft_target=3, tpot_target=10)
    assert mon.finished == 1
    assert list(mon.ttft_samples) == [2]           # 2 - 0, not 9 - 0
    assert list(mon.tpot_samples) == [pytest.approx((15 - 2) / 5)]
    assert mon.attained_count == 1


def test_slo_unset_targets_always_attain():
    mon = SLOMonitor()
    mon.observe_ttft(0, 100.0)
    mon.observe_finish(0, 50.0, tokens=3)
    assert mon.attainment == 1.0 and mon.breaches == 0


def test_slo_register_publishes_goodput():
    mon = SLOMonitor(ttft_target=2, tpot_target=1)
    mon.observe_ttft(0, 1.0)
    mon.observe_finish(0, 0.5, tokens=8)
    mon.observe_ttft(1, 9.0)                       # breach
    mon.observe_finish(1, 0.5, tokens=8)
    reg = MetricsRegistry()
    mon.register(reg, elapsed=16)
    snap = reg.snapshot()
    assert snap["slo_ttft_target"] == 2.0
    assert snap["slo_finished"] == 2.0
    assert snap["slo_attained"] == 1.0
    assert snap["slo_breaches"] == 1.0
    assert snap["slo_attainment"] == 0.5
    assert snap["slo_goodput_tokens_per_round"] == 0.5
    assert snap["slo_ttft_count"] == 2.0


def test_slo_breach_marks_in_trace(model_params):
    """A tight TTFT target under queued load must drop slo_breach marks
    the trace check can gate on, without changing tokens."""
    model, params = model_params
    base, _ = _serve(model, params, PROMPTS, schedule="hybrid",
                     prefill_chunk=8)
    slo = SLOMonitor(ttft_target=0, tpot_target=0.1)    # unattainable
    tracer = Tracer(wall=True, slo=slo)
    monitored, _ = _serve(model, params, PROMPTS, schedule="hybrid",
                          prefill_chunk=8, tracer=tracer)
    for b, w in zip(base, monitored):
        assert b.out_tokens == w.out_tokens
    assert slo.finished == len(PROMPTS)
    assert slo.attainment == 0.0
    obj = to_chrome_trace(tracer)
    marks = [e for e in obj["traceEvents"]
             if e["ph"] == "i" and e["name"] == "slo_breach"]
    assert len(marks) >= len(PROMPTS)
    for m in marks:
        assert m["args"]["metric"] in ("ttft", "tpot")
        assert m["args"]["value"] > m["args"]["target"]


def test_tracer_wall_dispatch_annotations(model_params):
    """Async spans close at observe time; the dispatch-time wall stamp
    must ride along so viewers can show true overlap."""
    model, params = model_params
    tracer = Tracer(wall=True)
    _, _ = _serve(model, params, PROMPTS[:2], schedule="hybrid",
                  prefill_chunk=8, async_mode=True, tracer=tracer)
    stamped = [s for s in tracer.spans
               if s.name in ("prefill_chunk", "decode")
               and "wall_dispatch" in s.attrs]
    assert stamped, "no spans carry dispatch-time wall stamps"
    for s in stamped:
        assert s.t_end is None or s.attrs["wall_dispatch"] <= s.t_end


# -------------------------------------------------------------- dashboard
def test_dashboard_renders_engine_and_cluster(model_params):
    model, params = model_params
    prof = DispatchProfiler(sample_every=2)
    slo = SLOMonitor(ttft_target=3)
    cl = Cluster(model, params, 2, profiler=prof,
                 n_slots=2, max_seq=32, cache_kind="paged", block_size=8,
                 schedule="hybrid", prefill_chunk=8)
    for i, p in enumerate(PROMPTS):
        cl.submit(Request(uid=i, prompt=p, max_new_tokens=4))
    cl.run()
    out = render_dashboard(cl, 7, slo=slo, profiler=prof)
    assert "[round 7]" in out and "global_queue=" in out
    assert "r0[M]" in out and "r1[M]" in out and "pool=" in out
    assert "slo[" in out and "measured[" in out
    solo = render_dashboard(cl.engines[0], 1)
    assert "r0[M]" in solo and "global_queue" not in solo


# ------------------------------------------------------------ bench report
def test_bench_report_trend_and_drift(tmp_path):
    import sys
    sys.path.insert(0, "scripts")
    try:
        import bench_report
    finally:
        sys.path.pop(0)
    (tmp_path / "BENCH_1.json").write_text(json.dumps(
        {"b": {"x": 1.0, "y": 5.0}}))
    (tmp_path / "BENCH_2.json").write_text(json.dumps(
        {"b": {"x": 2.0, "y": 5.0}, "c": {"z": 3.0}}))
    (tmp_path / "BENCH_ci.json").write_text(json.dumps(
        {"metrics": {"x": 2.0}}))
    snaps = bench_report.load_snapshots(tmp_path)
    assert [n for n, _ in snaps] == [1, 2]
    report = bench_report.render(snaps, drift_pct=25.0,
                                 ci=json.loads(
                                     (tmp_path / "BENCH_ci.json").read_text()))
    assert "b.x" in report and "c.z" in report
    assert "DRIFTS" in report and "b.x: 1 -> 2 (+100.0%)" in report
    assert "b.y" in report and "b.y: " not in report.split("DRIFTS")[1]
    out = tmp_path / "report.txt"
    assert bench_report.main(["--root", str(tmp_path),
                              "--out", str(out)]) == 0
    assert out.read_text() == report
    assert bench_report.main(["--root", str(tmp_path / "empty")]) == 1
