"""Paged KV subsystem: BlockPool invariants, prefix sharing + COW, the
paged Pallas kernel vs its jnp oracle, and paged-vs-dense engine
equivalence (greedy, mixed prompt lengths, preemption)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.reduced import reduce_config
from repro.core.placement import Env
from repro.kernels import ops, ref
from repro.models.registry import build_model
from repro.serving.engine import Engine, Request
from repro.serving.paged import BlockPool, PagedCacheManager


# ---------------------------------------------------------------- BlockPool
def test_pool_alloc_free_refcount():
    pool = BlockPool(n_blocks=5, block_size=8)   # 4 usable, id 0 reserved
    assert pool.free_count == 4 and pool.in_use == 0
    a, b = pool.alloc(), pool.alloc()
    assert 0 not in (a, b) and a != b
    assert pool.refcount(a) == 1
    pool.incref(a)
    assert pool.refcount(a) == 2
    pool.decref(a)
    assert pool.refcount(a) == 1 and pool.free_count == 2
    pool.decref(a)
    assert pool.refcount(a) == 0 and pool.free_count == 3
    pool.decref(b)
    assert pool.free_count == 4 and pool.in_use == 0
    assert pool.stats.allocs == 2 and pool.stats.frees == 2


def test_pool_exhaustion_raises():
    pool = BlockPool(n_blocks=2, block_size=4)
    pool.alloc()
    with pytest.raises(RuntimeError):
        pool.alloc()


def test_pool_hash_register_lookup_invalidate():
    pool = BlockPool(n_blocks=4, block_size=4)
    b = pool.alloc()
    pool.register(("k",), b)
    assert pool.lookup(("k",)) == b
    assert pool.stats.hash_hits == 1
    pool.invalidate(b)
    assert pool.lookup(("k",)) is None
    # freeing also drops the hash entry
    pool.register(("k2",), b)
    pool.decref(b)
    assert pool.lookup(("k2",)) is None


# ---------------------------------------------------------------- manager
def test_manager_prefix_sharing_and_cow():
    pool = BlockPool(n_blocks=8, block_size=4)
    mgr = PagedCacheManager(pool, n_slots=2, max_blocks=4)
    prompt = np.arange(1, 7, dtype=np.int32)      # 6 tokens: 1 full + partial

    ids0, cached0 = mgr.try_admit(0, prompt)
    assert cached0 == 0 and len(ids0) == 2
    ids1, cached1 = mgr.try_admit(1, prompt)
    assert cached1 == 2 and ids1 == ids0          # full prefix shared
    assert pool.stats.allocs == 2                 # not 4: sharing worked
    assert pool.refcount(ids0[1]) == 2

    # first divergent append on the shared tail -> COW for the appender
    d0, payload = mgr.ensure_append(0, 6)
    assert d0 == "cow" and payload[0] == ids0[1]
    assert mgr.blocks[0][1] == payload[1] != ids0[1]
    assert pool.refcount(ids0[1]) == 1
    # the other owner now appends in place
    d1, _ = mgr.ensure_append(1, 6)
    assert d1 == "ready"


def test_manager_boundary_alloc_and_oom():
    pool = BlockPool(n_blocks=3, block_size=4)    # 2 usable
    mgr = PagedCacheManager(pool, n_slots=1, max_blocks=4)
    # exact-multiple prompt: the decode boundary block is reserved at
    # admission (returned ids cover the prompt block only)
    ids, _ = mgr.try_admit(0, np.arange(4, dtype=np.int32))
    assert len(ids) == 1 and len(mgr.blocks[0]) == 2
    assert pool.free_count == 0
    assert mgr.ensure_append(0, 4) == ("ready", None)   # reserved block
    assert mgr.ensure_append(0, 8) == ("oom", None)     # pool dry
    mgr.free_slot(0)
    assert pool.in_use == 0 and not mgr.blocks[0]


def test_manager_admit_insufficient_blocks_is_sideeffect_free():
    pool = BlockPool(n_blocks=3, block_size=4)
    mgr = PagedCacheManager(pool, n_slots=2, max_blocks=4)
    assert mgr.try_admit(0, np.arange(12, dtype=np.int32)) is None
    assert pool.free_count == 2 and pool.stats.allocs == 0


# ------------------------------------------------------------ paged kernel
PAGED_CASES = [
    # (B, Hkv, G, D, block_size, max_blocks, lengths)
    (1, 1, 1, 8, 8, 2, (5,)),
    (3, 2, 4, 16, 8, 4, (5, 17, 32)),
    (2, 2, 8, 32, 16, 3, (1, 48)),      # HPU design point G=8
    (2, 1, 3, 16, 8, 4, (9, 25)),       # non-pow2 group
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", PAGED_CASES)
def test_paged_kernel_matches_oracle(case, dtype):
    B, Hkv, G, D, bs, MB, lens = case
    N = 1 + B * MB
    ks = jax.random.split(jax.random.key(hash(case) % 2**31), 3)
    q = jax.random.normal(ks[0], (B, Hkv * G, D), jnp.float32).astype(dtype)
    kp = jax.random.normal(ks[1], (N, Hkv, bs, D), jnp.float32).astype(dtype)
    vp = jax.random.normal(ks[2], (N, Hkv, bs, D), jnp.float32).astype(dtype)
    # scrambled physical placement, null block 0 for unused entries
    rng = np.random.default_rng(0)
    perm = iter(rng.permutation(np.arange(1, N)))
    tables = np.zeros((B, MB), np.int32)
    for b in range(B):
        for j in range(-(-int(lens[b]) // bs)):
            tables[b, j] = next(perm)
    lengths = jnp.asarray(lens, jnp.int32)
    out = ops.paged_decode_attention(q, kp, vp, jnp.asarray(tables), lengths)
    exp = ref.paged_decode_attention(q, kp, vp, jnp.asarray(tables), lengths)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        out.astype(jnp.float32), exp.astype(jnp.float32), atol=tol, rtol=tol
    )


def test_paged_kernel_ignores_null_block_garbage():
    B, Hkv, G, D, bs, MB = 2, 2, 2, 16, 8, 2
    N = 1 + B * MB
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (B, Hkv * G, D), jnp.float32)
    kp = jax.random.normal(ks[1], (N, Hkv, bs, D), jnp.float32)
    vp = jax.random.normal(ks[2], (N, Hkv, bs, D), jnp.float32)
    tables = jnp.asarray([[1, 0], [2, 0]], jnp.int32)
    lengths = jnp.asarray([6, 8], jnp.int32)
    out1 = ops.paged_decode_attention(q, kp, vp, tables, lengths)
    kp2 = kp.at[0].set(99.0)
    vp2 = vp.at[0].set(-99.0)
    out2 = ops.paged_decode_attention(q, kp2, vp2, tables, lengths)
    np.testing.assert_allclose(out1, out2, atol=1e-6)


# ------------------------------------------------------------------- specs
def test_paged_cache_specs_resolve_for_every_policy():
    """The pool's block axis must land on HPU-lane mesh axes (and the
    specs must match the kernel-native leaf shapes) under every KV
    placement policy."""
    cfg = reduce_config("llama3.2-1b")
    axes = {"pod": 1, "data": 2, "model": 2}
    for policy in ("batch", "head", "sequence", "batch_seq", "none"):
        model = build_model(cfg, Env(axes=axes, kv_policy=policy))
        n_slots, n_blocks, bs, mb = 4, 32, 8, 4
        specs = model.paged_cache_specs(n_slots, n_blocks, bs, mb)
        shapes = model.paged_cache_shapes(n_slots, n_blocks, bs, mb)
        assert set(specs) == set(shapes) == {"k", "v", "block_tables", "lengths"}
        for name in ("k", "v"):
            assert len(specs[name]) <= shapes[name].ndim
            if policy == "batch":    # blocks split across HPU lanes
                assert "data" in jax.tree.leaves(tuple(specs[name]))
            if policy == "none":
                assert specs[name] == jax.sharding.PartitionSpec()


# ------------------------------------------------------------------ engine
def _setup():
    cfg = reduce_config("llama3.2-1b")
    model = build_model(cfg, Env())
    params = model.init(jax.random.key(0))
    return model, params


def _serve(model, params, prompts, n_new, **kw):
    eng = Engine(model, params, n_slots=2, max_seq=32, **kw)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=n_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    stats = eng.run()
    return reqs, stats, eng


def test_paged_engine_matches_dense_engine():
    model, params = _setup()
    prompts = [np.arange(1, 6, dtype=np.int32),      # mixed lengths
               np.arange(7, 10, dtype=np.int32),
               np.arange(2, 13, dtype=np.int32)]
    dense, ds, _ = _serve(model, params, prompts, 5, cache_kind="dense")
    paged, ps, eng = _serve(model, params, prompts, 5,
                            cache_kind="paged", block_size=8)
    for a, b in zip(dense, paged):
        assert b.done
        assert a.out_tokens == b.out_tokens, (a.uid, a.out_tokens, b.out_tokens)
    assert ps.peak_active == 2                       # continuous batching ran
    assert eng.pool.in_use == 0                      # all blocks returned


def test_paged_engine_prefix_sharing_saves_blocks():
    model, params = _setup()
    prompt = np.arange(1, 13, dtype=np.int32)        # 12 tokens = 2 blocks of 8
    paged, _, eng = _serve(model, params, [prompt, prompt], 4,
                           cache_kind="paged", block_size=8)
    assert paged[0].out_tokens == paged[1].out_tokens
    # no-sharing would allocate 2 prompt blocks per request (4 total);
    # sharing allocates 2 + one COW copy on first divergent append
    assert eng.pool.stats.allocs < 4
    assert eng.pool.stats.hash_hits >= 2
    assert eng.pool.stats.cow_copies >= 1
    dense, _, _ = _serve(model, params, [prompt, prompt], 4, cache_kind="dense")
    assert dense[0].out_tokens == paged[0].out_tokens


def test_paged_engine_preemption_restores_exact_tokens():
    model, params = _setup()
    prompts = [np.arange(1, 10, dtype=np.int32),
               np.arange(3, 8, dtype=np.int32)]
    dense, _, _ = _serve(model, params, prompts, 10, cache_kind="dense")
    # 8 usable blocks of 4 tokens: both sequences cannot finish resident
    paged, ps, eng = _serve(model, params, prompts, 10,
                            cache_kind="paged", block_size=4, n_blocks=9)
    assert ps.preemptions >= 1
    for a, b in zip(dense, paged):
        assert a.out_tokens == b.out_tokens, (a.uid, a.out_tokens, b.out_tokens)
    assert eng.pool.in_use == 0


def test_paged_engine_admission_gated_on_blocks():
    model, params = _setup()
    # pool holds one max-length sequence; second request must wait even
    # though a slot is free
    prompts = [np.arange(1, 9, dtype=np.int32), np.arange(11, 19, dtype=np.int32)]
    paged, ps, eng = _serve(model, params, prompts, 4,
                            cache_kind="paged", block_size=4, n_blocks=9)
    dense, _, _ = _serve(model, params, prompts, 4, cache_kind="dense")
    for a, b in zip(dense, paged):
        assert b.done and a.out_tokens == b.out_tokens
