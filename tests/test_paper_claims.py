"""Validate the faithful reproduction against the paper's own claims.

All numbers come out of ``core/oi.py`` seeded only with Table I constants
and Llama-2-7B dimensions (DESIGN.md §7).  Tolerances are stated per
claim; residuals trace to prototype effects (QDMA chunking) the analytic
model does not include.
"""
import pytest

from repro.core import oi
from repro.core.oi import DEVICES, LLAMA2_7B as M

L40S = DEVICES["L40S"]
H100 = DEVICES["H100-NVL"]
HPUP = DEVICES["HPU-PROTO"]
A100 = DEVICES["A100"]

SEQ_FULL = 2048          # context at end of generation
SEQ_AVG = 1024 + 512     # input 1K + half of the 1K output


def test_a100_crossover_batch_203():
    """§III: GEMM turns compute-bound at batch ~ perf/BW ratio ~ 203."""
    assert abs(A100.ridge - 203) < 4


def test_gemv_oi_is_batch_independent():
    assert oi.gemv_oi(1) == oi.gemv_oi(1)
    # attention OI equals the GQA group size, never the batch
    for g in (1, 4, 8):
        assert oi.gemv_oi(g) == g


def test_oom_boundary_batch_16():
    """§VI-B: L40S serves batch 16 but OOMs at 32 (2K ctx, Llama-2-7B).
    The paper sweeps powers of two, so the claim is 16 <= limit < 32."""
    mb = oi.max_batch_gpu_only(L40S, M, SEQ_FULL)
    assert 16 <= mb < 32, mb


def test_hpu_proto_capacity_16_per_unit():
    """§VI-B: one 16GB HPU prototype holds ~16 sequences' KV at 2K ctx."""
    assert 13 <= oi.max_batch_per_hpu(HPUP, M, SEQ_FULL) <= 18


@pytest.mark.parametrize(
    "batch,expected,tol",
    [(16, 1.9, 0.75), (32, 2.9, 0.75), (64, 4.1, 0.9)],
)
def test_fig7a_throughput_ratios(batch, expected, tol):
    """Fig. 7a: GPU+4HPU at batch {16,32,64} vs GPU-only at batch 16."""
    base = oi.step_time_gpu_only(L40S, M, 16, SEQ_AVG)
    base_tput = 16 / base["total"]
    het = oi.step_time_hetero(L40S, HPUP, M, batch, SEQ_AVG, n_hpu=4)
    ratio = (batch / het["total"]) / base_tput
    assert abs(ratio - expected) <= tol, f"model {ratio:.2f} vs paper {expected}"


def test_fig7b_network_share_small():
    """Fig. 7b / §VI-C: boundary-transfer share stays ~10% of step time."""
    het = oi.step_time_hetero(L40S, HPUP, M, 64, SEQ_AVG, n_hpu=4)
    share = het["network"] / het["total"]
    assert share < 0.15, share


def test_fig8_mfu_gpu_only_about_1pct():
    t = oi.step_time_gpu_only(L40S, M, 16, SEQ_AVG)
    mfu = oi.mfu_end_to_end(L40S, M, 16, SEQ_AVG, t)
    assert mfu < 0.03, mfu


def test_fig8_mfu_hetero_tens_of_pct():
    """Fig. 8: linear-only GPU at large batch reaches tens of % MFU."""
    t = oi.step_time_hetero(L40S, HPUP, M, 512, SEQ_AVG, n_hpu=16)
    mfu_linear = (M.linear_flops_per_token() * 512) / (t["linear"] * L40S.flops)
    assert mfu_linear > 0.25, mfu_linear


def test_fig9_energy_efficiency_gain():
    """Fig. 9: ~4.6x tokens/s/W for L40S+4HPU@64 vs L40S-only@16."""
    base = oi.step_time_gpu_only(L40S, M, 16, SEQ_AVG)
    het = oi.step_time_hetero(L40S, HPUP, M, 64, SEQ_AVG, n_hpu=4)
    e_base = oi.tokens_per_joule(16, base, L40S, n_hpu=0)
    e_het = oi.tokens_per_joule(64, het, L40S, n_hpu=4)
    ratio = e_het / e_base
    assert 3.2 <= ratio <= 6.0, ratio


@pytest.mark.xfail(
    reason="Documented deviation (EXPERIMENTS.md §Paper-validation): the "
    "paper's 1.92x-vs-H100 result rests on measured wall power and real "
    "kernel efficiencies; an ideal-roofline model seeded only with Table I "
    "constants predicts the opposite ordering (H100 NVL's 3.9 TB/s serves "
    "attention faster per watt than the 460 GB/s FPGA prototype).",
    strict=True,
)
def test_fig9_beats_h100_nvl():
    """Fig. 9: mid-range GPU + HPUs beats a high-end GPU on tokens/s/W."""
    h100 = oi.step_time_gpu_only(H100, M, 64, SEQ_AVG)
    het = oi.step_time_hetero(L40S, HPUP, M, 64, SEQ_AVG, n_hpu=4)
    e_h100 = oi.tokens_per_joule(64, h100, H100, n_hpu=0)
    e_het = oi.tokens_per_joule(64, het, L40S, n_hpu=4)
    assert e_het > e_h100, (e_het, e_h100)


def test_mfu_mbu_balance_at_ridge():
    """Fig. 1c: at OI == ridge, both MFU and MBU are ~max simultaneously."""
    mfu, mbu = oi.mfu_mbu(A100, A100.ridge)
    assert mfu > 0.99 and mbu > 0.99
