"""Pipeline parallelism: GPipe schedule == sequential reference, fwd + grad
(subprocess: needs >1 placeholder device)."""
import json
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import compat_mesh
from repro.training.pipeline_pp import pipeline_forward, sequential_reference, split_stages

mesh = compat_mesh((4,), ("stage",))
L, D = 8, 16
n_micro, B, S = 6, 2, 4
key = jax.random.key(0)
w = jax.random.normal(key, (L, D, D)) * 0.3
params = {"w": w}

def block_fn(p, h):
    # p["w"]: (L/stages, D, D) — apply the stage's layers sequentially
    def body(hc, wl):
        return jnp.tanh(hc @ wl), None
    out, _ = jax.lax.scan(body, h, p["w"])
    return out

x = jax.random.normal(jax.random.key(1), (n_micro, B, S, D))
stage_params = split_stages(params, 4)

ref = sequential_reference(block_fn, stage_params, x, 4)
with mesh:
    got = jax.jit(lambda sp, xx: pipeline_forward(block_fn, sp, xx, mesh))(stage_params, x)
fwd_err = float(jnp.max(jnp.abs(ref - got)))

# gradient equivalence
def loss_pp(sp, xx):
    return jnp.sum(pipeline_forward(block_fn, sp, xx, mesh) ** 2)

def loss_ref(sp, xx):
    return jnp.sum(sequential_reference(block_fn, sp, xx, 4) ** 2)

with mesh:
    g_pp = jax.jit(jax.grad(loss_pp))(stage_params, x)
g_ref = jax.grad(loss_ref)(stage_params, x)
g_err = max(float(jnp.max(jnp.abs(a - b))) for a, b in
            zip(jax.tree.leaves(g_pp), jax.tree.leaves(g_ref)))
print(json.dumps({"fwd_err": fwd_err, "grad_err": g_err}))
"""


def test_gpipe_matches_sequential_fwd_and_grad():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(__file__)), timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["fwd_err"] < 1e-5, res
    assert res["grad_err"] < 1e-4, res
