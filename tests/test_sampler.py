"""On-device sampling vs the host oracle, and async (dispatch-ahead)
vs synchronous engine greedy equivalence for both cache kinds —
including a preemption run and one-step-late EOS retirement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.reduced import reduce_config
from repro.core.placement import Env
from repro.models.registry import build_model
from repro.serving.engine import Engine, Request
from repro.serving.sampler import SamplerConfig, sample, sample_on_device


# ------------------------------------------------------------ device/host
@pytest.mark.parametrize("cfg", [
    SamplerConfig(),                                # greedy
    SamplerConfig(temperature=0.7),                 # temperature
    SamplerConfig(temperature=1.0, top_k=5),        # top-k
], ids=["greedy", "temperature", "top-k"])
def test_sample_on_device_matches_host_oracle(cfg):
    logits = jax.random.normal(jax.random.key(3), (4, 64))
    for seed in range(5):
        rng = jax.random.key(seed)
        dev = jax.jit(sample_on_device, static_argnames=("cfg",))(
            logits, rng, cfg=cfg
        )
        host = sample(logits, rng, cfg)
        assert dev.dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(dev), np.asarray(host))


def test_sample_on_device_top_k_truncates():
    """Tokens outside the top-k must never be sampled, however hot."""
    logits = jnp.array([[1.0, 0.9, 0.89, 0.88]])
    cfg = SamplerConfig(temperature=50.0, top_k=2)   # near-uniform over top-2
    seen = {int(sample_on_device(logits, jax.random.key(i), cfg)[0])
            for i in range(30)}
    assert seen <= {0, 1} and len(seen) == 2


# --------------------------------------------------------- engine parity
@pytest.fixture(scope="module")
def model_params():
    cfg = reduce_config("llama3.2-1b")
    model = build_model(cfg, Env())
    return model, model.init(jax.random.key(0))


def _serve(model, params, prompts, async_mode, n_new=6, n_slots=2,
           max_seq=32, **kw):
    eng = Engine(model, params, n_slots=n_slots, max_seq=max_seq,
                 async_mode=async_mode, **kw)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=n_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    stats = eng.run()
    return reqs, stats, eng


PROMPTS = [np.arange(1, 6, dtype=np.int32),
           np.arange(7, 10, dtype=np.int32),
           np.arange(2, 13, dtype=np.int32),
           np.arange(2, 13, dtype=np.int32),      # shared prefix (paged)
           np.arange(4, 25, dtype=np.int32)]      # multi-chunk


@pytest.mark.parametrize("kw", [
    dict(),
    dict(schedule="hybrid", prefill_chunk=8),
    dict(cache_kind="paged", block_size=8),
    dict(cache_kind="paged", block_size=8, schedule="hybrid", prefill_chunk=8),
], ids=["dense/decode-only", "dense/hybrid", "paged/decode-only", "paged/hybrid"])
def test_async_matches_sync_greedy(model_params, kw):
    model, params = model_params
    s_reqs, s_stats, _ = _serve(model, params, PROMPTS, async_mode=False, **kw)
    a_reqs, a_stats, _ = _serve(model, params, PROMPTS, async_mode=True, **kw)
    for s, a in zip(s_reqs, a_reqs):
        assert a.done
        assert a.in_flight == 0            # pipeline fully drained
        assert s.out_tokens == a.out_tokens, (s.uid, s.out_tokens, a.out_tokens)
    assert a_stats.generated == s_stats.generated


def test_async_matches_sync_paged_preemption(model_params):
    """A pool sized to force preemption: the async engine must observe
    the victim's in-flight tokens before evicting so the refolded prompt
    is exact (tests/test_cluster.py checks the drain stays victim-only)."""
    model, params = model_params
    prompts = [np.arange(1, 10, dtype=np.int32),
               np.arange(3, 8, dtype=np.int32)]
    kw = dict(cache_kind="paged", block_size=4, n_blocks=9,
              schedule="hybrid", prefill_chunk=8)
    s_reqs, _, _ = _serve(model, params, prompts, async_mode=False,
                          n_new=10, **kw)
    a_reqs, a_stats, eng = _serve(model, params, prompts, async_mode=True,
                                  n_new=10, **kw)
    assert a_stats.preemptions >= 1
    for s, a in zip(s_reqs, a_reqs):
        assert s.out_tokens == a.out_tokens, (s.uid, s.out_tokens, a.out_tokens)
    assert eng.pool.in_use == 0


def test_async_eos_one_step_late_is_masked(model_params):
    """EOS is observed one step after the speculative next step was
    dispatched; the extra token must be masked, leaving output identical
    to the sync engine's."""
    model, params = model_params
    prompt = np.arange(1, 5, dtype=np.int32)
    ref, _, _ = _serve(model, params, [prompt], async_mode=False, n_new=8,
                       n_slots=1)
    eos = ref[0].out_tokens[2]             # stop at the 3rd generated token
    for async_mode in (False, True):
        eng = Engine(model, params, n_slots=1, max_seq=32,
                     async_mode=async_mode)
        r = Request(uid=0, prompt=prompt, max_new_tokens=8, eos_id=eos)
        eng.submit(r)
        eng.run()
        assert r.out_tokens == ref[0].out_tokens[:3], (async_mode, r.out_tokens)
        assert r.in_flight == 0
