"""Token-budget scheduler: budget invariants (decode priority), hybrid
chunked-prefill greedy equivalence with the whole-prefill path (dense and
paged), bounded jit compilation across mixed prompt lengths, and
per-request latency accounting."""
import jax
import numpy as np
import pytest

from repro.configs.reduced import reduce_config
from repro.core.placement import Env
from repro.models.registry import build_model
from repro.serving.engine import Engine, Request
from repro.serving.scheduler import Scheduler, chunk_buckets


def _setup():
    cfg = reduce_config("llama3.2-1b")
    model = build_model(cfg, Env())
    params = model.init(jax.random.key(0))
    return model, params


def _serve(model, params, prompts, n_new=5, n_slots=2, max_seq=32, **kw):
    eng = Engine(model, params, n_slots=n_slots, max_seq=max_seq, **kw)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=n_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    stats = eng.run()
    return reqs, stats, eng


# ----------------------------------------------------------- pure scheduler
def test_chunk_buckets():
    assert chunk_buckets(32) == [32, 16, 8]
    assert chunk_buckets(24) == [24, 12, 8]
    assert chunk_buckets(8) == [8]
    assert chunk_buckets(4) == [4]            # floor only clips downward


def test_scheduler_never_exceeds_token_budget():
    sched = Scheduler(n_slots=4, max_seq=64, mode="hybrid",
                      prefill_chunk=16, token_budget=18)
    sched.submit("req")
    sched.begin(sched.pop(), slot=0, start=0, total=37)
    seen = 0
    for active in ([0, 1, 2, 3], [0, 1, 2, 3], [1, 3], []):
        if sched.inflight is None:
            break
        d = sched.schedule(list(active))
        assert d.tokens_packed() <= sched.token_budget
        assert d.decode_slots == list(active)     # every active slot decodes
        if d.prefill is not None:
            assert d.prefill.n_valid <= sched.prefill_chunk
            assert d.prefill.bucket in sched.buckets
            assert d.prefill.n_valid <= d.prefill.bucket
            seen += d.prefill.n_valid
            sched.advance(d.prefill)
    assert seen > 0


def test_scheduler_decode_slots_take_priority():
    # budget exactly covers the decode batch: no room for prefill
    sched = Scheduler(n_slots=4, max_seq=64, mode="hybrid",
                      prefill_chunk=16, token_budget=4)
    sched.begin("req", slot=0, start=0, total=20)
    d = sched.schedule([0, 1, 2, 3])
    assert d.prefill is None and d.decode_slots == [0, 1, 2, 3]
    # slots drain -> leftover budget funds the chunk again
    d = sched.schedule([0])
    assert d.prefill is not None and d.tokens_packed() <= 4


def test_scheduler_budget_must_cover_decode_batch():
    with pytest.raises(ValueError):
        Scheduler(n_slots=4, max_seq=64, mode="hybrid",
                  prefill_chunk=8, token_budget=3)


def test_scheduler_paged_chunks_end_on_block_boundaries():
    sched = Scheduler(n_slots=2, max_seq=64, mode="hybrid",
                      prefill_chunk=16, block_size=8)
    sched.begin("req", slot=0, start=0, total=21)
    ends = []
    while sched.inflight is not None:
        w = sched.schedule([0, 1]).prefill
        assert w is not None
        ends.append(w.start + w.n_valid)
        sched.advance(w)
    assert ends == [16, 21]                   # block-aligned, final partial
    with pytest.raises(ValueError):           # chunk must be a block multiple
        Scheduler(n_slots=2, max_seq=64, mode="hybrid",
                  prefill_chunk=12, block_size=8)


# ------------------------------------------------------ engine equivalence
PROMPTS = [np.arange(1, 6, dtype=np.int32),
           np.arange(7, 10, dtype=np.int32),
           np.arange(2, 13, dtype=np.int32),
           np.arange(4, 25, dtype=np.int32)]     # 21 tokens: multi-chunk


def test_hybrid_matches_decode_only_dense():
    model, params = _setup()
    d, _, _ = _serve(model, params, PROMPTS)
    h, hs, _ = _serve(model, params, PROMPTS, schedule="hybrid", prefill_chunk=8)
    for a, b in zip(d, h):
        assert b.done
        assert a.out_tokens == b.out_tokens, (a.uid, a.out_tokens, b.out_tokens)
    assert hs.prefill_chunks > hs.prefills       # chunking actually happened


def test_hybrid_matches_decode_only_paged():
    model, params = _setup()
    shared = np.arange(2, 13, dtype=np.int32)
    prompts = [np.arange(1, 6, dtype=np.int32),
               shared, shared,                       # prefix sharing
               np.arange(1, 17, dtype=np.int32),     # exact block multiple
               np.arange(4, 25, dtype=np.int32)]
    d, _, _ = _serve(model, params, prompts)
    p, _, eng = _serve(model, params, prompts, cache_kind="paged",
                       block_size=8, schedule="hybrid", prefill_chunk=8)
    for a, b in zip(d, p):
        assert b.done
        assert a.out_tokens == b.out_tokens, (a.uid, a.out_tokens, b.out_tokens)
    assert eng.pool.stats.hash_hits >= 1             # prefix cache exercised
    assert eng.pool.in_use == 0                      # all blocks returned


def test_hybrid_paged_preemption_restores_exact_tokens():
    model, params = _setup()
    prompts = [np.arange(1, 10, dtype=np.int32),
               np.arange(3, 8, dtype=np.int32)]
    d, _, _ = _serve(model, params, prompts, n_new=10)
    p, ps, eng = _serve(model, params, prompts, n_new=10, cache_kind="paged",
                        block_size=4, n_blocks=9, schedule="hybrid",
                        prefill_chunk=8)
    assert ps.preemptions >= 1
    for a, b in zip(d, p):
        assert a.out_tokens == b.out_tokens, (a.uid, a.out_tokens, b.out_tokens)
    assert eng.pool.in_use == 0


# ------------------------------------------------------------- compilation
def test_hybrid_compiles_within_bucket_set():
    """Serving >= 4 distinct prompt lengths must not compile more hybrid
    programs than the fixed bucket set allows (the decode-only path would
    compile one whole-prefill program per distinct length)."""
    model, params = _setup()
    lens = [5, 9, 13, 21, 27]
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, model.cfg.vocab, size=n).astype(np.int32)
               for n in lens]
    _, _, eng = _serve(model, params, prompts, max_seq=64,
                       schedule="hybrid", prefill_chunk=16)
    n_buckets = len(eng.sched.buckets)
    assert eng._fused._cache_size() <= n_buckets
    assert eng._solo._cache_size() <= n_buckets
    # boundary-pack programs: one shape per (bucket_A, bucket_B) combo
    assert eng._fused2._cache_size() <= n_buckets**2
    assert eng._solo2._cache_size() <= n_buckets**2
    # decode: one fixed shape regardless of the length mix (the async
    # engine dispatches the sampled variant, never the logits step)
    decode_jit = eng._decode_sampled if eng.async_mode else eng._decode
    assert decode_jit._cache_size() == 1


# ------------------------------------------------------ latency accounting
def test_latency_accounting_monotone():
    model, params = _setup()
    hybrid, h_stats, _ = _serve(model, params, PROMPTS, schedule="hybrid",
                                prefill_chunk=8)
    decode_only, d_stats, _ = _serve(model, params, PROMPTS)
    for reqs, stats in ((hybrid, h_stats), (decode_only, d_stats)):
        for r in reqs:
            assert 0 <= r.submit_step <= r.admit_step
            assert r.admit_step <= r.first_token_step <= r.finish_step
        assert stats.ttft_count == len(PROMPTS)
        assert stats.mean_ttft_steps > 0
        assert stats.tokens_per_step > 0
        assert stats.engine_steps > 0
