"""Serving engine: continuous batching must produce exactly the tokens a
per-request reference decode produces (greedy)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.reduced import reduce_config
from repro.core.placement import Env
from repro.models.registry import build_model
from repro.serving import kv_cache
from repro.serving.engine import Engine, Request
from repro.serving.sampler import SamplerConfig, sample


def _reference_decode(model, params, prompt, n_new):
    cache = model.init_cache(1, 32)
    logits, cache = jax.jit(model.prefill)(params, jnp.asarray(prompt)[None], cache)
    toks = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(n_new):
        toks.append(int(tok[0]))
        logits, cache = jax.jit(model.decode_step)(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    return toks


def test_engine_matches_reference_decode():
    cfg = reduce_config("llama3.2-1b")
    model = build_model(cfg, Env())
    params = model.init(jax.random.key(0))
    prompts = [np.arange(1, 6, dtype=np.int32),
               np.arange(7, 10, dtype=np.int32),
               np.arange(2, 11, dtype=np.int32)]
    n_new = 5

    expected = [_reference_decode(model, params, p, n_new) for p in prompts]

    eng = Engine(model, params, n_slots=2, max_seq=32)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=n_new) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    stats = eng.run()
    assert stats.prefills == 3
    # 3 requests through 2 slots -> continuous batching actually happened
    assert stats.peak_active == 2
    for r, exp in zip(reqs, expected):
        assert r.done
        assert r.out_tokens == exp, (r.uid, r.out_tokens, exp)


def test_engine_eos_stops_early():
    cfg = reduce_config("llama3.2-1b")
    model = build_model(cfg, Env())
    params = model.init(jax.random.key(0))
    ref = _reference_decode(model, params, np.arange(1, 5, dtype=np.int32), 8)
    eos = ref[2]  # force stop at the 3rd generated token
    eng = Engine(model, params, n_slots=1, max_seq=32)
    r = Request(uid=0, prompt=np.arange(1, 5, dtype=np.int32), max_new_tokens=8, eos_id=eos)
    eng.submit(r)
    eng.run()
    assert r.out_tokens == ref[:3]


def test_submit_rejects_prompts_that_overflow_cache():
    """A prompt with len >= max_seq - 1 silently overflowed the KV cache
    mid-decode (the first generated token's K/V has no position to land
    in); submit must reject it up front with a clear error."""
    cfg = reduce_config("llama3.2-1b")
    model = build_model(cfg, Env())
    params = model.init(jax.random.key(0))
    eng = Engine(model, params, n_slots=1, max_seq=16)
    for plen in (15, 16, 20):              # max_seq - 1 and beyond
        with pytest.raises(ValueError, match="max_seq"):
            eng.submit(Request(uid=0, prompt=np.arange(plen, dtype=np.int32),
                               max_new_tokens=4))
    # the largest admissible prompt still round-trips
    r = Request(uid=1, prompt=np.arange(1, 15, dtype=np.int32), max_new_tokens=4)
    eng.submit(r)
    eng.run()
    assert r.done and len(r.out_tokens) >= 1


def test_slot_insert_reset_roundtrip():
    cfg = reduce_config("rwkv6-7b")
    model = build_model(cfg, Env())
    cache = model.init_cache(3, 16)
    sub = jax.tree.map(lambda v: jnp.ones_like(v[:, :1] if v.ndim > 1 else v[:1]), cache)
    sub = {k: (jnp.ones_like(v[:, :1]) if k != "lengths" else jnp.ones_like(v[:1]))
           for k, v in cache.items()}
    c2 = kv_cache.insert(cache, sub, 1)
    assert float(c2["state"][:, 1].min()) == 1.0
    assert float(c2["state"][:, 0].max()) == 0.0
    c3 = kv_cache.reset_slot(c2, 1)
    assert float(c3["state"][:, 1].max()) == 0.0


def test_sampler_greedy_topk():
    logits = jnp.array([[0.0, 5.0, 1.0, -2.0]])
    assert int(sample(logits, jax.random.key(0), SamplerConfig())[0]) == 1
    # top-k=1 with temperature == greedy
    t = sample(logits, jax.random.key(0), SamplerConfig(temperature=1.0, top_k=1))
    assert int(t[0]) == 1
