"""Sharded execution on 8 placeholder CPU devices (subprocess — the device
count must be fixed before jax initializes, which pytest already did)."""
import json
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.reduced import reduce_config
from repro.core.placement import Env
from repro.models.registry import build_model
from repro.launch import specs as S
from repro.launch.mesh import compat_mesh, mesh_axes

mesh = compat_mesh((4, 2), ("data", "model"))
axes = mesh_axes(mesh)
cfg = reduce_config("llama3.2-1b").with_overrides(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, head_dim=16)

# single-device reference
m0 = build_model(cfg, Env())
params = m0.init(jax.random.key(0))
B, Sq = 4, 8
toks = jax.random.randint(jax.random.key(1), (B, Sq), 0, cfg.vocab)
c0 = m0.init_cache(B, 16)
log0, c0 = jax.jit(m0.prefill)(params, toks, c0)
log0d, _ = jax.jit(m0.decode_step)(params, c0, jnp.argmax(log0, -1).astype(jnp.int32))

results = {}
for policy in ("batch", "head", "sequence"):
    env = Env(axes=axes, kv_policy=policy, offload="hpu")
    m = build_model(cfg, env)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), m.param_specs(),
                       is_leaf=lambda x: isinstance(x, P))
    params_sharded = jax.tree.map(lambda x, sh: jax.device_put(x, sh), params, psh)
    cache = m.init_cache(B, 16)
    csh = S.cache_shardings(m, jax.eval_shape(lambda: cache), mesh)
    cache = jax.tree.map(lambda x, sh: jax.device_put(x, sh), cache, csh)
    with mesh:
        log, cache = jax.jit(m.prefill)(params_sharded, toks, cache)
        logd, _ = jax.jit(m.decode_step)(
            params_sharded, cache, jnp.argmax(log, -1).astype(jnp.int32))
    err = float(jnp.max(jnp.abs(logd.astype(jnp.float32) - log0d.astype(jnp.float32))))
    results[policy] = err

# sharded train step
from repro.configs.base import ParallelConfig, RunConfig, TrainConfig
from repro.training.trainer import make_train_step
env = Env(axes=axes, fsdp=True)
mt = build_model(cfg, env)
run = RunConfig(model=cfg, parallel=ParallelConfig(zero_stage=1), train=TrainConfig())
init_state, train_step, state_specs, _ = make_train_step(mt, run)
with mesh:
    state = init_state(jax.random.key(0))
    ssh = jax.tree.map(lambda s: NamedSharding(mesh, s), state_specs(),
                       is_leaf=lambda x: isinstance(x, P))
    state = jax.tree.map(lambda x, sh: jax.device_put(x, sh), state, ssh)
    batch = {
        "inputs": toks, "targets": toks,
        "mask": jnp.ones_like(toks, jnp.float32),
    }
    state, metrics = jax.jit(train_step)(state, batch)
results["train_loss"] = float(metrics["loss"])
print(json.dumps(results))
"""


def test_sharded_decode_matches_single_device_all_policies():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)),
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    results = json.loads(out.stdout.strip().splitlines()[-1])
    for policy in ("batch", "head", "sequence"):
        assert results[policy] < 5e-2, (policy, results)
    assert results["train_loss"] > 0 and results["train_loss"] == results["train_loss"]
