"""Speculative multi-token decoding: greedy outputs are token-identical
to non-speculative serving at every depth across all cache x schedule
combos (sync and async, incl. preemption/refold and EOS landing inside
an accepted window), temperature rejection sampling preserves the exact
target distribution, the engine's load accounting charges k+1 tokens
per in-flight verify window, and the spec telemetry (trace marks,
acceptance metrics) round-trips."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.reduced import reduce_config
from repro.core.placement import Env
from repro.models.registry import build_model
from repro.serving.engine import Engine, Request
from repro.serving.sampler import (
    SamplerConfig,
    _transformed,
    spec_draft_sample,
    spec_verify_tokens,
)
from repro.serving.telemetry import (
    Tracer,
    engine_registry,
    to_chrome_trace,
)


@pytest.fixture(scope="module")
def model_params():
    cfg = reduce_config("llama3.2-1b")
    model = build_model(cfg, Env())
    return model, model.init(jax.random.key(0))


@pytest.fixture(scope="module")
def draft():
    """A same-family, differently-seeded draft: its proposals mostly
    *miss*, so identity tests exercise the rejection path for real."""
    cfg = reduce_config("llama3.2-1b")
    model = build_model(cfg, Env())
    return model, model.init(jax.random.key(1))


PROMPTS = [np.arange(1, 6, dtype=np.int32),
           np.arange(7, 10, dtype=np.int32),
           np.arange(2, 13, dtype=np.int32),
           np.arange(4, 25, dtype=np.int32)]      # multi-chunk


def _serve(model, params, prompts, n_new=5, eos_id=-1, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_seq", 32)
    eng = Engine(model, params, **kw)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=n_new, eos_id=eos_id)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return reqs, eng


COMBOS = [
    dict(),
    dict(schedule="hybrid", prefill_chunk=8),
    dict(cache_kind="paged", block_size=8),
    dict(cache_kind="paged", block_size=8, schedule="hybrid", prefill_chunk=8),
]
IDS = ["dense/decode-only", "dense/hybrid", "paged/decode-only", "paged/hybrid"]


# -------------------------------------------------------- greedy identity
@pytest.mark.parametrize("combo", COMBOS, ids=IDS)
@pytest.mark.parametrize("async_mode", [False, True], ids=["sync", "async"])
def test_spec_greedy_token_identical(model_params, draft, combo, async_mode):
    """Whatever the draft proposes, greedy speculative serving emits the
    exact token stream of non-speculative serving — the verify argmax is
    the decode argmax, and rejection truncates at the first mismatch."""
    model, params = model_params
    dmodel, dparams = draft
    base, _ = _serve(model, params, PROMPTS, async_mode=True, **combo)
    for depth in (2, 4):
        spec, eng = _serve(model, params, PROMPTS, async_mode=async_mode,
                           spec_depth=depth, draft_model=dmodel,
                           draft_params=dparams, **combo)
        assert eng.stats.spec_steps >= 1
        for b, s in zip(base, spec):
            assert s.done and s.in_flight == 0 and s.in_flight_steps == 0
            assert b.out_tokens == s.out_tokens, \
                (depth, b.uid, b.out_tokens, s.out_tokens)


def test_spec_preemption_refold_identical(model_params, draft):
    """Block pressure preempts a speculating slot mid-stream: the victim
    drain must observe the pending verify window (committing its accepted
    prefix) so the refolded prompt is exact in both engine modes."""
    model, params = model_params
    dmodel, dparams = draft
    prompts = [np.arange(1, 10, dtype=np.int32),
               np.arange(3, 8, dtype=np.int32)]
    kw = dict(cache_kind="paged", block_size=4, n_blocks=9,
              schedule="hybrid", prefill_chunk=8)
    base, _ = _serve(model, params, prompts, n_new=10, async_mode=True, **kw)
    for async_mode in (False, True):
        spec, eng = _serve(model, params, prompts, n_new=10,
                           async_mode=async_mode, spec_depth=2,
                           draft_model=dmodel, draft_params=dparams, **kw)
        assert eng.stats.preemptions >= 1
        assert eng.pool.in_use == 0
        for b, s in zip(base, spec):
            assert b.out_tokens == s.out_tokens, (b.uid, async_mode)


def test_spec_eos_inside_accepted_window(model_params, draft):
    """With a perfect draft (target params) whole windows are accepted at
    once; an EOS in the middle of the window must truncate the emitted
    run exactly where non-speculative decoding stops."""
    model, params = model_params
    dmodel, _ = draft
    prompts = [np.arange(1, 10, dtype=np.int32),
               np.arange(3, 8, dtype=np.int32)]
    ref, _ = _serve(model, params, prompts, n_new=8, async_mode=True)
    eos = ref[0].out_tokens[3]          # lands mid-window at depth 4
    base, _ = _serve(model, params, prompts, n_new=8, eos_id=eos,
                     async_mode=True)
    spec, eng = _serve(model, params, prompts, n_new=8, eos_id=eos,
                       async_mode=True, spec_depth=4,
                       draft_model=dmodel, draft_params=params)
    assert eng.stats.acceptance_rate > 0.5      # windows really accepted
    for b, s in zip(base, spec):
        assert b.out_tokens == s.out_tokens, (b.uid, b.out_tokens, s.out_tokens)


# ------------------------------------------------------- load accounting
def test_spec_inflight_charges_k_plus_one(model_params, draft):
    """Each dispatched, unobserved verify window holds k+1 in-flight
    token charges (the commit upper bound admission control must assume)
    while counting as a single pipeline step."""
    model, params = model_params
    dmodel, dparams = draft
    depth = 3
    eng = Engine(model, params, n_slots=1, max_seq=64, spec_depth=depth,
                 draft_model=dmodel, draft_params=dparams)
    req = Request(uid=0, prompt=np.arange(1, 6, dtype=np.int32),
                  max_new_tokens=20)
    eng.submit(req)
    seen_window = False
    for _ in range(200):
        more = eng.step()
        if not req.done and req.in_flight_steps > 0:
            # the pipeline holds prefill-sample steps (1 charge) and
            # verify windows (k+1 charges); a window's full charge shows
            # whenever in_flight exceeds the step count
            assert req.in_flight_steps <= req.in_flight \
                <= (depth + 1) * req.in_flight_steps
            if req.in_flight == (depth + 1) * req.in_flight_steps:
                seen_window = True
            # load() reports the charged (worst-case) token footprint
            base = len(req.prompt) + len(req.out_tokens)
            assert eng.load().inflight_tokens == base + req.in_flight
        if not more:
            break
    assert seen_window, "no step ever held only pending verify windows"
    assert req.done and req.in_flight == 0 and req.in_flight_steps == 0


def test_spec_perfect_draft_full_acceptance(model_params, draft):
    """Target-as-draft accepts every window: acceptance rate 1.0 and
    roughly (k+1)x fewer engine steps than token count."""
    model, params = model_params
    dmodel, _ = draft
    reqs, eng = _serve(model, params, PROMPTS, n_new=8, async_mode=True,
                       spec_depth=2, draft_model=dmodel, draft_params=params)
    assert eng.stats.acceptance_rate == 1.0
    assert eng.stats.drafted_tokens == eng.stats.accepted_tokens > 0


# ------------------------------------------------ rejection-sampling math
def _emit_first_token(t_logits, d_logits, cfg, rng):
    """One full draft->verify round; returns the first emitted token."""
    k = d_logits.shape[1]
    keys = jax.random.split(rng, k + 1)
    drafts, probs = [], []
    for j in range(k):
        tok, q = spec_draft_sample(d_logits[:, j], keys[j], cfg)
        drafts.append(tok)
        probs.append(q)
    emitted, _ = spec_verify_tokens(
        t_logits, jnp.stack(drafts, 1), jnp.stack(probs, 1), keys[k], cfg
    )
    return emitted[0, 0]


@pytest.mark.parametrize("cfg", [
    SamplerConfig(temperature=1.0),
    SamplerConfig(temperature=0.7, top_k=5),
], ids=["temperature", "top-k"])
def test_spec_rejection_sampling_preserves_target_distribution(cfg):
    """The emitted token's marginal equals the target's (modified)
    softmax exactly, however bad the draft: empirical counts over many
    independent rounds stay within 5 sigma of the analytic target."""
    V, k, N = 8, 2, 20_000
    t_logits = jax.random.normal(jax.random.key(10), (1, k + 1, V))
    d_logits = 2.0 * jax.random.normal(jax.random.key(11), (1, k, V))
    p_t = np.asarray(jax.nn.softmax(_transformed(t_logits[:, 0], cfg), -1))[0]
    toks = jax.vmap(lambda r: _emit_first_token(t_logits, d_logits, cfg, r))(
        jax.random.split(jax.random.key(12), N)
    )
    counts = np.bincount(np.asarray(toks), minlength=V).astype(float)
    for v in range(V):
        sigma = max(math.sqrt(N * p_t[v] * (1 - p_t[v])), 1.0)
        assert abs(counts[v] - N * p_t[v]) < 5 * sigma, \
            (v, counts[v], N * p_t[v], sigma)
    # top-k: tokens the target truncated away must never be emitted
    if cfg.top_k:
        assert np.all(counts[p_t == 0.0] == 0)


def test_spec_verify_greedy_matches_argmax():
    """Greedy verify emits the target argmax at every position and
    accepts exactly the longest matching draft prefix."""
    cfg = SamplerConfig()
    logits = jax.random.normal(jax.random.key(5), (2, 4, 16))
    tgt = np.asarray(jnp.argmax(logits, -1))
    drafts = jnp.asarray(np.stack([
        tgt[0, :3],                                  # full match -> accept 3
        [tgt[1, 0], (tgt[1, 1] + 1) % 16, tgt[1, 2]],  # mismatch at 1
    ]).astype(np.int32))
    emitted, n_accept = spec_verify_tokens(logits, drafts, None,
                                           jax.random.key(0), cfg)
    np.testing.assert_array_equal(np.asarray(emitted), tgt)
    np.testing.assert_array_equal(np.asarray(n_accept), [3, 1])


def test_spec_rejection_sampling_hypothesis():
    """Property form of the distribution test over random shapes/seeds
    (runs only where the optional ``hypothesis`` dependency exists)."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(seed=st.integers(0, 2**31 - 1), k=st.integers(1, 3))
    @hyp.settings(max_examples=10, deadline=None)
    def run(seed, k):
        cfg = SamplerConfig(temperature=1.0)
        V, N = 6, 4_000
        kt, kd, ks = jax.random.split(jax.random.key(seed), 3)
        t_logits = jax.random.normal(kt, (1, k + 1, V))
        d_logits = jax.random.normal(kd, (1, k, V))
        p_t = np.asarray(jax.nn.softmax(_transformed(t_logits[:, 0], cfg)))[0]
        toks = jax.vmap(
            lambda r: _emit_first_token(t_logits, d_logits, cfg, r)
        )(jax.random.split(ks, N))
        counts = np.bincount(np.asarray(toks), minlength=V).astype(float)
        for v in range(V):
            sigma = max(math.sqrt(N * p_t[v] * (1 - p_t[v])), 1.0)
            assert abs(counts[v] - N * p_t[v]) < 6 * sigma

    run()


# -------------------------------------------------------------- telemetry
def test_spec_trace_and_registry(model_params, draft):
    """A traced spec run pairs spec_propose/spec_verify marks, exports an
    acceptance counter track, and surfaces the acceptance metrics through
    the registry."""
    model, params = model_params
    dmodel, dparams = draft
    tracer = Tracer()
    _, eng = _serve(model, params, PROMPTS, async_mode=True, tracer=tracer,
                    spec_depth=2, draft_model=dmodel, draft_params=dparams)
    proposes = [e for e in tracer.events if e.name == "spec_propose"]
    verifies = [e for e in tracer.events if e.name == "spec_verify"]
    assert len(proposes) == eng.stats.spec_steps >= 1
    assert len(verifies) == eng.stats.spec_steps
    # verify marks are stamped at their window's dispatch step: pairable
    assert {e.step for e in proposes} == {e.step for e in verifies}
    assert sum(e.attrs["accepted"] for e in verifies) == \
        eng.stats.accepted_tokens
    obj = to_chrome_trace(tracer)
    assert any(e["ph"] == "C" and e["name"] == "accepted_per_step"
               for e in obj["traceEvents"])
    snap = engine_registry(eng.stats).snapshot()
    assert snap["spec_steps"] == float(eng.stats.spec_steps)
    assert snap["drafted_tokens"] == float(eng.stats.drafted_tokens)
    assert snap["accepted_tokens"] == float(eng.stats.accepted_tokens)
    assert snap["spec_accept_rate"] == eng.stats.acceptance_rate
    assert snap["spec_accept_frac_count"] == \
        float(len(eng.stats.spec_accept_samples))


# ------------------------------------------------------------- guardrails
def test_spec_rejects_invalid_configs(model_params, draft):
    model, params = model_params
    dmodel, dparams = draft
    with pytest.raises(ValueError):
        Engine(model, params, n_slots=2, max_seq=32, spec_depth=-1)
    with pytest.raises(ValueError):
        Engine(model, params, n_slots=2, max_seq=32, spec_depth=2)  # no draft
    with pytest.raises(NotImplementedError):
        Engine(model, params, n_slots=2, max_seq=32, spec_depth=2,
               draft_model=dmodel, draft_params=dparams,
               cache_kind="paged", block_size=8, kv_dtype="fp8")
