"""Serving telemetry: span-tree structure across every cache x schedule
combo (sync and async, incl. preemption/refold and boundary packing),
Perfetto trace export round-trips and validates, the metrics registry
matches legacy ``EngineStats`` exactly, tracing never changes tokens,
and the disabled tracer stays a no-op."""
import json

import jax
import numpy as np
import pytest

from repro.configs.reduced import reduce_config
from repro.core.placement import Env
from repro.models.registry import build_model
from repro.serving.cluster import Cluster
from repro.serving.cluster.stats import ClusterStats, ReplicaStats
from repro.serving.engine import Engine, EngineStats, Request
from repro.serving.telemetry import (
    NULL_TRACER,
    Counter,
    DispatchCostModel,
    Gauge,
    Histogram,
    MetricsRegistry,
    Tracer,
    build_request_trees,
    cluster_registry,
    engine_registry,
    percentile,
    to_chrome_trace,
    validate_trace,
    write_metrics,
    write_trace,
)


@pytest.fixture(scope="module")
def model_params():
    cfg = reduce_config("llama3.2-1b")
    model = build_model(cfg, Env())
    return model, model.init(jax.random.key(0))


PROMPTS = [np.arange(1, 6, dtype=np.int32),
           np.arange(7, 10, dtype=np.int32),
           np.arange(2, 13, dtype=np.int32),
           np.arange(4, 25, dtype=np.int32)]      # multi-chunk


def _serve_traced(model, params, prompts, n_new=5, tracer=None, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_seq", 32)
    tracer = Tracer() if tracer is None else tracer
    eng = Engine(model, params, tracer=tracer, **kw)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=n_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return reqs, eng, tracer


def _assert_all_well_formed(tracer, n_requests):
    trees = build_request_trees(tracer)
    assert len(trees) == n_requests
    for tree in trees.values():
        assert tree.finished
        assert tree.well_formed() == [], tree.well_formed()
    return trees


# ------------------------------------------------------------- span trees
COMBOS = [
    dict(),                                                   # dense/decode-only
    dict(schedule="hybrid", prefill_chunk=8),                 # dense/hybrid
    dict(cache_kind="paged", block_size=8),                   # paged/decode-only
    dict(cache_kind="paged", block_size=8,
         schedule="hybrid", prefill_chunk=8),                 # paged/hybrid
]


@pytest.mark.parametrize("combo", COMBOS,
                         ids=["dense-decode", "dense-hybrid",
                              "paged-decode", "paged-hybrid"])
@pytest.mark.parametrize("async_mode", [False, True], ids=["sync", "async"])
def test_span_trees_well_formed(model_params, combo, async_mode):
    model, params = model_params
    _, eng, tracer = _serve_traced(model, params, PROMPTS,
                                   async_mode=async_mode, **combo)
    trees = _assert_all_well_formed(tracer, len(PROMPTS))
    # per-dispatch timeline covered every engine step exactly once
    assert len(tracer.steps) == eng.stats.engine_steps
    assert [r.step for r in tracer.steps] == \
        list(range(1, eng.stats.engine_steps + 1))
    # the multi-chunk prompt produced multiple chunk spans under hybrid
    if combo.get("schedule") == "hybrid":
        assert len(trees[(0, 3)].child("prefill_chunk")) >= 2


def test_preemption_refold_trace(model_params):
    """Under block pressure the victim's decode span closes at the
    preemption, a fresh queued span opens, and the re-admission carries a
    ``refolded`` mark — in both engine modes."""
    model, params = model_params
    prompts = [np.arange(1, 10, dtype=np.int32),
               np.arange(3, 8, dtype=np.int32)]
    kw = dict(cache_kind="paged", block_size=4, n_blocks=9,
              schedule="hybrid", prefill_chunk=8)
    for async_mode in (False, True):
        _, eng, tracer = _serve_traced(model, params, prompts, n_new=10,
                                       async_mode=async_mode, **kw)
        assert eng.stats.preemptions >= 1
        trees = _assert_all_well_formed(tracer, len(prompts))
        victim = next(t for t in trees.values() if t.marks("preempted"))
        assert len(victim.marks("refolded")) == len(victim.marks("preempted"))
        assert len(victim.child("queued")) >= 2        # requeued while evicted
        assert len(victim.child("decode")) >= 2        # decode resumed
        pre_step = victim.marks("preempted")[0].step
        closed_at_pre = [s for s in victim.child("decode")
                        if s.end == pre_step and s.attrs.get("preempted")]
        assert closed_at_pre, "no decode span closed at the preemption"


def test_boundary_pack_trace(model_params):
    """A packed boundary leaves a ``boundary_packed`` mark on the head
    request and both chunks appear as spans on their own slot tracks."""
    model, params = model_params
    for async_mode in (False, True):
        _, eng, tracer = _serve_traced(model, params, PROMPTS,
                                       schedule="hybrid", prefill_chunk=8,
                                       async_mode=async_mode)
        assert eng.stats.boundary_packs >= 1
        packs = [e for e in tracer.events if e.name == "boundary_packed"]
        assert len(packs) == eng.stats.boundary_packs
        trees = _assert_all_well_formed(tracer, len(PROMPTS))
        packed = trees[(0, packs[0].uid)]
        # the packed head chunk is a real span at the pack step
        assert any(s.end == packs[0].step
                   for s in packed.child("prefill_chunk"))


# ---------------------------------------------------------------- export
def test_trace_json_round_trip(model_params, tmp_path):
    model, params = model_params
    _, _, tracer = _serve_traced(model, params, PROMPTS,
                                 schedule="hybrid", prefill_chunk=8,
                                 cache_kind="paged", block_size=8)
    path = write_trace(tracer, tmp_path / "trace.json")
    obj = json.loads(path.read_text())
    assert validate_trace(obj) == []
    evs = obj["traceEvents"]
    # every slot/queue/steps track is named for the Perfetto UI
    names = {(e["pid"], e["tid"], e["args"]["name"])
             for e in evs if e["ph"] == "M"}
    assert (0, 0, "replica 0") in {(p, t, n) for p, t, n in names} or \
        any(n == "replica 0" for _, _, n in names)
    assert any(n == "queue" for _, _, n in names)
    assert any(n == "steps" for _, _, n in names)
    # spans and counters made it through JSON intact
    assert any(e["ph"] == "X" and e.get("cat") == "request" for e in evs)
    assert any(e["ph"] == "X" and e.get("cat") == "dispatch" for e in evs)
    assert any(e["ph"] == "C" and e["name"] == "oi" for e in evs)
    assert any(e["ph"] == "C" and e["name"] == "pool_util" for e in evs)


def test_validate_trace_rejects_malformed():
    assert validate_trace([]) == ["top level is not an object"]
    assert validate_trace({}) == ["traceEvents missing or not a list"]
    bad = {"traceEvents": [{"ph": "Q", "name": "x", "pid": 0, "tid": 0,
                            "ts": 0}]}
    assert any("bad ph" in p for p in validate_trace(bad))
    bad = {"traceEvents": [{"ph": "X", "name": "x", "pid": 0, "tid": 0,
                            "ts": -1, "dur": 1}]}
    assert any("bad ts" in p for p in validate_trace(bad))
    bad = {"traceEvents": [{"ph": "X", "name": "x", "pid": 0, "tid": 0,
                            "ts": 0}]}
    assert any("bad dur" in p for p in validate_trace(bad))


# -------------------------------------------------------------- registry
def test_engine_registry_matches_legacy_stats(model_params, tmp_path):
    """The registry is a *view* over EngineStats — every reported number
    equals the legacy field exactly on a greedy run."""
    model, params = model_params
    _, eng, _ = _serve_traced(model, params, PROMPTS,
                              schedule="hybrid", prefill_chunk=8,
                              cache_kind="paged", block_size=8)
    stats = eng.stats
    reg = engine_registry(stats, eng.pool.stats)
    snap = reg.snapshot()
    for name in ("prefills", "prefill_chunks", "boundary_packs",
                 "decode_steps", "engine_steps", "generated",
                 "preemptions", "victim_drains"):
        assert snap[name] == float(getattr(stats, name)), name
    assert snap["peak_active"] == float(stats.peak_active)
    assert snap["tokens_per_step"] == stats.tokens_per_step
    assert snap["mean_ttft_steps"] == stats.mean_ttft_steps
    assert snap["ttft_steps_count"] == float(stats.ttft_count)
    assert snap["ttft_steps_p50"] == stats.ttft_p50_steps
    assert snap["ttft_steps_p99"] == stats.ttft_p99_steps
    assert snap["pool_allocs"] == float(eng.pool.stats.allocs)
    # and the flat JSON dump is the same snapshot
    out = write_metrics(reg, tmp_path / "metrics.json", extra={"wall_s": 1.0})
    dumped = json.loads(out.read_text())
    assert dumped.pop("wall_s") == 1.0
    assert dumped == snap


def test_metrics_primitives():
    assert percentile([], 99) == 0.0
    assert percentile([3.0], 50) == 3.0
    samples = list(range(1, 101))
    assert percentile(samples, 50) == 50.0
    assert percentile(samples, 99) == 99.0
    assert percentile(samples, 100) == 100.0
    # a measured percentile is a value some sample actually took
    odd = [1.0, 10.0, 100.0]
    assert percentile(odd, 90) in odd

    reg = MetricsRegistry()
    reg.counter("c").inc()
    reg.counter("c").inc(2)
    reg.gauge("g").set(7)
    h = reg.histogram("h")
    h.extend([1, 2, 3, 4])
    assert isinstance(reg.counter("c"), Counter)
    assert isinstance(reg.gauge("g"), Gauge)
    assert isinstance(reg.histogram("h"), Histogram)
    with pytest.raises(TypeError):
        reg.gauge("c")
    snap = reg.snapshot()
    assert snap["c"] == 3.0 and snap["g"] == 7.0
    assert snap["h_count"] == 4.0 and snap["h_mean"] == 2.5
    assert "c=3" in reg.render()


# ------------------------------------------------------------- zero cost
def test_null_tracer_is_default_and_inert(model_params):
    model, params = model_params
    eng = Engine(model, params, n_slots=2, max_seq=32)
    assert eng.tracer is NULL_TRACER
    assert not NULL_TRACER.enabled
    assert eng._cost_model is None          # record building skipped entirely
    # every hook is a no-op returning None
    req = Request(uid=0, prompt=np.arange(1, 4, dtype=np.int32),
                  max_new_tokens=1)
    assert NULL_TRACER.on_submit(0, req, 0) is None
    assert NULL_TRACER.on_step(None) is None
    assert NULL_TRACER.wall() is None


def test_tracing_never_changes_tokens(model_params):
    model, params = model_params
    plain, _, _ = _serve_traced(model, params, PROMPTS, tracer=NULL_TRACER,
                                schedule="hybrid", prefill_chunk=8)
    traced, _, tracer = _serve_traced(model, params, PROMPTS,
                                      schedule="hybrid", prefill_chunk=8)
    assert tracer.spans                     # actually recorded something
    for a, b in zip(plain, traced):
        assert a.out_tokens == b.out_tokens, a.uid


# --------------------------------------------------------------- cluster
def test_cluster_trace_and_registry(model_params, tmp_path):
    model, params = model_params
    tracer = Tracer()
    cl = Cluster(model, params, 2, route="prefix_affinity", tracer=tracer,
                 n_slots=2, max_seq=32, cache_kind="paged", block_size=8)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=4)
            for i, p in enumerate(PROMPTS)]
    for r in reqs:
        cl.submit(r)
    cstats = cl.run()
    # every request traced on the replica it was placed on
    trees = build_request_trees(tracer)
    assert len(trees) == len(reqs)
    for (replica, uid), tree in trees.items():
        assert cl.placement[uid] == replica
        assert tree.finished and tree.well_formed() == []
    # one route event per request, stamped with the chosen replica
    routes = [e for e in tracer.events if e.name == "route"]
    assert len(routes) == len(reqs)
    for e in routes:
        assert e.attrs["chosen"] == cl.placement[e.uid]
        assert e.attrs["policy"] == "prefix_affinity"
    # both replicas produced at least one complete span tree
    assert {r for r, _ in trees} == {0, 1}
    # trace exports with a cluster row for the router track
    path = write_trace(tracer, tmp_path / "cluster.json")
    obj = json.loads(path.read_text())
    assert validate_trace(obj) == []
    assert any(e["ph"] == "M" and e["args"]["name"] == "cluster"
               for e in obj["traceEvents"])
    # cluster registry pools replica samples for its percentiles
    reg = cluster_registry(cstats)
    snap = reg.snapshot()
    n = sum(len(r.engine.ttft_samples) for r in cstats.replicas)
    assert snap["ttft_steps_count"] == float(n) == float(len(reqs))
    assert snap["ttft_steps_p99"] == cstats.ttft_p99_steps
    assert snap["generated"] == float(cstats.generated)


def test_cluster_stats_zero_guards():
    empty = ClusterStats(rounds=0, replicas=[], spills=0,
                         prefix_hit_tokens=0, probed_tokens=0,
                         queue_wait_sum=0, queue_wait_count=0)
    assert empty.load_imbalance == 1.0
    assert empty.tokens_per_round == 0.0
    assert empty.ttft_p99_steps == 0.0
    assert empty.per_token_percentile(50) == 0.0
    rs = ReplicaStats(replica=0, routed=0, n_slots=2, engine=EngineStats())
    assert rs.utilization(0) == 0.0
    assert rs.routed_share == 0.0


# ------------------------------------------------------------ cost model
def test_dispatch_cost_model_oi_ordering():
    """Decode-only dispatches sit deep in the memory-bound regime; fusing
    a prefill chunk raises operational intensity — the paper's Fig-1
    co-processing premise, reproduced by the analytic model."""
    cfg = reduce_config("llama3.2-1b")
    cm = DispatchCostModel(cfg)
    d_flops, d_bytes = cm.cost(n_decode=4, kv_tokens=400)
    f_flops, f_bytes = cm.cost(n_decode=4, kv_tokens=400, prefill_tokens=16,
                               prefill_ctx_tokens=cm.chunk_ctx_tokens(0, 16))
    assert d_flops > 0 and d_bytes > 0
    assert f_flops > d_flops                # the chunk adds real work
    assert f_flops / f_bytes > d_flops / d_bytes    # ...at higher OI
    assert cm.chunk_ctx_tokens(0, 4) == 1 + 2 + 3 + 4
    assert cm.chunk_ctx_tokens(8, 2) == 9 + 10


def test_step_records_cover_composition(model_params):
    """The step timeline distinguishes dispatch kinds and its budget-fill
    fraction stays in (0, 1]."""
    model, params = model_params
    _, eng, tracer = _serve_traced(model, params, PROMPTS,
                                   schedule="hybrid", prefill_chunk=8)
    kinds = {r.kind for r in tracer.steps}
    assert "decode" in kinds
    assert kinds & {"fused", "solo", "fused2", "solo2"}
    for r in tracer.steps:
        assert 0.0 < r.fill <= 1.0, r
        assert r.oi > 0.0
        assert r.bytes > 0.0
        assert (r.prefill_tokens > 0) == (r.bucket is not None)
    fused = [r for r in tracer.steps if r.kind.startswith("fused")]
    decode = [r for r in tracer.steps if r.kind == "decode"]
    if fused and decode:
        assert max(f.oi for f in fused) > min(d.oi for d in decode)
