"""Training substrate: convergence, grad-accum equivalence, schedules,
int8 gradient compression (hypothesis properties)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import ParallelConfig, RunConfig, TrainConfig
from repro.configs.reduced import reduce_config
from repro.core.placement import Env
from repro.data.pipeline import DataConfig, host_batch
from repro.models.registry import build_model
from repro.training import compression
from repro.training.optimizer import AdamW, make_schedule
from repro.training.trainer import make_train_step


def _setup(arch="llama3.2-1b", **pkw):
    cfg = reduce_config(arch)
    model = build_model(cfg, Env())
    run = RunConfig(
        model=cfg,
        parallel=ParallelConfig(**pkw),
        train=TrainConfig(lr=3e-3, warmup_steps=2, total_steps=50),
    )
    return cfg, model, make_train_step(model, run)


def test_loss_decreases():
    cfg, model, (init_state, train_step, _, _) = _setup()
    state = init_state(jax.random.key(0))
    dc = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=8)
    step = jax.jit(train_step)
    first = last = None
    for i in range(15):
        b = {k: jnp.asarray(v) for k, v in host_batch(dc, i, 0, 1).items()}
        state, m = step(state, b)
        if i == 0:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first - 0.2, (first, last)


def test_grad_accum_matches_full_batch():
    """accum=2 over the same tokens must match accum=1 closely."""
    cfg, model, (init1, step1, _, _) = _setup(grad_accum=1)
    _, _, (init2, step2, _, _) = _setup(grad_accum=2)
    s1 = init1(jax.random.key(0))
    s2 = init2(jax.random.key(0))
    dc = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=8)
    b = {k: jnp.asarray(v) for k, v in host_batch(dc, 0, 0, 1).items()}
    s1, m1 = jax.jit(step1)(s1, b)
    s2, m2 = jax.jit(step2)(s2, b)
    p1 = jax.tree.leaves(s1["params"])
    p2 = jax.tree.leaves(s2["params"])
    err = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b_.astype(jnp.float32)))) for a, b_ in zip(p1, p2))
    assert err < 2e-2, err  # bf16 params; accum reorders reductions


@pytest.mark.parametrize("name", ["cosine", "wsd", "const"])
def test_schedules_shape(name):
    tc = TrainConfig(lr=1.0, warmup_steps=10, total_steps=100, schedule=name)
    sched = make_schedule(tc)
    xs = jnp.arange(0, 101, dtype=jnp.float32)
    ys = jax.vmap(sched)(xs)
    assert float(ys[0]) == 0.0
    assert float(ys[10]) == pytest.approx(1.0, abs=1e-5)
    if name != "const":
        assert float(ys[100]) <= 0.21
    if name == "wsd":
        # stable phase: flat at peak until 10 + 90*0.8 = 82
        assert float(ys[50]) == pytest.approx(1.0, abs=1e-5)
        assert float(ys[80]) == pytest.approx(1.0, abs=1e-5)
        assert float(ys[95]) < 0.9


def test_adamw_moves_toward_minimum():
    tc = TrainConfig(lr=0.1, warmup_steps=1, total_steps=200, schedule="const",
                     weight_decay=0.0)
    opt = AdamW(tc)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}  # d/dw w^2
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.3


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**16), scale=st.floats(1e-3, 1e3))
def test_property_int8_compression_bounded_error(seed, scale):
    g = jax.random.normal(jax.random.key(seed), (64,)) * scale
    grads = {"g": g}
    err = compression.init_error(grads)
    out, err = compression.compress_grads(grads, err)
    # single-step quantization error bounded by scale/127 per element
    bound = float(jnp.max(jnp.abs(g))) / 127.0 + 1e-9
    assert float(jnp.max(jnp.abs(out["g"] - g))) <= bound * 1.01


def test_int8_error_feedback_unbiased_over_time():
    """With a CONSTANT gradient, error feedback makes the running mean of
    decompressed gradients converge to the true gradient."""
    g = {"g": jnp.array([0.301, -0.777, 0.0031, 1.9])}
    err = compression.init_error(g)
    acc = jnp.zeros(4)
    n = 200
    for _ in range(n):
        out, err = compression.compress_grads(g, err)
        acc = acc + out["g"]
    np.testing.assert_allclose(acc / n, g["g"], rtol=2e-3, atol=2e-4)


def test_state_specs_match_state_tree():
    cfg, model, (init_state, _, state_specs, state_shapes) = _setup()
    env_axes = {"data": 2, "model": 2}
    model2 = build_model(cfg, Env(axes=env_axes))
    run = RunConfig(model=cfg, parallel=ParallelConfig(), train=TrainConfig())
    init2, _, specs2, shapes2 = make_train_step(model2, run)
    specs = specs2()
    shapes = shapes2()
    # same tree structure -> zippable at jit boundary
    jax.tree.map(lambda a, b: None, specs, shapes)
